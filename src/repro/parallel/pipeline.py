"""Pipeline parallelism (PP): GPipe-style circular pipeline over a
``stage`` mesh axis (the MaxText pipelining pattern, JAX-native).

The layer stack is split into S stages; stage s's parameters live on the
mesh slice ``stage=s`` (sharded via shard_map).  M >= S microbatches
flow through the pipeline in M + S - 1 ticks; at each tick every stage
applies its layers to its current activation and the activations rotate
one stage forward via ``lax.ppermute`` (HLO collective-permute over the
ICI ring — the natural TPU topology for PP).

Autodiff goes straight through (transpose of ppermute is the reverse
permute), so ``jax.grad`` of a pipelined loss is GPipe backward; wrap
``stage_fn`` in ``jax.checkpoint`` for the standard activation-memory
profile.  Bubble fraction = (S-1)/(M+S-1), reported by
:func:`bubble_fraction`.

Composes with the other axes: the mesh can be ('stage','data','model'),
with DP/TP rules applying inside each stage as usual.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

Params = Any


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    return (num_stages - 1) / (num_microbatches + num_stages - 1)


def pipeline_apply(stage_params: Params, x_mb: jax.Array,
                   stage_fn: Callable[[Params, jax.Array], jax.Array],
                   num_stages: int, axis: str = "stage") -> jax.Array:
    """Run the circular pipeline; call INSIDE shard_map over `axis`.

    stage_params: this stage's parameter shard (leading dim already
        consumed by shard_map).
    x_mb: (M, mb, ...) microbatches — identical on every stage; stage 0
        feeds them in, stage S-1 produces outputs.
    Returns (M, mb, ...) outputs (valid on every stage; they are
        broadcast back through the rotation).
    """
    S = num_stages
    M = x_mb.shape[0]
    ticks = M + S - 1
    stage_id = jax.lax.axis_index(axis)
    fwd_perm = [(i, (i + 1) % S) for i in range(S)]

    mb_shape = x_mb.shape[1:]
    state = jnp.zeros(mb_shape, x_mb.dtype)          # current activation
    outputs = jnp.zeros_like(x_mb)                   # collected at exit

    def tick(carry, t):
        state, outputs = carry
        # stage 0 ingests microbatch t (if in range)
        feed = x_mb[jnp.clip(t, 0, M - 1)]
        state = jnp.where((stage_id == 0) & (t < M), feed, state)
        # every stage applies its layers
        state = stage_fn(stage_params, state)
        # stage S-1 has finished microbatch (t - (S-1)) at the END of tick t
        out_idx = t - (S - 1)
        is_exit = (stage_id == S - 1) & (out_idx >= 0)
        outputs = jax.lax.dynamic_update_slice_in_dim(
            outputs,
            jnp.where(is_exit, state, outputs[jnp.clip(out_idx, 0, M - 1)])
            [None],
            jnp.clip(out_idx, 0, M - 1), axis=0)
        # rotate activations to the next stage
        state = jax.lax.ppermute(state, axis, fwd_perm)
        return (state, outputs), None

    (state, outputs), _ = jax.lax.scan(
        tick, (state, outputs), jnp.arange(ticks))
    # outputs live on stage S-1; broadcast to all stages (masked psum)
    # so every shard returns the same value and shard_map's out_spec is
    # replicated over the stage axis
    mask = (stage_id == S - 1).astype(outputs.dtype)
    outputs = jax.lax.psum(outputs * mask, axis)
    return outputs


def make_pipelined_forward(stage_fn: Callable, mesh: Mesh,
                           num_stages: int, axis: str = "stage",
                           param_spec=None, x_spec=None):
    """Build f(stacked_stage_params, microbatches) -> outputs.

    stacked_stage_params: leading dim = num_stages (sharded over `axis`);
    microbatches: (M, mb, ...) replicated over `axis`.
    """
    p_spec = param_spec if param_spec is not None else P(axis)

    def body(params, x_mb):
        params = jax.tree.map(lambda a: a[0], params)
        out = pipeline_apply(params, x_mb, stage_fn, num_stages, axis)
        return out

    from repro.parallel.sharding import shard_map_compat

    return shard_map_compat(
        body, mesh=mesh,
        in_specs=(p_spec, x_spec if x_spec is not None else P()),
        out_specs=P())
