"""Roofline analysis from compiled dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh), all in seconds:

  compute    = HLO_FLOPs   / (chips * PEAK_FLOPS)
  memory     = HLO_bytes   / (chips * HBM_BW)
  collective = coll_bytes  / (chips * ICI_BW)

``cost_analysis()`` of an SPMD-partitioned executable reports *per-device*
flops/bytes but counts ``while`` (scan) bodies ONCE — so all three terms
are rebuilt from the optimized HLO text by
:mod:`repro.parallel.hlo_analysis`, which applies loop trip-count
multipliers (validated against ``cost_analysis()`` on unrolled models in
tests).  Collective bytes sum operand sizes over all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Optional

from repro.parallel.hlo_analysis import analyze_hlo

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link
HBM_PER_CHIP = 16 * 1024 ** 3   # v5e: 16 GiB

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

# instruction definition:  %name = dtype[dims]{layout} opcode(...)
_DEF_RE = re.compile(
    r"%?([\w\.\-]+)\s*=\s*\(?([a-z0-9]+)\[([\d,]*)\]")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, int]
    count_by_op: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Collective operand bytes (trip-count corrected, via hlo_analysis)."""
    hc = analyze_hlo(hlo_text)
    return CollectiveStats({k: int(v) for k, v in hc.coll_by_op.items()},
                           dict(hc.coll_count))


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops: float                 # 6*N*D (active N for MoE)
    peak_bytes_per_chip: float = 0.0   # from memory_analysis
    coll_detail: Optional[Dict[str, int]] = None
    tag_bytes: Optional[Dict[str, float]] = None   # kernel-taggable traffic
    tag_coll_bytes: Optional[Dict[str, float]] = None

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """No-overlap upper bound; with perfect overlap it's max(terms)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs, both per-chip (catches remat waste)."""
        return self.model_flops / self.flops_per_chip \
            if self.flops_per_chip else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline step time (per chip)."""
        if self.step_time == 0:
            return 0.0
        return self.model_flops / (PEAK_FLOPS * self.step_time)

    def to_dict(self):
        d = dict(self.__dict__)
        d.update(bottleneck=self.bottleneck, step_time=self.step_time,
                 useful_flops_ratio=self.useful_flops_ratio, mfu=self.mfu)
        return d


def analyze(arch: str, shape: str, mesh_name: str, chips: int,
            cost: Dict[str, float], hlo_text: str,
            model_flops: float, memory_stats=None) -> RooflineReport:
    hc = analyze_hlo(hlo_text)   # trip-count-corrected per-chip costs
    flops = hc.flops
    byts = hc.bytes
    coll_bytes = hc.coll_bytes
    peak_bytes = 0.0
    if memory_stats is not None:
        peak_bytes = (memory_stats.argument_size_in_bytes
                      + memory_stats.output_size_in_bytes
                      + memory_stats.temp_size_in_bytes
                      - memory_stats.alias_size_in_bytes)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_chip=flops, bytes_per_chip=byts,
        coll_bytes_per_chip=coll_bytes,
        t_compute=flops / PEAK_FLOPS,
        t_memory=byts / HBM_BW,
        t_collective=coll_bytes / ICI_BW,
        model_flops=model_flops / chips,   # per-chip share of useful work
        peak_bytes_per_chip=peak_bytes,
        coll_detail={k: int(v) for k, v in hc.coll_by_op.items()},
        tag_bytes={k: float(v) for k, v in hc.tag_bytes.items()},
        tag_coll_bytes={k: float(v) for k, v in hc.tag_coll_bytes.items()},
    )


def kernel_credit_bytes(cfg, shape, chips: int) -> Dict[str, float]:
    """Analytic per-chip HBM traffic of the Pallas kernels that replace
    the tagged pure-JAX scan implementations when deployed on TPU
    (kernels/flash_attention.py, kernels/slstm.py; mLSTM chunkwise).

    fwd traffic = kernel inputs + outputs; training multiplies by ~3.5x
    (backward reads q,k,v,out,dout and writes gradients + the remat
    re-read).  Decode shapes never hit these paths (cache attention /
    single-step recurrences), so credits apply to train/prefill only.
    """
    if shape.kind == "decode":
        return {}
    mult = 3.5 if shape.kind == "train" else 1.0
    B, S = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim
    out: Dict[str, float] = {}
    kinds = cfg.layer_kinds() if cfg.family != "ssm" or cfg.xlstm is None \
        else tuple(cfg.xlstm.pattern[i % len(cfg.xlstm.pattern)]
                   for i in range(cfg.num_layers))
    n_attn = sum(1 for k in kinds if k == "a")
    n_slstm = sum(1 for k in kinds if k == "s")
    n_mlstm = sum(1 for k in kinds if k == "m")
    if n_attn and S >= 4096:   # chunked/flash path only kicks in there
        qkvo = (2 * B * S * cfg.num_heads * hd
                + 2 * B * S * cfg.num_kv_heads * hd) * 2
        out["flash_attention"] = mult * n_attn * qkvo / chips
    if n_slstm:
        d = cfg.d_model
        gx_h = B * S * (4 * d + d) * 4
        out["slstm_cell"] = mult * n_slstm * gx_h / chips
    if n_mlstm:
        from repro.models.xlstm import _mlstm_dims
        dm, H, DH = _mlstm_dims(cfg)
        qkvo = 4 * B * S * dm * 4
        out["mlstm_chunkwise"] = mult * n_mlstm * qkvo / chips
    n_mamba = sum(1 for k in kinds if k == "M")
    if n_mamba and cfg.mamba is not None:
        d_in = cfg.mamba.expand * cfg.d_model
        N = cfg.mamba.d_state
        # kernels/mamba_scan.py: read dt+xc, write y (+ small B/C mats)
        traffic = (3 * B * S * d_in + 2 * B * S * N) * 4
        out["mamba_scan"] = mult * n_mamba * traffic / chips
    return out


def kernel_credit_coll_bytes(cfg, shape, chips: int) -> Dict[str, float]:
    """Collective credit for kernel deployments: a manual-VJP kernel
    accumulates weight gradients LOCALLY and emits one all-reduce of the
    layer's parameters per step, instead of the per-timestep/per-chunk
    partial-gradient all-reduces XLA emits for the scan formulation
    (observed: 4096 x 2.4 MB per sLSTM layer).  Replacement = one f32
    gradient all-reduce of that layer type's params."""
    if shape.kind != "train" or cfg.xlstm is None:
        return {}
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    xc = cfg.xlstm
    kinds = tuple(xc.pattern[i % len(xc.pattern)]
                  for i in range(cfg.num_layers))
    df_s = int(xc.proj_factor_slstm * d)
    slstm_params = d * 4 * d + H * dh * 4 * dh + 2 * d * df_s + df_s * d
    from repro.models.xlstm import _mlstm_dims
    dm, _, _ = _mlstm_dims(cfg)
    mlstm_params = d * 2 * dm + 3 * dm * dm + dm * 2 * H + dm * d
    return {
        "slstm_cell": kinds.count("s") * slstm_params * 4.0,
        "mlstm_chunkwise": kinds.count("m") * mlstm_params * 4.0,
    }


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); D = tokens.
    Train counts fwd+bwd (the 6 factor); prefill/decode are forward-only
    (2*N*D), decode D = batch tokens (one step)."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence per step
    return 2.0 * n_active * shape.global_batch
