"""Custom HLO cost analyzer with while-loop trip-count attribution.

``compiled.cost_analysis()`` visits each instruction ONCE — the body of a
``lax.scan`` (lowered to ``while``) is counted a single time regardless of
trip count, which undercounts flops/bytes/collectives of scan-over-layers
models by the period count.  This module parses the optimized HLO text
and rebuilds the cost model with correct loop multipliers:

  * computations are parsed into blocks; ``while`` instructions link
    body/condition computations; trip counts come from the loop-condition
    ``constant(N)`` + LT compare pattern (JAX scans always lower this way);
  * only *executable* computations are walked (entry, while bodies,
    conditional branches).  Fusion internals / reduce ``to_apply`` regions
    are skipped — their cost is the call-site I/O, matching fused traffic;
  * per instruction: bytes = operand bytes + result bytes;
    flops for dot (2 * result_elems * contracted_elems) and convolution;
  * collective bytes = operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, x loop multiplier.

Validated against ``cost_analysis()`` on unrolled small models in
tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COMP_HDR = re.compile(
    r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_INSTR_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^=]*?\)|[a-z0-9]+\[[\d,]*\]"
    r"(?:\{[^}]*\})?)\s*([a-z0-9\-]+)\((.*)$")


def _shape_list(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _shape_list(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    result_text: str
    opcode: str
    rest: str          # everything after the opening paren

    @property
    def result_bytes(self) -> int:
        return _shape_bytes(self.result_text)

    @property
    def result_elems(self) -> int:
        shapes = _shape_list(self.result_text)
        if not shapes:
            return 0
        n = 1
        for d in shapes[0][1]:
            n *= d
        return n


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = re.sub(r"/\*.*?\*/", "", raw).strip()
        if not line or line.startswith("//"):
            continue
        if " = " not in line:
            m = _COMP_HDR.match(line)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                continue
        if line.startswith("}"):
            continue
        m = _INSTR_RE.match(line)
        if m and cur is not None:
            name, result_text, opcode, rest = m.groups()
            cur.instrs.append(Instr(name, result_text, opcode, rest))
    return comps


def _operand_names(rest: str) -> List[str]:
    # operands are inside the first balanced paren group
    depth, end = 1, len(rest)
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    args = rest[:end]
    return re.findall(r"%([\w\.\-]+)", args)


def _attr(rest: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w\.\-]+)", rest)
    return m.group(1) if m else None


def _branch_comps(rest: str) -> List[str]:
    m = re.search(r"branch_computations=\{([^}]*)\}", rest)
    if m:
        return re.findall(r"%?([\w\.\-]+)", m.group(1))
    out = []
    for key in ("true_computation", "false_computation"):
        v = _attr(rest, key)
        if v:
            out.append(v)
    return out


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_op: Dict[str, float] = field(default_factory=dict)
    coll_count: Dict[str, int] = field(default_factory=dict)
    trip_counts: Dict[str, int] = field(default_factory=dict)
    transcendentals: float = 0.0
    # bytes/flops attributed to instructions whose op_name metadata
    # matches a requested tag (e.g. "flash_attention") — used to credit
    # Pallas-kernel deployments in the roofline (DESIGN.md §6)
    tag_bytes: Dict[str, float] = field(default_factory=dict)
    tag_flops: Dict[str, float] = field(default_factory=dict)
    tag_coll_bytes: Dict[str, float] = field(default_factory=dict)

    def add_coll(self, op: str, nbytes: float, mult: float):
        self.coll_bytes += nbytes * mult
        self.coll_by_op[op] = self.coll_by_op.get(op, 0.0) + nbytes * mult
        self.coll_count[op] = self.coll_count.get(op, 0) + int(mult)


def _dot_flops(instr: Instr, shapes: Dict[str, str]) -> float:
    ops = _operand_names(instr.rest)
    if not ops:
        return 0.0
    lhs_text = shapes.get(ops[0], "")
    lhs_shapes = _shape_list(lhs_text)
    if not lhs_shapes:
        return 0.0
    lhs_dims = lhs_shapes[0][1]
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
    contract = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2.0 * instr.result_elems * contract


def _conv_flops(instr: Instr, shapes: Dict[str, str]) -> float:
    ops = _operand_names(instr.rest)
    if len(ops) < 2:
        return 0.0
    k_shapes = _shape_list(shapes.get(ops[1], ""))
    if not k_shapes:
        return 0.0
    k_elems = 1
    for d in k_shapes[0][1]:
        k_elems *= d
    m = re.search(r"feature_group_count=(\d+)", instr.rest)
    groups = int(m.group(1)) if m else 1
    # per output element: 2 * kernel_elems / (out_features * groups) ... use
    # the standard approximation 2 * out_elems * kernel_elems / out_features
    out_feats = k_shapes[0][1][-1] if k_shapes[0][1] else 1
    return 2.0 * instr.result_elems * max(1, k_elems // max(1, out_feats))


def _trip_count(cond: Computation) -> int:
    """JAX scan condition: compare(%iv, %constant(N)), direction=LT."""
    consts = []
    for ins in cond.instrs:
        if ins.opcode == "constant":
            m = re.match(r"(\-?\d+)", ins.rest)
            if m:
                consts.append(int(m.group(1)))
        m2 = re.search(r"constant\((\-?\d+)\)", ins.rest)
        if m2:
            consts.append(int(m2.group(1)))
    pos = [c for c in consts if c > 0]
    return max(pos) if pos else 1


_METADATA_RE = re.compile(r'op_name="([^"]*)"')


DEFAULT_TAGS = ("flash_attention", "slstm_cell", "mlstm_chunkwise",
                "mamba_scan")


def analyze_hlo(hlo: str, tags: Tuple[str, ...] = DEFAULT_TAGS
                ) -> HloCost:
    comps = parse_computations(hlo)
    # map instruction name -> result text (for operand shape lookups)
    shapes: Dict[str, str] = {}
    for comp in comps.values():
        for ins in comp.instrs:
            shapes[ins.name] = ins.result_text
        # computation parameters also define shapes via header — skip; JAX
        # HLO references params as instructions ("%param = f32[..] parameter")

    cost = HloCost()
    referenced = set()
    for comp in comps.values():
        for ins in comp.instrs:
            for key in ("condition", "body", "calls", "to_apply"):
                v = _attr(ins.rest, key)
                if v:
                    referenced.add(v)
            referenced.update(_branch_comps(ins.rest))
    entries = [n for n in comps if n not in referenced]

    def _tags_of(ins: Instr):
        m = _METADATA_RE.search(ins.rest)
        name = m.group(1) if m else ""
        return [t for t in tags if t in name]

    def _tag(ins: Instr, nbytes: float, nflops: float):
        for t in _tags_of(ins):
            cost.tag_bytes[t] = cost.tag_bytes.get(t, 0.0) + nbytes
            cost.tag_flops[t] = cost.tag_flops.get(t, 0.0) + nflops

    def walk(comp_name: str, mult: float, visiting=()):
        comp = comps.get(comp_name)
        if comp is None or comp_name in visiting:
            return
        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                cond_name = _attr(ins.rest, "condition")
                body_name = _attr(ins.rest, "body")
                trips = _trip_count(comps[cond_name]) if cond_name in comps \
                    else 1
                cost.trip_counts[body_name or "?"] = trips
                if body_name:
                    walk(body_name, mult * trips,
                         visiting + (comp_name,))
                continue
            if op == "conditional":
                branches = _branch_comps(ins.rest)
                # exactly one branch executes per call: average the cost
                # over branches (lax.switch branches here are isomorphic)
                for b in branches:
                    walk(b, mult / max(1, len(branches)),
                         visiting + (comp_name,))
                continue
            # bytes: operands + result (fusion internals are skipped, so
            # this measures fused traffic)
            op_names = _operand_names(ins.rest)
            obytes = sum(_shape_bytes(shapes.get(n, "")) for n in op_names)
            ins_bytes = 0.0
            ins_flops = 0.0
            is_dus_fusion = (op == "fusion"
                             and "dynamic-update-slice" in ins.name)
            is_ds_fusion = (op == "fusion" and not is_dus_fusion
                            and "dynamic-slice" in ins.name)
            if op == "dynamic-update-slice" or is_dus_fusion:
                # in-place update (donated/aliased buffers): traffic is
                # read+write of the UPDATE slice, not the full buffer(s).
                if op == "dynamic-update-slice":
                    upd = _shape_bytes(shapes.get(op_names[1], "")) \
                        if len(op_names) > 1 else 0
                else:
                    # exclude every big loop-carried buffer operand
                    # (>= half the result size), count the rest
                    sizes = [_shape_bytes(shapes.get(n, ""))
                             for n in op_names]
                    thresh = ins.result_bytes / 2
                    upd = sum(s for s in sizes if s < thresh)
                ins_bytes = 2.0 * upd * mult
                cost.bytes += ins_bytes
            elif op == "dynamic-slice" or is_ds_fusion:
                # sliced read of a big buffer (scan xs / KV lookup):
                # traffic = slice read + result write
                ins_bytes = 2.0 * ins.result_bytes * mult
                cost.bytes += ins_bytes
            elif op not in ("parameter", "constant", "get-tuple-element",
                            "tuple", "bitcast"):
                ins_bytes = (obytes + ins.result_bytes) * mult
                cost.bytes += ins_bytes
            if op == "dot":
                ins_flops = _dot_flops(ins, shapes) * mult
            elif op == "convolution":
                ins_flops = _conv_flops(ins, shapes) * mult
            elif op == "fusion":
                # count dot/conv inside the fusion computation (bytes are
                # already the fusion I/O)
                sub = comps.get(_attr(ins.rest, "calls") or "")
                if sub:
                    for s in sub.instrs:
                        if s.opcode == "dot":
                            ins_flops += _dot_flops(s, shapes) * mult
                        elif s.opcode == "convolution":
                            ins_flops += _conv_flops(s, shapes) * mult
                        elif s.opcode in ("exponential", "tanh", "log",
                                          "power", "rsqrt", "sqrt"):
                            cost.transcendentals += s.result_elems * mult
            cost.flops += ins_flops
            _tag(ins, ins_bytes, ins_flops)
            base = None
            for c in COLLECTIVE_OPS:
                if op == c or op.startswith(c + "-"):
                    base = c
                    break
            if base:
                cost.add_coll(base, float(obytes), mult)
                for t in _tags_of(ins):
                    cost.tag_coll_bytes[t] = \
                        cost.tag_coll_bytes.get(t, 0.0) + obytes * mult

    for e in entries:
        walk(e, 1.0)
    return cost


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """Version-portable ``compiled.cost_analysis()``: jax 0.4.x returns a
    one-element list of dicts, newer jax returns the dict directly."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)
