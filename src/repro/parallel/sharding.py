"""Logical-axis sharding rules (MaxText-style) for DP / FSDP / TP / EP / SP.

Models annotate params and activations with *logical* axis names; a
:class:`ShardingRules` object (active via :func:`use_sharding`) maps those
names onto physical mesh axes.  Outside a sharding context every
constraint is a no-op, so the same model code runs on 1 CPU device in
tests and on the 512-chip production mesh in the dry-run.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

AxisName = Union[str, None]
LogicalAxes = Tuple[AxisName, ...]


def shard_map_compat(f, mesh, in_specs, out_specs, check: bool = False):
    """Version-portable shard_map: jax >= 0.6 exposes ``jax.shard_map``
    (``check_vma``); jax 0.4.x has ``jax.experimental.shard_map``
    (``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=check)

# ---------------------------------------------------------------------------
# Default logical -> physical rules
# ---------------------------------------------------------------------------

# Weight axes
#   "embed"    : the d_model dim of weights — FSDP (ZeRO-3) over the data axes
#   "heads_w"  : flattened (num_heads*head_dim) projection dim — TP
#   "mlp"      : FFN hidden dim — TP
#   "experts"  : MoE expert dim — EP
#   "vocab"    : embedding/logits vocab dim — TP
#   "layers"/"period" : scan-stacking dims — never sharded
# Activation axes
#   "batch"    : global batch — DP over (pod, data)
#   "seq"      : sequence — unsharded (or "model" when seq_parallel)
#   "heads"    : per-head activation dim — TP
#   "mlp_act"  : FFN hidden activation — TP
#   "kv_seq"   : KV-cache sequence dim — TP (flash-decode style)
#   "pages"    : paged-KV pool page dim — DP over `data` (serving mesh)
DEFAULT_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_sp": None,          # becomes "model" when seq_parallel is on
    "act_embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp_act": "model",
    "experts_act": "model",
    "kv_seq": "model",
    "state": "model",        # SSM/mLSTM inner state dim
    # weights
    "embed": ("pod", "data"),
    "vocab": "model",
    "heads_w": "model",
    "mlp": "model",
    "experts": "model",
    "state_w": "model",
    "layers": None,
    "period": None,
    "conv": None,
    "pages": "data",
    None: None,
}

# serve — weights-stationary decode: pure TP over `model` (weights never
# gathered; per-token collectives are tiny activation all-reduces), the
# decode batch DP over (pod, data), and every KV-cache leaf over `data`:
# dense rows / recurrent state on their batch dim, paged pools on the
# page dim (each data shard owns a private sub-pool its block tables
# address — see repro.serve.mesh).  Shared by the dry-run "serve" preset
# (launch/dryrun.py) and the live serving mesh (serve/mesh.py) so the
# compile-time capacity study and the runtime agree on the layout.
SERVE_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq_sp": None,
    "embed": None,
    "vocab": "model",
    "heads_w": "model",
    "mlp": "model",
    "experts": "model",
    "state_w": "model",
    "kv_seq": "model",
    "kv_heads": "model",
    "pages": "data",
}


def serve_rules(**overrides) -> Dict[str, Any]:
    """The weights-stationary serving rule set (copy; override freely)."""
    rules = dict(SERVE_RULES)
    rules.update(overrides)
    return rules


@dataclass
class ShardingRules:
    mesh: Optional[Mesh] = None
    rules: Dict[str, Any] = field(default_factory=dict)

    def resolve(self, axes: Sequence[AxisName], shape=None) -> P:
        """Map logical axes -> PartitionSpec, dropping mesh axes that are
        absent from the mesh or that do not divide the dimension."""
        if self.mesh is None:
            return P()
        mesh_axes = dict(zip(self.mesh.axis_names, self.mesh.shape.values())) \
            if hasattr(self.mesh.shape, "values") else \
            dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        spec = []
        used = set()
        for i, name in enumerate(axes):
            phys = self.rules.get(name, DEFAULT_RULES.get(name))
            if phys is None:
                spec.append(None)
                continue
            if isinstance(phys, str):
                phys = (phys,)
            phys = tuple(a for a in phys if a in mesh_axes and a not in used)
            if not phys:
                spec.append(None)
                continue
            if shape is not None:
                total = 1
                for a in phys:
                    total *= mesh_axes[a]
                if shape[i] % total != 0:
                    # drop trailing axes until divisible
                    while phys and shape[i] % _prod(mesh_axes, phys) != 0:
                        phys = phys[:-1]
                    if not phys:
                        spec.append(None)
                        continue
            used.update(phys)
            spec.append(phys if len(phys) > 1 else phys[0])
        return P(*spec)

    def sharding(self, axes: Sequence[AxisName], shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.resolve(axes, shape))


def _prod(mesh_axes, phys):
    t = 1
    for a in phys:
        t *= mesh_axes[a]
    return t


# ---------------------------------------------------------------------------
# Context management
# ---------------------------------------------------------------------------

_local = threading.local()


def _current() -> Optional[ShardingRules]:
    return getattr(_local, "ctx", None)


@contextlib.contextmanager
def use_sharding(mesh: Optional[Mesh], **rule_overrides):
    """Activate sharding rules for model code executed inside."""
    prev = _current()
    _local.ctx = ShardingRules(mesh, dict(rule_overrides)) if mesh is not None \
        else None
    try:
        yield _local.ctx
    finally:
        _local.ctx = prev


def current_mesh() -> Optional[Mesh]:
    """Mesh of the active sharding context (None outside one).

    Read at TRACE time: kernel dispatchers (``repro.kernels.ops``) use
    it to pick a shard_map lowering when model code is being traced
    under a serving mesh.
    """
    ctx = _current()
    return ctx.mesh if ctx is not None else None


def constrain(x: jax.Array, *axes: AxisName) -> jax.Array:
    """with_sharding_constraint under the active rules; no-op otherwise."""
    ctx = _current()
    if ctx is None or ctx.mesh is None:
        return x
    spec = ctx.resolve(axes, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def tree_shardings(mesh: Mesh, axes_tree, shape_tree=None,
                   **rule_overrides):
    """Map a tree of logical-axes tuples to a tree of NamedShardings.

    ``shape_tree`` (matching ShapeDtypeStructs or arrays) enables the
    divisibility check so non-divisible dims fall back to replication.
    """
    ctx = ShardingRules(mesh, dict(rule_overrides))

    if shape_tree is None:
        return jax.tree.map(
            lambda axes: ctx.sharding(axes),
            axes_tree, is_leaf=_is_axes_leaf)
    return jax.tree.map(
        lambda axes, arr: ctx.sharding(axes, arr.shape),
        axes_tree, shape_tree, is_leaf=_is_axes_leaf)


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(a is None or isinstance(a, str)
                                        for a in x)


def seq_parallel_rules() -> Dict[str, Any]:
    """Rule overrides enabling sequence parallelism on the residual stream."""
    return {"seq_sp": "model"}
