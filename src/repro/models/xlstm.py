"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM.

TPU adaptation: the mLSTM is computed in the *chunkwise-parallel* form —
quadratic attention-like mixing inside fixed-size chunks plus a recurrent
carry ``(C, n, m)`` across chunks (exactly the formulation that maps onto
MXU matmuls), instead of the fused CUDA recurrent kernel.  The sLSTM is a
``lax.scan`` recurrence (it is sequential by construction; the paper's
GPU kernel parallelizes over batch/heads which XLA also does here).

Both blocks expose decode steps carrying O(1) state — this is what makes
the ``long_500k`` shape runnable for this family.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, XLSTMConfig
from repro.models.layers import KeyGen, dense_init
from repro.parallel.sharding import constrain

Params = Dict[str, Any]


def _xcfg(cfg: ModelConfig) -> XLSTMConfig:
    return cfg.xlstm or XLSTMConfig()


def _mlstm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    xc = _xcfg(cfg)
    dm = int(xc.proj_factor_mlstm * cfg.d_model)
    H = cfg.num_heads
    dm -= dm % (H * 2)  # keep head dim even and divisible
    return dm, H, dm // H


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(keys: KeyGen, cfg: ModelConfig) -> Tuple[Params, Params]:
    d, dt = cfg.d_model, jnp.dtype(cfg.dtype)
    dm, H, DH = _mlstm_dims(cfg)
    p: Params = {
        "up": dense_init(keys(), d, 2 * dm, dt),
        "wq": dense_init(keys(), dm, dm, dt),
        "wk": dense_init(keys(), dm, dm, dt),
        "wv": dense_init(keys(), dm, dm, dt),
        "w_if": dense_init(keys(), dm, 2 * H, jnp.dtype("float32")),
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]),
        "down": dense_init(keys(), dm, d, dt),
    }
    a: Params = {
        "up": ("embed", "mlp"), "wq": ("mlp", "state_w"),
        "wk": ("mlp", "state_w"), "wv": ("mlp", "state_w"),
        "w_if": ("mlp", None), "b_if": (None,),
        "down": ("mlp", "embed"),
    }
    return p, a


def _mlstm_qkvif(params: Params, cfg: ModelConfig, x: jax.Array):
    """x: (B,S,d) -> q,k,v: (B,H,S,DH); li,lf: (B,H,S) (log-gates)."""
    B, S, _ = x.shape
    dm, H, DH = _mlstm_dims(cfg)
    xz = x @ params["up"]
    xm, z = jnp.split(xz, 2, axis=-1)                      # (B,S,dm) each
    xm = constrain(xm, "batch", "seq", "mlp_act")

    def heads(w):
        return (xm @ w).reshape(B, S, H, DH).transpose(0, 2, 1, 3)

    q, k, v = heads(params["wq"]), heads(params["wk"]), heads(params["wv"])
    gates = (xm.astype(jnp.float32) @ params["w_if"]) + params["b_if"]
    li, lf_raw = jnp.split(gates, 2, axis=-1)              # (B,S,H)
    li = li.transpose(0, 2, 1)
    lf = jax.nn.log_sigmoid(lf_raw).transpose(0, 2, 1)     # (B,H,S)
    return q, k, v, li, lf, z


def _mlstm_chunk(q, k, v, li, lf, state):
    """One chunk of the stabilized chunkwise mLSTM.

    q/k/v: (B,H,Q,DH) float32; li/lf: (B,H,Q); state=(C,n,m):
    C (B,H,DH,DH), n (B,H,DH), m (B,H).  Returns (h, new_state).
    """
    B, H, Q, DH = q.shape
    C0, n0, m0 = state
    csum = jnp.cumsum(lf, axis=-1)                           # (B,H,Q)
    # intra-chunk log-weights: D[t,s] = csum_t - csum_s + li_s (s<=t)
    Dtil = csum[..., :, None] - csum[..., None, :] + li[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    Dtil = jnp.where(mask, Dtil, -jnp.inf)
    b = csum + m0[..., None]                                 # carry-in decay
    m_new = jnp.maximum(jnp.max(Dtil, axis=-1), b)           # (B,H,Q)
    W = jnp.exp(Dtil - m_new[..., None])                     # (B,H,Q,Q)
    a = jnp.exp(b - m_new)                                   # (B,H,Q)

    scale = 1.0 / math.sqrt(DH)
    qk = jnp.einsum("bhtd,bhsd->bhts", q, k) * scale         # (B,H,Q,Q)
    num = jnp.einsum("bhts,bhsd->bhtd", W * qk, v) \
        + a[..., None] * jnp.einsum("bhde,bhtd->bhte", C0, q * scale)
    # denominator: n_t^T q_t with n_t = decayed n0 + sum_s w[t,s] k_s
    den = jnp.einsum("bhts,bhsd,bhtd->bht", W, k * scale, q) \
        + a * jnp.einsum("bhd,bhtd->bht", n0, q * scale)
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_new))
    h = num / den[..., None]                                 # (B,H,Q,DH)

    # end-of-chunk state
    g_end = csum[..., -1]                                    # (B,H)
    m_end = jnp.maximum(g_end + m0,
                        jnp.max(g_end[..., None] - csum + li, axis=-1))
    w_end = jnp.exp(g_end[..., None] - csum + li - m_end[..., None])
    C1 = jnp.exp(g_end + m0 - m_end)[..., None, None] * C0 \
        + jnp.einsum("bhs,bhsd,bhse->bhde", w_end, k, v)
    n1 = jnp.exp(g_end + m0 - m_end)[..., None] * n0 \
        + jnp.einsum("bhs,bhsd->bhd", w_end, k)
    return h, (C1, n1, m_end)


def mlstm_block(params: Params, cfg: ModelConfig, x: jax.Array,
                return_state: bool = False):
    """Full-sequence chunkwise mLSTM. x: (B,S,d)."""
    B, S, d = x.shape
    xc = _xcfg(cfg)
    dm, H, DH = _mlstm_dims(cfg)
    q, k, v, li, lf, z = _mlstm_qkvif(params, cfg, x)
    q, k, v = (t.astype(jnp.float32) for t in (q, k, v))

    Q = min(xc.chunk_size, S)
    pad = (-S) % Q
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0)))
                   for t in (q, k, v))
        li = jnp.pad(li, ((0, 0), (0, 0), (0, pad)), constant_values=-1e9)
        lf = jnp.pad(lf, ((0, 0), (0, 0), (0, pad)))
    Sp = S + pad
    nch = Sp // Q

    def to_chunks(t):
        return t.reshape(B, H, nch, Q, *t.shape[3:]).swapaxes(0, 2) \
                .swapaxes(1, 2)  # (nch, B, H, Q, ...)

    qs, ks, vs = to_chunks(q), to_chunks(k), to_chunks(v)
    lis, lfs = to_chunks(li[..., None])[..., 0], to_chunks(lf[..., None])[..., 0]

    state = init_mlstm_state(cfg, B)[0]
    state = (state["C"], state["n"], state["m"])

    def step(st, inp):
        cq, ck, cv, cli, clf = inp
        h, st = _mlstm_chunk(cq, ck, cv, cli, clf, st)
        return st, h

    # checkpointed body: backward saves only the (C, n, m) carry per chunk
    with jax.named_scope("mlstm_chunkwise"):
        st, hs = jax.lax.scan(jax.checkpoint(step), state,
                              (qs, ks, vs, lis, lfs))
    h = hs.swapaxes(1, 2).swapaxes(0, 2).reshape(B, H, Sp, DH)[:, :, :S]
    h = h.transpose(0, 2, 1, 3).reshape(B, S, dm).astype(x.dtype)
    h = h * jax.nn.silu(z)
    out = h @ params["down"]
    out = constrain(out, "batch", "seq", "act_embed")
    if not return_state:
        return out
    return out, {"C": st[0], "n": st[1], "m": st[2]}


def init_mlstm_state(cfg: ModelConfig, batch: int):
    _, H, DH = _mlstm_dims(cfg)
    state = {
        "C": jnp.zeros((batch, H, DH, DH), jnp.float32),
        "n": jnp.zeros((batch, H, DH), jnp.float32),
        "m": jnp.full((batch, H), -1e9, jnp.float32),
    }
    axes = {"C": ("batch", "heads", None, None),
            "n": ("batch", "heads", None),
            "m": ("batch", "heads")}
    return state, axes


def mlstm_decode(params: Params, cfg: ModelConfig, x: jax.Array,
                 state: Params):
    """Single-token stabilized mLSTM recurrence. x: (B,1,d)."""
    B = x.shape[0]
    dm, H, DH = _mlstm_dims(cfg)
    q, k, v, li, lf, z = _mlstm_qkvif(params, cfg, x)
    q, k, v = (t[:, :, 0].astype(jnp.float32) for t in (q, k, v))  # (B,H,DH)
    li, lf = li[..., 0], lf[..., 0]                                # (B,H)
    C0, n0, m0 = state["C"], state["n"], state["m"]
    m1 = jnp.maximum(lf + m0, li)
    fp = jnp.exp(lf + m0 - m1)
    ip = jnp.exp(li - m1)
    scale = 1.0 / math.sqrt(DH)
    C1 = fp[..., None, None] * C0 + ip[..., None, None] \
        * jnp.einsum("bhd,bhe->bhde", k, v)
    n1 = fp[..., None] * n0 + ip[..., None] * k
    num = jnp.einsum("bhde,bhd->bhe", C1, q * scale)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n1, q * scale)),
                      jnp.exp(-m1))
    h = (num / den[..., None]).reshape(B, 1, dm).astype(x.dtype)
    h = h * jax.nn.silu(z)
    out = h @ params["down"]
    return constrain(out, "batch", "seq", "act_embed"), \
        {"C": C1, "n": n1, "m": m1}


def mlstm_decode_multi(params: Params, cfg: ModelConfig, x: jax.Array,
                       state: Params, valid=None):
    """K-token mLSTM decode with per-row state freezing past ``valid``
    (speculative verify / rollback replay; see
    :func:`repro.models.layers.decode_scan`)."""
    from repro.models.layers import decode_scan
    return decode_scan(
        lambda xt, st: mlstm_decode(params, cfg, xt, st), x, state, valid)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(keys: KeyGen, cfg: ModelConfig) -> Tuple[Params, Params]:
    """sLSTM: per the paper, the recurrent weights are BLOCK-DIAGONAL per
    head (r: (H, dh, 4*dh)) — 1/H the flops/bytes of a dense recurrence
    and small enough to stay VMEM-resident in a fused TPU kernel."""
    d, dt = cfg.d_model, jnp.dtype(cfg.dtype)
    xc = _xcfg(cfg)
    df = int(xc.proj_factor_slstm * d)
    H = cfg.num_heads
    dh = d // H
    p: Params = {
        "w_x": dense_init(keys(), d, 4 * d, jnp.dtype("float32")),
        "r_h": (jax.random.normal(keys(), (H, dh, 4 * dh), jnp.float32)
                / (dh ** 0.5)),
        "bias": jnp.zeros((4 * d,), jnp.float32),
        "up_g": dense_init(keys(), d, df, dt),
        "up_v": dense_init(keys(), d, df, dt),
        "down": dense_init(keys(), df, d, dt),
    }
    a: Params = {
        "w_x": ("embed", None), "r_h": ("heads", None, None),
        "bias": (None,),
        "up_g": ("embed", "mlp"), "up_v": ("embed", "mlp"),
        "down": ("mlp", "embed"),
    }
    return p, a


def init_slstm_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    state = {k: jnp.zeros((batch, d), jnp.float32) for k in "hcn"}
    state["m"] = jnp.full((batch, d), -1e9, jnp.float32)
    axes = {k: ("batch", "state") for k in ("h", "c", "n", "m")}
    return state, axes


def _slstm_cell(params: Params, cfg: ModelConfig, state, gx):
    """One recurrence step from precomputed input gates gx = W_x x + b.

    gx: (B, 4d) f32.  The recurrent contribution uses the per-head
    block-diagonal r_h: (H, dh, 4dh).  Stabilized exponential gating."""
    H = cfg.num_heads
    d = cfg.d_model
    dh = d // H
    h0, c0, n0, m0 = state["h"], state["c"], state["n"], state["m"]
    B = h0.shape[0]
    rec = jnp.einsum("bhd,hde->bhe", h0.reshape(B, H, dh),
                     params["r_h"])                     # (B, H, 4*dh)
    # regroup per-head gates to the (B, 4d) [i|f|z|o] layout
    rec = rec.reshape(B, H, 4, dh).transpose(0, 2, 1, 3).reshape(B, 4 * d)
    gates = gx + rec
    it, ft, zt, ot = jnp.split(gates, 4, axis=-1)
    lf = jax.nn.log_sigmoid(ft)
    m1 = jnp.maximum(lf + m0, it)
    ip = jnp.exp(it - m1)
    fp = jnp.exp(lf + m0 - m1)
    c1 = fp * c0 + ip * jnp.tanh(zt)
    n1 = jnp.maximum(fp * n0 + ip, 1e-6)
    h1 = jax.nn.sigmoid(ot) * c1 / n1
    return h1, {"h": h1, "c": c1, "n": n1, "m": m1}


def slstm_block(params: Params, cfg: ModelConfig, x: jax.Array,
                return_state: bool = False):
    """Sequential sLSTM over S, then gated FFN. x: (B,S,d).

    The input-side gate projections for ALL timesteps are one batched
    matmul outside the scan (the only per-step work left is the small
    block-diagonal recurrence — which a fused TPU kernel keeps in VMEM).
    """
    B, S, d = x.shape
    state, _ = init_slstm_state(cfg, B)
    gx = x.astype(jnp.float32) @ params["w_x"] + params["bias"]  # (B,S,4d)

    def step(st, g):
        h, st = _slstm_cell(params, cfg, st, g)
        return st, h

    with jax.named_scope("slstm_cell"):
        st, hs = jax.lax.scan(jax.checkpoint(step), state,
                              gx.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).astype(x.dtype)                   # (B,S,d)
    out = (jax.nn.gelu(h @ params["up_g"]) * (h @ params["up_v"])) \
        @ params["down"]
    out = constrain(out, "batch", "seq", "act_embed")
    if not return_state:
        return out
    return out, st


def slstm_decode(params: Params, cfg: ModelConfig, x: jax.Array,
                 state: Params):
    gx = x[:, 0].astype(jnp.float32) @ params["w_x"] + params["bias"]
    h, st = _slstm_cell(params, cfg, state, gx)
    h = h[:, None].astype(x.dtype)
    out = (jax.nn.gelu(h @ params["up_g"]) * (h @ params["up_v"])) \
        @ params["down"]
    return constrain(out, "batch", "seq", "act_embed"), st


def slstm_decode_multi(params: Params, cfg: ModelConfig, x: jax.Array,
                       state: Params, valid=None):
    """K-token sLSTM decode with per-row state freezing past ``valid``
    (speculative verify / rollback replay; see
    :func:`repro.models.layers.decode_scan`)."""
    from repro.models.layers import decode_scan
    return decode_scan(
        lambda xt, st: slstm_decode(params, cfg, xt, st), x, state, valid)
