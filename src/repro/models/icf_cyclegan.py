"""The paper's surrogate model: CycleGAN for ICF (Section II-D, Fig. 2).

Components (all fully-connected, per the paper):
  * multimodal autoencoder — encoder ``E: R^out -> R^20`` and decoder
    ``Dec: R^20 -> R^out`` over the output bundle y = (15 scalars,
    12 x 64x64 images) — *internal consistency* (joint prediction).
  * forward model ``F: R^5 -> R^20`` into the AE latent.
  * latent discriminator ``D: R^20 -> [0,1]`` — *physical consistency*
    (adversarial: F(x) latents vs E(y) latents).
  * inverse model ``G: R^20 -> R^5`` with ``G(F(x)) ~= x`` —
    *self consistency* (cycle, MAE).

Parameters are split into ``{"gen": ..., "disc": ...}`` so the LTFB GAN
variant (paper Section III-C / Fig. 6) can exchange generators while
keeping discriminators local.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.icf_cyclegan import CycleGANConfig
from repro.models.layers import KeyGen, dense_init

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# MLP helper
# ---------------------------------------------------------------------------


def init_mlp_stack(keys: KeyGen, dims, dtype) -> Tuple[Params, Params]:
    p = {"w": [], "b": []}
    for i in range(len(dims) - 1):
        p["w"].append(dense_init(keys(), dims[i], dims[i + 1], dtype))
        p["b"].append(jnp.zeros((dims[i + 1],), dtype))
    p["w"] = tuple(p["w"])
    p["b"] = tuple(p["b"])
    axes = {"w": tuple(("embed", "mlp") for _ in p["w"]),
            "b": tuple(("mlp",) for _ in p["b"])}
    return p, axes


def mlp_apply(p: Params, x: jax.Array, final_act=None) -> jax.Array:
    n = len(p["w"])
    for i, (w, b) in enumerate(zip(p["w"], p["b"])):
        x = x @ w + b
        if i < n - 1:
            x = jax.nn.leaky_relu(x, 0.2)
        elif final_act is not None:
            x = final_act(x)
    return x


# ---------------------------------------------------------------------------
# CycleGAN init / apply
# ---------------------------------------------------------------------------


def init_cyclegan(cfg: CycleGANConfig, key: jax.Array) -> Tuple[Params, Params]:
    keys = KeyGen(key)
    dt = jnp.dtype(cfg.dtype)
    d_out, z = cfg.output_dim, cfg.latent_dim
    p: Params = {"gen": {}, "disc": {}}
    a: Params = {"gen": {}, "disc": {}}
    p["gen"]["fwd"], a["gen"]["fwd"] = init_mlp_stack(
        keys, (cfg.input_dim, *cfg.fwd_hidden, z), dt)
    p["gen"]["inv"], a["gen"]["inv"] = init_mlp_stack(
        keys, (z, *cfg.inv_hidden, cfg.input_dim), dt)
    p["gen"]["enc"], a["gen"]["enc"] = init_mlp_stack(
        keys, (d_out, *cfg.enc_hidden, z), dt)
    p["gen"]["dec"], a["gen"]["dec"] = init_mlp_stack(
        keys, (z, *cfg.dec_hidden, d_out), dt)
    p["disc"], a["disc"] = init_mlp_stack(
        keys, (z, *cfg.disc_hidden, 1), dt)
    return p, a


def forward_model(gen: Params, x: jax.Array) -> jax.Array:
    """F: experiment params (B,5) -> latent (B,20)."""
    return mlp_apply(gen["fwd"], x)


def inverse_model(gen: Params, zlat: jax.Array) -> jax.Array:
    """G: latent -> experiment params."""
    return mlp_apply(gen["inv"], zlat)


def encode(gen: Params, y: jax.Array) -> jax.Array:
    return mlp_apply(gen["enc"], y)


def decode(gen: Params, zlat: jax.Array) -> jax.Array:
    return mlp_apply(gen["dec"], zlat)


def discriminate(disc: Params, zlat: jax.Array) -> jax.Array:
    """D: latent -> logit (pre-sigmoid)."""
    return mlp_apply(disc, zlat)[..., 0]


def predict(gen: Params, x: jax.Array) -> jax.Array:
    """Surrogate prediction: x -> output bundle (scalars + images)."""
    return decode(gen, forward_model(gen, x))


# ---------------------------------------------------------------------------
# Losses (paper: MAE for consistency, adversarial on latent)
# ---------------------------------------------------------------------------


def _mae(a, b):
    return jnp.mean(jnp.abs(a - b))


def generator_loss(gen: Params, disc: Params, cfg: CycleGANConfig,
                   batch: Dict[str, jax.Array]):
    """batch: {'x': (B,5), 'y': (B, output_dim)}."""
    x, y = batch["x"], batch["y"]
    z_fake = forward_model(gen, x)
    z_real = encode(gen, y)
    y_hat = decode(gen, z_fake)
    y_rec = decode(gen, z_real)
    x_cyc = inverse_model(gen, z_fake)

    l_recon = _mae(y_rec, y)                        # AE reconstruction
    l_forward = _mae(y_hat, y)                      # internal consistency
    l_latent = _mae(z_fake, jax.lax.stop_gradient(z_real))
    l_cycle = _mae(x_cyc, x)                        # self consistency
    # non-saturating GAN loss against the (frozen) local discriminator
    logit_fake = discriminate(jax.lax.stop_gradient(disc), z_fake)
    l_adv = jnp.mean(jax.nn.softplus(-logit_fake))

    loss = (cfg.w_recon * l_recon + cfg.w_forward * (l_forward + l_latent)
            + cfg.w_cycle * l_cycle + cfg.w_adv * l_adv)
    metrics = {"recon": l_recon, "forward": l_forward, "cycle": l_cycle,
               "adv_gen": l_adv, "latent": l_latent}
    return loss, metrics


def discriminator_loss(disc: Params, gen: Params, cfg: CycleGANConfig,
                       batch: Dict[str, jax.Array]):
    x, y = batch["x"], batch["y"]
    z_fake = jax.lax.stop_gradient(forward_model(gen, x))
    z_real = jax.lax.stop_gradient(encode(gen, y))
    logit_real = discriminate(disc, z_real)
    logit_fake = discriminate(disc, z_fake)
    loss = jnp.mean(jax.nn.softplus(-logit_real)) \
        + jnp.mean(jax.nn.softplus(logit_fake))
    acc = 0.5 * (jnp.mean((logit_real > 0)) + jnp.mean((logit_fake < 0)))
    return loss, {"disc_loss": loss, "disc_acc": acc}


def validation_metric(params: Params, cfg: CycleGANConfig,
                      batch: Dict[str, jax.Array]) -> jax.Array:
    """Tournament / validation metric (lower = better): forward + inverse
    loss on held-out data — the paper's generalization measure."""
    gen = params["gen"]
    x, y = batch["x"], batch["y"]
    z = forward_model(gen, x)
    return _mae(decode(gen, z), y) + _mae(inverse_model(gen, z), x)


def discriminator_metric(params: Params, cfg: CycleGANConfig,
                         batch: Dict[str, jax.Array]) -> jax.Array:
    """GAN-LTFB tournament metric: how well a (possibly foreign) generator
    fools the LOCAL discriminator on tournament data (lower = better,
    i.e. mean softplus(-D(F(x)))) — paper Fig. 6(b)."""
    logit = discriminate(params["disc"],
                         forward_model(params["gen"], batch["x"]))
    return jnp.mean(jax.nn.softplus(-logit))
