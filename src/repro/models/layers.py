"""Core transformer building blocks (pure-functional JAX).

Every ``init_*`` function returns ``(params, axes)`` where ``axes`` is a
pytree of logical-axis tuples parallel to ``params`` (consumed by
``repro.parallel.sharding.tree_shardings`` for FSDP/TP/EP placement).

All forward functions are shape-polymorphic over batch/seq and annotate
activations with ``constrain`` so GSPMD propagates DP/TP/SP shardings.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import constrain

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


class KeyGen:
    """Sequential PRNG key dispenser."""

    def __init__(self, key: jax.Array):
        self._key = key

    def __call__(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub


def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    # GPT-style small init: keeps tied-head logits O(1) at initialization
    return (jax.random.normal(key, (vocab, d), jnp.float32)
            * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype) -> Tuple[Params, Params]:
    return {"scale": jnp.ones((d,), dtype)}, {"scale": (None,)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                     # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: Tuple[int, int, int]) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, D); positions: (3, B, S) — (temporal, height, width)
    components.  ``sections`` partitions the D/2 rotary frequencies among
    the three components.
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                               # (D/2,)
    # section id per frequency
    sec = jnp.concatenate([
        jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)])
    assert sec.shape[0] == d // 2, (sections, d)
    pos = positions.astype(jnp.float32)                        # (3,B,S)
    pos_per_freq = jnp.take(pos, sec, axis=0)                  # (D/2,B,S)
    angles = jnp.transpose(pos_per_freq, (1, 2, 0)) * freqs    # (B,S,D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def default_positions(batch: int, seq: int, offset=0) -> jax.Array:
    return jnp.arange(seq, dtype=jnp.int32)[None, :] + offset \
        + jnp.zeros((batch, 1), jnp.int32)


# ---------------------------------------------------------------------------
# Attention (GQA + RoPE/M-RoPE + qk-norm + optional bias + KV cache)
# ---------------------------------------------------------------------------


def init_attention(keys: KeyGen, cfg: ModelConfig) -> Tuple[Params, Params]:
    d, dt = cfg.d_model, jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    p: Params = {
        "wq": dense_init(keys(), d, cfg.q_dim, dt),
        "wk": dense_init(keys(), d, cfg.kv_dim, dt),
        "wv": dense_init(keys(), d, cfg.kv_dim, dt),
        "wo": dense_init(keys(), cfg.q_dim, d, dt),
    }
    a: Params = {
        "wq": ("embed", "heads_w"),
        "wk": ("embed", "heads_w"),
        "wv": ("embed", "heads_w"),
        "wo": ("heads_w", "embed"),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dt)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dt)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dt)
        a["bq"] = ("heads_w",)
        a["bk"] = ("heads_w",)
        a["bv"] = ("heads_w",)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
        a["q_norm"] = (None,)
        a["k_norm"] = (None,)
    return p, a


def _project_qkv(params: Params, cfg: ModelConfig, x: jax.Array,
                 positions: jax.Array):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, S, cfg.num_kv_heads, hd)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm({"scale": params["q_norm"]}, q, cfg.norm_eps)
        k = rmsnorm({"scale": params["k_norm"]}, k, cfg.norm_eps)
    if cfg.use_mrope:
        sec = cfg.frontend.mrope_sections
        q = apply_mrope(q, positions, cfg.rope_theta, sec)
        k = apply_mrope(k, positions, cfg.rope_theta, sec)
    else:
        if positions.ndim == 3:       # (3,B,S) given but plain rope
            positions = positions[0]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def dense_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True) -> jax.Array:
    """Reference grouped-query attention. q: (B,S,H,D), k/v: (B,S,Hkv,D).
    Materializes the (S, S) score matrix — short sequences only."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    q = q.reshape(B, S, Hkv, g, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), jnp.bool_))
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(v.dtype), v)
    return out.reshape(B, S, H, D)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool = True, k_chunk: int = 1024) -> jax.Array:
    """Flash-style attention: online softmax over KV chunks via lax.scan.

    The pure-JAX twin of ``kernels/flash_attention.py`` — never
    materializes the (S, S) score matrix in HBM (peak extra memory is one
    (B, S, H, k_chunk) block), which is what makes prefill_32k lowerable
    and is the memory-roofline optimization the Pallas kernel performs in
    VMEM on real TPUs.
    """
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    Sk = k.shape[1]
    C = min(k_chunk, Sk)
    while Sk % C:
        C -= 1
    n_chunks = Sk // C
    scale = 1.0 / math.sqrt(D)
    qg = (q * scale).reshape(B, S, Hkv, g, D).astype(jnp.float32)
    kc = k.reshape(B, n_chunks, C, Hkv, D).swapaxes(0, 1)
    vc = v.reshape(B, n_chunks, C, Hkv, D).swapaxes(0, 1)
    q_pos = jnp.arange(S, dtype=jnp.int32)

    def step(carry, inp):
        acc, m, l = carry                     # (B,S,Hkv,g,D), (B,S,Hkv,g)x2
        kb, vb, ci = inp                      # (B,C,Hkv,D), (B,C,Hkv,D), ()
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, kb.astype(jnp.float32))
        if causal:
            kv_pos = ci * C + jnp.arange(C, dtype=jnp.int32)
            mask = q_pos[:, None] >= kv_pos[None, :]     # (S, C)
            s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p, vb.astype(jnp.float32))
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, S, Hkv, g, D), jnp.float32)
    m0 = jnp.full((B, S, Hkv, g), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, S, Hkv, g), jnp.float32)
    with jax.named_scope("flash_attention"):
        (acc, m, l), _ = jax.lax.scan(
            step, (acc0, m0, l0),
            (kc, vc, jnp.arange(n_chunks, dtype=jnp.int32)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, S, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Flash attention with custom VJP (training-memory-correct)
#
# Differentiating through the online-softmax scan would make scan-carry
# residuals O(S * n_chunks); instead we save only (q, k, v, out, lse) and
# run the textbook flash-attention backward as a second chunked scan —
# exactly what the Pallas kernel does on TPU (kernels/flash_attention.py
# is the forward; its backward twin shares this structure).
# ---------------------------------------------------------------------------


def _flash_fwd_scan(q, k, v, causal: bool, k_chunk: int):
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    Sk = k.shape[1]
    C = min(k_chunk, Sk)
    while Sk % C:
        C -= 1
    n_chunks = Sk // C
    scale = 1.0 / math.sqrt(D)
    qg = (q.astype(jnp.float32) * scale).reshape(B, S, Hkv, g, D)
    kc = k.reshape(B, n_chunks, C, Hkv, D).swapaxes(0, 1)
    vc = v.reshape(B, n_chunks, C, Hkv, D).swapaxes(0, 1)
    q_pos = jnp.arange(S, dtype=jnp.int32)

    def step(carry, inp):
        acc, m, l = carry
        kb, vb, ci = inp
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, kb.astype(jnp.float32))
        if causal:
            kv_pos = ci * C + jnp.arange(C, dtype=jnp.int32)
            mask = q_pos[:, None] >= kv_pos[None, :]
            s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_safe[..., None]), 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p, vb.astype(jnp.float32))
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, S, Hkv, g, D), jnp.float32)
    m0 = jnp.full((B, S, Hkv, g), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, S, Hkv, g), jnp.float32)
    with jax.named_scope("flash_attention"):
        (acc, m, l), _ = jax.lax.scan(
            step, (acc0, m0, l0),
            (kc, vc, jnp.arange(n_chunks, dtype=jnp.int32)))
        l = jnp.maximum(l, 1e-30)
        out = (acc / l[..., None]).reshape(B, S, H, D).astype(q.dtype)
    lse = jnp.where(jnp.isfinite(m), m + jnp.log(l), -jnp.inf)  # (B,S,h,g)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention_jax(q, k, v, causal: bool = True, k_chunk: int = 1024):
    out, _ = _flash_fwd_scan(q, k, v, causal, k_chunk)
    return out


def _flash_vjp_fwd(q, k, v, causal, k_chunk):
    out, lse = _flash_fwd_scan(q, k, v, causal, k_chunk)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(causal, k_chunk, res, dout):
    q, k, v, out, lse = res
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    Sk = k.shape[1]
    C = min(k_chunk, Sk)
    while Sk % C:
        C -= 1
    n_chunks = Sk // C
    scale = 1.0 / math.sqrt(D)
    qg = q.astype(jnp.float32).reshape(B, S, Hkv, g, D)
    og = out.astype(jnp.float32).reshape(B, S, Hkv, g, D)
    dog = dout.astype(jnp.float32).reshape(B, S, Hkv, g, D)
    delta = jnp.sum(og * dog, axis=-1)                    # (B,S,h,g)
    kc = k.reshape(B, n_chunks, C, Hkv, D).swapaxes(0, 1)
    vc = v.reshape(B, n_chunks, C, Hkv, D).swapaxes(0, 1)
    q_pos = jnp.arange(S, dtype=jnp.int32)

    def step(dq, inp):
        kb, vb, ci = inp
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qg * scale,
                       kb.astype(jnp.float32))
        if causal:
            kv_pos = ci * C + jnp.arange(C, dtype=jnp.int32)
            mask = q_pos[:, None] >= kv_pos[None, :]
            s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
        lse_safe = jnp.where(jnp.isfinite(lse), lse, 0.0)
        p = jnp.where(jnp.isfinite(s),
                      jnp.exp(s - lse_safe[..., None]), 0.0)  # (B,S,h,g,C)
        dv_c = jnp.einsum("bqhgk,bqhgd->bkhd", p, dog)
        dp = jnp.einsum("bqhgd,bkhd->bqhgk", dog, vb.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bqhgk,bkhd->bqhgd", ds,
                             kb.astype(jnp.float32))
        dk_c = jnp.einsum("bqhgk,bqhgd->bkhd", ds, qg)
        return dq, (dk_c, dv_c)

    dq0 = jnp.zeros((B, S, Hkv, g, D), jnp.float32)
    with jax.named_scope("flash_attention_bwd"):
        dq, (dk_c, dv_c) = jax.lax.scan(
            step, dq0, (kc, vc, jnp.arange(n_chunks, dtype=jnp.int32)))
    dk = dk_c.swapaxes(0, 1).reshape(B, Sk, Hkv, D).astype(k.dtype)
    dv = dv_c.swapaxes(0, 1).reshape(B, Sk, Hkv, D).astype(v.dtype)
    return (dq.reshape(B, S, H, D).astype(q.dtype), dk, dv)


flash_attention_jax.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def causal_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     causal: bool = True, impl: str = "auto",
                     k_chunk: int = 1024) -> jax.Array:
    if impl == "dense" or (impl == "auto" and q.shape[1] < 4096):
        return dense_attention(q, k, v, causal)
    return flash_attention_jax(q, k, v, causal, k_chunk)


def attention_block(params: Params, cfg: ModelConfig, x: jax.Array,
                    positions: jax.Array) -> jax.Array:
    """Full-sequence causal attention (training / prefill)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, positions)
    out = causal_attention(q, k, v, impl=cfg.attn_impl,
                           k_chunk=cfg.attn_chunk)
    out = constrain(out, "batch", "seq", "heads", "head_dim")
    out = out.reshape(B, S, cfg.q_dim) @ params["wo"]
    return constrain(out, "batch", "seq", "act_embed")


def attention_prefill(params: Params, cfg: ModelConfig, x: jax.Array,
                      positions: jax.Array):
    """Like attention_block but also returns the (K, V) cache."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, positions)
    out = causal_attention(q, k, v, impl=cfg.attn_impl,
                           k_chunk=cfg.attn_chunk)
    out = out.reshape(B, S, cfg.q_dim) @ params["wo"]
    cache = {
        "k": constrain(k, "batch", "kv_seq", "kv_heads", "head_dim"),
        "v": constrain(v, "batch", "kv_seq", "kv_heads", "head_dim"),
    }
    return constrain(out, "batch", "seq", "act_embed"), cache


def attention_decode(params: Params, cfg: ModelConfig, x: jax.Array,
                     cache: Params, index: jax.Array,
                     positions: jax.Array):
    """K-token decode/verify with a KV cache of static length S_max.

    x: (B, K, d) — K >= 1 consecutive tokens per row (K = 1 is the
    classic decode step; K > 1 is the speculative-verify write/read);
    cache['k'/'v']: (B, S_max, Hkv, D); index: scalar int32 write
    position of the FIRST token (= current KV length), or an int32 (B,)
    vector of per-row write positions (continuous batching: each cache
    row belongs to a different request at a different length).  Token t
    of row b is written at ``index[b] + t`` and attends causally over
    positions ``<= index[b] + t``.  Writes past S_max are dropped —
    they can only be speculative padding the caller rolls back.
    Returns (out, new_cache).
    """
    B, K, _ = x.shape
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)
    index = jnp.asarray(index, jnp.int32)
    if index.ndim == 0 and K == 1:
        k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_new.astype(cache["k"].dtype), index, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_new.astype(cache["v"].dtype), index, axis=1)
    else:
        # per-(row, token) write: scatter K (Hkv, D) rows per batch
        # element — O(B*K*Hkv*D) traffic, independent of max_len
        idx_col = index[:, None] if index.ndim else \
            jnp.full((B, 1), index, jnp.int32)
        wpos = idx_col + jnp.arange(K, dtype=jnp.int32)[None, :]  # (B,K)
        rows = jnp.broadcast_to(
            jnp.arange(B, dtype=jnp.int32)[:, None], (B, K))
        k = cache["k"].at[rows, wpos].set(
            k_new.astype(cache["k"].dtype), mode="drop")
        v = cache["v"].at[rows, wpos].set(
            v_new.astype(cache["v"].dtype), mode="drop")
    k = constrain(k, "batch", "kv_seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "kv_seq", "kv_heads", "head_dim")
    S_max = k.shape[1]
    H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    g = H // Hkv
    qh = q.reshape(B, K, Hkv, g, D)
    scores = jnp.einsum("bthgd,bkhd->bthgk", qh, k,
                        preferred_element_type=jnp.float32) / math.sqrt(D)
    pos = jnp.arange(S_max, dtype=jnp.int32)
    reach = (index if index.ndim else jnp.full((B,), index, jnp.int32))[
        :, None] + jnp.arange(K, dtype=jnp.int32)[None, :]      # (B, K)
    valid = pos[None, None, :] <= reach[..., None]              # (B,K,S)
    scores = jnp.where(valid[:, :, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bthgk,bkhd->bthgd", w.astype(v.dtype), v)
    out = out.reshape(B, K, cfg.q_dim) @ params["wo"]
    return constrain(out, "batch", "seq", "act_embed"), {"k": k, "v": v}


# ---------------------------------------------------------------------------
# Paged attention (serving): KV lives in a shared page pool, requests
# address it through per-row block tables.  The gather-decode compute is
# kernels/paged_attention.py on TPU and kernels/ref.paged_attention_ref
# (the jnp twin) everywhere else.
# ---------------------------------------------------------------------------


def _paged_attention_dispatch(q, k_pages, v_pages, tables, lengths):
    # single dispatch site: ops.paged_attention picks the compiled
    # Pallas kernel on TPU and the jnp oracle everywhere else
    from repro.kernels import ops
    return ops.paged_attention(q, k_pages, v_pages, tables, lengths)


def init_paged_attention_cache(cfg: ModelConfig, num_pages: int,
                               block_size: int, dtype=None):
    """One layer's paged KV pool: (num_pages + 1, block_size, Hkv, D).

    The extra page (index ``num_pages``) is the NULL page: inactive
    batch rows and dropped (padded) prefill positions write there, so a
    row that owns no pages can never corrupt another request's cache.
    """
    dt = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    shape = (num_pages + 1, block_size, cfg.num_kv_heads, hd)
    cache = {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
    axes = {"k": ("pages", None, "kv_heads", "head_dim"),
            "v": ("pages", None, "kv_heads", "head_dim")}
    return cache, axes


def _scatter_pages(pages: jax.Array, vals: jax.Array, page_ids: jax.Array,
                   offsets: jax.Array) -> jax.Array:
    """Write vals[n] -> pages[page_ids[n], offsets[n]] (rows of (Hkv, D))."""
    return pages.at[page_ids, offsets].set(vals.astype(pages.dtype))


def attention_decode_paged(params: Params, cfg: ModelConfig, x: jax.Array,
                           cache: Params, index: jax.Array,
                           positions: jax.Array, tables: jax.Array,
                           valid: Optional[jax.Array] = None):
    """K-token decode/verify against the paged pool.

    x: (B, K, d) — K >= 1 consecutive tokens per row; cache['k'/'v']:
    (P+1, bs, Hkv, D) shared pools; index: int32 (B,) per-row write
    position of the FIRST token, with -1 marking inactive rows (their
    KV is routed to the null page and their output is garbage the
    caller discards); tables: (B, W) int32 physical page ids; valid:
    optional int32 (B,) count of real tokens per row — tokens t >=
    valid[b] (speculative padding / replay no-ops) scatter to the null
    page so they can never corrupt a page the row does not own yet.
    Token t writes at ``index[b] + t`` and attends causally over
    positions ``<= index[b] + t``.  Returns (out, new_cache).
    """
    B, K, _ = x.shape
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)
    bs = cache["k"].shape[1]
    null_page = cache["k"].shape[0] - 1
    W = tables.shape[1]
    index = jnp.asarray(index, jnp.int32)
    active = (index >= 0)[:, None]                        # (B, 1)
    if valid is not None:
        active = active & (jnp.arange(K, dtype=jnp.int32)[None, :]
                           < jnp.asarray(valid, jnp.int32)[:, None])
    widx = jnp.maximum(index, 0)
    wpos = widx[:, None] + jnp.arange(K, dtype=jnp.int32)[None, :]  # (B,K)
    page = jnp.take_along_axis(tables, jnp.minimum(wpos // bs, W - 1),
                               axis=1)
    page = jnp.where(active, page, null_page)
    off = wpos % bs
    k = _scatter_pages(cache["k"], k_new.reshape(B * K, *k_new.shape[2:]),
                       page.reshape(-1), off.reshape(-1))
    v = _scatter_pages(cache["v"], v_new.reshape(B * K, *v_new.shape[2:]),
                       page.reshape(-1), off.reshape(-1))
    lengths = widx + 1                       # KV tokens seen by query 0
    out = _paged_attention_dispatch(q, k, v, tables, lengths)
    out = out.reshape(B, K, cfg.q_dim) @ params["wo"]
    return constrain(out, "batch", "seq", "act_embed"), {"k": k, "v": v}


def attention_chunk_paged(params: Params, cfg: ModelConfig, x: jax.Array,
                          cache: Params, tables: jax.Array,
                          hist_len: jax.Array, prompt_len: jax.Array,
                          positions: jax.Array):
    """One chunked-prefill step for a single request over the paged pool.

    x: (1, C, d) — the prompt slice [hist_len, hist_len + C) (the tail
    chunk may be right-padded past ``prompt_len``; padded positions
    scatter to the null page and are causally invisible to real
    queries); cache: shared (P+1, bs, Hkv, D) pools; tables: (1, W)
    this request's block-table row; hist_len/prompt_len: int32 scalars.
    Chunk KV is scattered into the pool first, then the chunk queries
    attend over the gathered pages — which covers both the already-
    prefilled history (including prefix-shared pages) and the chunk
    itself under one causal mask.  Returns (out, new_cache).
    """
    B, C, _ = x.shape
    assert B == 1
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)
    bs = cache["k"].shape[1]
    null_page = cache["k"].shape[0] - 1
    W = tables.shape[1]
    abs_pos = jnp.asarray(hist_len, jnp.int32) \
        + jnp.arange(C, dtype=jnp.int32)
    valid = abs_pos < prompt_len
    page = jnp.take(tables[0], jnp.minimum(abs_pos // bs, W - 1))
    page = jnp.where(valid, page, null_page)
    off = abs_pos % bs
    k = _scatter_pages(cache["k"], k_new[0], page, off)
    v = _scatter_pages(cache["v"], v_new[0], page, off)

    Hkv, D = cfg.num_kv_heads, cfg.resolved_head_dim
    g = cfg.num_heads // Hkv
    kg = k[tables[0]].reshape(W * bs, Hkv, D).astype(jnp.float32)
    vg = v[tables[0]].reshape(W * bs, Hkv, D).astype(jnp.float32)
    qg = q[0].reshape(C, Hkv, g, D).astype(jnp.float32)
    s = jnp.einsum("qhgd,khd->hgqk", qg, kg) / math.sqrt(D)
    kv_pos = jnp.arange(W * bs, dtype=jnp.int32)
    mask = kv_pos[None, :] <= abs_pos[:, None]            # causal, absolute
    s = jnp.where(mask[None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("hgqk,khd->qhgd", w, vg).astype(x.dtype)
    out = out.reshape(1, C, cfg.q_dim) @ params["wo"]
    return constrain(out, "batch", "seq", "act_embed"), {"k": k, "v": v}


def decode_scan(step_fn, x: jax.Array, state,
                valid: Optional[jax.Array] = None):
    """Drive a single-token recurrent decode step over K tokens.

    ``step_fn(x_t (B, 1, d), state) -> (out (B, 1, d), new_state)`` is
    any recurrent mixer's decode step (mamba / mLSTM / sLSTM); x is
    (B, K, d).  With ``valid`` (int32 (B,)), rows stop updating their
    state after ``valid[b]`` tokens — the masking that makes K-token
    speculative steps and rollback replays safe for recurrent state
    (tokens past ``valid`` still produce (garbage) outputs but leave
    the carried state untouched).  Returns (out (B, K, d), new_state).
    """
    B, K, _ = x.shape
    if K == 1 and valid is None:
        return step_fn(x, state)
    keep = jnp.ones((K, B), bool) if valid is None else \
        (jnp.arange(K, dtype=jnp.int32)[:, None]
         < jnp.asarray(valid, jnp.int32)[None, :])

    def step(st, inp):
        xt, keep_t = inp                                 # (B, d), (B,)
        out, st_new = step_fn(xt[:, None], st)
        st2 = jax.tree.map(
            lambda n, o: jnp.where(
                keep_t.reshape((B,) + (1,) * (n.ndim - 1)), n, o),
            st_new, st)
        return st2, out[:, 0]

    st, ys = jax.lax.scan(step, state, (x.swapaxes(0, 1), keep))
    return ys.swapaxes(0, 1), st


def init_attention_cache(cfg: ModelConfig, batch: int, max_len: int,
                         dtype=None):
    dt = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    shape = (batch, max_len, cfg.num_kv_heads, hd)
    cache = {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
    axes = {"k": ("batch", "kv_seq", "kv_heads", "head_dim"),
            "v": ("batch", "kv_seq", "kv_heads", "head_dim")}
    return cache, axes


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(keys: KeyGen, cfg: ModelConfig, d_ff: Optional[int] = None
             ) -> Tuple[Params, Params]:
    d, dt = cfg.d_model, jnp.dtype(cfg.dtype)
    f = d_ff or cfg.d_ff
    p = {
        "wi": dense_init(keys(), d, f, dt),
        "wg": dense_init(keys(), d, f, dt),
        "wo": dense_init(keys(), f, d, dt),
    }
    a = {"wi": ("embed", "mlp"), "wg": ("embed", "mlp"),
         "wo": ("mlp", "embed")}
    return p, a


def mlp_block(params: Params, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ params["wg"]) * (x @ params["wi"])
    h = constrain(h, "batch", "seq", "mlp_act")
    return constrain(h @ params["wo"], "batch", "seq", "act_embed")


# ---------------------------------------------------------------------------
# Mixture of Experts (shared + routed, fine-grained, capacity-based)
# ---------------------------------------------------------------------------


def init_moe(keys: KeyGen, cfg: ModelConfig) -> Tuple[Params, Params]:
    m = cfg.moe
    d, dt = cfg.d_model, jnp.dtype(cfg.dtype)
    d_e = m.d_expert or cfg.d_ff
    E = m.num_experts

    def expert_stack(d_in, d_out):
        ks = keys()
        flat = jax.random.normal(ks, (E, d_in, d_out), jnp.float32)
        return (flat / math.sqrt(d_in)).astype(dt)

    p: Params = {
        "router": dense_init(keys(), d, E, jnp.dtype("float32")),
        "wi": expert_stack(d, d_e),
        "wg": expert_stack(d, d_e),
        "wo": expert_stack(d_e, d),
    }
    a: Params = {
        "router": ("embed", None),
        "wi": ("experts", "embed", "mlp"),
        "wg": ("experts", "embed", "mlp"),
        "wo": ("experts", "mlp", "embed"),
    }
    if m.num_shared_experts:
        sp, sa = init_mlp(keys, cfg, d_ff=d_e * m.num_shared_experts)
        p["shared"] = sp
        a["shared"] = sa
    return p, a


import os as _os
# GShard-style dispatch group size (dispatch tensor volume scales
# linearly with this; perf knob — see EXPERIMENTS.md §Perf)
MOE_GROUP_TOKENS = int(_os.environ.get("REPRO_MOE_GROUP", "1024"))


def moe_block(params: Params, cfg: ModelConfig, x: jax.Array,
              dropless: bool = False
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Capacity-based top-k MoE with GShard group dispatch.

    Tokens are split into groups of ~MOE_GROUP_TOKENS; routing positions
    and the dispatch/combine one-hot tensors are built per group, keeping
    the dispatch cost O(T * k * C_group) instead of O(T^2).  Under EP
    sharding (experts -> "model", groups -> "batch") the (g,e) einsums
    lower to all-to-alls — the MoE communication pattern of the roofline.

    ``dropless=True`` (decode/eval) sizes the buffers so no token is ever
    dropped, making prefill/decode bit-consistent with full forward.
    Returns (out, aux) with load-balancing and z losses.
    """
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.num_experts, m.top_k
    T = B * S
    # pick a group size dividing T
    Tg = min(T, MOE_GROUP_TOKENS)
    while T % Tg:
        Tg -= 1
    G = T // Tg
    xt = x.reshape(G, Tg, d)

    # bf16 inputs, f32 accumulation: avoids materializing + gathering a
    # full f32 copy of the activations just for routing
    logits = jnp.einsum("gtd,de->gte", xt,
                        params["router"].astype(xt.dtype),
                        preferred_element_type=jnp.float32)    # (G,Tg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # (G,Tg,k)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    if dropless:
        capacity = Tg   # per-expert worst case (choices per token distinct)
    else:
        capacity = max(1, int(m.capacity_factor * Tg * k / E))
    # position of each (token, choice) within its expert's buffer (per group)
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)      # (G,Tg,k,E)
    flat = onehot.reshape(G, Tg * k, E)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(G, Tg, k, E)
    pos = jnp.sum(pos_in_expert * onehot, axis=-1)             # (G,Tg,k)
    keep = pos < capacity
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    cdt = jnp.dtype(cfg.dtype)
    if m.dispatch == "scatter":
        # beyond-paper dispatch: scatter tokens straight into the expert
        # buffers and gather them back — O(T*k*d) traffic, zero dispatch
        # matmul flops (vs O(T*E*C) one-hot tensors + 2*T*d*E*C flops)
        gi = jnp.broadcast_to(
            jnp.arange(G, dtype=jnp.int32)[:, None, None], (G, Tg, k))
        pos_c = jnp.where(keep, pos, capacity)         # C = drop slot
        vals = jnp.broadcast_to(xt.astype(cdt)[:, :, None, :],
                                (G, Tg, k, d))
        expert_in = jnp.zeros((G, E, capacity + 1, d), cdt) \
            .at[gi, gate_idx, pos_c].add(vals)[:, :, :capacity]
        expert_in = constrain(expert_in, "batch", "experts_act", None,
                              "act_embed")
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in,
                                   params["wg"])) \
            * jnp.einsum("gecd,edf->gecf", expert_in, params["wi"])
        h = constrain(h, "batch", "experts_act", None, "mlp_act")
        expert_out = jnp.einsum("gecf,efd->gecd", h, params["wo"])
        expert_out = constrain(expert_out, "batch", "experts_act", None,
                               "act_embed")
        pad = jnp.zeros((G, E, 1, d), cdt)
        picked = jnp.concatenate([expert_out, pad], axis=2)[
            gi, gate_idx, pos_c]                        # (G,Tg,k,d)
        out = jnp.sum(picked * gate_vals.astype(cdt)[..., None], axis=2)
    else:
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, capacity), capacity,
                                dtype=cdt)              # (G,Tg,k,C)
        disp = jnp.einsum("gtke,gtkc->gtec", onehot.astype(cdt), pos_oh)
        expert_in = jnp.einsum("gtd,gtec->gecd", xt.astype(cdt), disp)
        expert_in = constrain(expert_in, "batch", "experts_act", None,
                              "act_embed")

        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in,
                                   params["wg"])) \
            * jnp.einsum("gecd,edf->gecf", expert_in, params["wi"])
        h = constrain(h, "batch", "experts_act", None, "mlp_act")
        expert_out = jnp.einsum("gecf,efd->gecd", h,
                                params["wo"])           # (G,E,C,d)
        expert_out = constrain(expert_out, "batch", "experts_act", None,
                               "act_embed")

        comb = jnp.einsum("gtke,gtkc,gtk->gtec", onehot.astype(cdt),
                          pos_oh, gate_vals.astype(cdt))
        out = jnp.einsum("gecd,gtec->gtd", expert_out, comb)

    if m.num_shared_experts:
        out = out + mlp_block(params["shared"], xt)

    # aux losses (Switch-style load balance + z-loss)
    density = jnp.mean(
        jnp.max(jax.nn.one_hot(gate_idx, E, dtype=jnp.float32), axis=2),
        axis=(0, 1))
    density_proxy = jnp.mean(probs, axis=(0, 1))
    aux = {
        "moe_load_balance": jnp.sum(density * density_proxy) * E,
        "moe_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
    }
    return out.reshape(B, S, d), aux
