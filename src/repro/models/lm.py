"""Decoder-LM composition: embeds -> scanned block stack -> logits.

Handles every assigned architecture family through `ModelConfig`:
  dense / moe   — attention + (SwiGLU | MoE) blocks
  hybrid        — jamba-style mamba/attention interleave (+ periodic MoE)
  ssm           — xLSTM mLSTM/sLSTM stacks (no FFN)
  vlm / audio   — same backbone; modality frontends are stubs that feed
                  precomputed embeddings (`batch["embeds"]`) / token ids.

The layer stack is grouped into a repeating *period* (lcm of the block
pattern and the MoE period); parameters are stacked over periods and the
stack is driven by ``jax.lax.scan`` — this keeps HLO size and compile time
independent of depth, and gives the FSDP all-gather/compute overlap
pattern on the period boundary.

Three entry points mirror the dry-run shapes:
  ``lm_loss``      (train_*)    — next-token CE + MoE aux losses
  ``lm_prefill``   (prefill_*)  — forward + cache construction (full
                   prompt, or one chunked slice over a paged pool when
                   ``tables`` is given)
  ``lm_decode``    (decode_*/long_*) — K >= 1 tokens per row with a
                   carried cache (dense slot rows or paged block
                   tables); K > 1 is the speculative-verify step

The serving layer drives these exclusively through
:class:`repro.serve.session.DecodeSession`, which pairs them with a
``CacheLayout`` (slot rows or paged pool) and owns the jit boundaries.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import xlstm as X
from repro.parallel.sharding import constrain

Params = Dict[str, Any]


class LayerSpec(NamedTuple):
    kind: str      # 'a' attention | 'M' mamba | 'm' mLSTM | 's' sLSTM
    ffn: str       # 'dense' | 'moe' | 'none'
    d_ff: int


def layer_specs(cfg: ModelConfig) -> Tuple[LayerSpec, ...]:
    specs = []
    if cfg.family == "ssm" and cfg.xlstm is not None:
        pat = cfg.xlstm.pattern
        return tuple(LayerSpec(pat[i % len(pat)], "none", 0)
                     for i in range(cfg.num_layers))
    pat = cfg.block_pattern
    for i in range(cfg.num_layers):
        kind = pat[i % len(pat)]
        if cfg.moe is not None and cfg.is_moe_layer(i):
            ffn = "moe"
            d_ff = 0
        elif cfg.moe is not None and i < cfg.moe.first_k_dense:
            ffn, d_ff = "dense", (cfg.moe.dense_d_ff or cfg.d_ff)
        elif cfg.d_ff > 0:
            ffn, d_ff = "dense", cfg.d_ff
        else:
            ffn, d_ff = "none", 0
        specs.append(LayerSpec(kind, ffn, d_ff))
    return tuple(specs)


def _grouping(cfg: ModelConfig) -> Tuple[int, int, int]:
    """Return (k0 prefix layers, period length R, num periods P)."""
    specs = layer_specs(cfg)
    k0 = cfg.moe.first_k_dense if cfg.moe is not None else 0
    body = len(specs) - k0
    pat_len = len(cfg.xlstm.pattern) if (cfg.family == "ssm" and cfg.xlstm) \
        else len(cfg.block_pattern)
    moe_p = cfg.moe.moe_period if (cfg.moe and cfg.moe.moe_period > 1) else 1
    R = math.lcm(pat_len, moe_p)
    assert body % R == 0, (cfg.name, body, R)
    # periods must be homogeneous
    for j in range(R):
        kinds = {specs[k0 + p * R + j] for p in range(body // R)}
        assert len(kinds) == 1, (cfg.name, j, kinds)
    return k0, R, body // R


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(keys: L.KeyGen, cfg: ModelConfig, spec: LayerSpec):
    d, dt = cfg.d_model, jnp.dtype(cfg.dtype)
    p: Params = {}
    a: Params = {}
    p["ln1"], a["ln1"] = L.init_rmsnorm(d, dt)
    if spec.kind == "a":
        p["mixer"], a["mixer"] = L.init_attention(keys, cfg)
    elif spec.kind == "M":
        p["mixer"], a["mixer"] = S.init_mamba(keys, cfg)
    elif spec.kind == "m":
        p["mixer"], a["mixer"] = X.init_mlstm(keys, cfg)
    elif spec.kind == "s":
        p["mixer"], a["mixer"] = X.init_slstm(keys, cfg)
    else:
        raise ValueError(spec.kind)
    if spec.ffn == "dense":
        p["ln2"], a["ln2"] = L.init_rmsnorm(d, dt)
        p["ffn"], a["ffn"] = L.init_mlp(keys, cfg, d_ff=spec.d_ff)
    elif spec.ffn == "moe":
        p["ln2"], a["ln2"] = L.init_rmsnorm(d, dt)
        p["ffn"], a["ffn"] = L.init_moe(keys, cfg)
    return p, a


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _push_axis(axes_tree, name):
    return jax.tree.map(
        lambda t: (name,) + t,
        axes_tree,
        is_leaf=lambda t: isinstance(t, tuple)
        and all(x is None or isinstance(x, str) for x in t))


def init_lm(cfg: ModelConfig, key: jax.Array) -> Tuple[Params, Params]:
    keys = L.KeyGen(key)
    dt = jnp.dtype(cfg.dtype)
    specs = layer_specs(cfg)
    k0, R, P = _grouping(cfg)

    p: Params = {"embed": L.embed_init(keys(), cfg.vocab_size, cfg.d_model, dt)}
    a: Params = {"embed": ("vocab", "embed")}

    if k0:
        pref = [_init_block(keys, cfg, specs[i]) for i in range(k0)]
        p["prefix"] = _stack([x[0] for x in pref])
        a["prefix"] = _push_axis(pref[0][1], "layers")

    body_p, body_a = [], []
    for j in range(R):
        per = [_init_block(keys, cfg, specs[k0 + pi * R + j])
               for pi in range(P)]
        body_p.append(_stack([x[0] for x in per]))
        body_a.append(_push_axis(per[0][1], "period"))
    p["body"] = tuple(body_p)
    a["body"] = tuple(body_a)

    p["final_norm"], a["final_norm"] = L.init_rmsnorm(cfg.d_model, dt)
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(keys(), cfg.d_model, cfg.vocab_size, dt)
        a["lm_head"] = ("embed", "vocab")
    return p, a


# ---------------------------------------------------------------------------
# block apply (train / prefill / decode)
# ---------------------------------------------------------------------------


def _zero_aux():
    return {"moe_load_balance": jnp.zeros((), jnp.float32),
            "moe_z": jnp.zeros((), jnp.float32)}


def _apply_block(bp: Params, cfg: ModelConfig, spec: LayerSpec, x, positions,
                 mode: str, cache=None, index=None, tables=None,
                 hist_len=None, prompt_len=None, valid=None):
    """Returns (x, new_cache, aux).

    ``tables`` switches attention layers onto the paged-KV path:
    mode "decode" uses the gather-decode kernel over scattered pages and
    mode "chunk" runs one chunked-prefill slice (attention-only stacks).
    Recurrent mixers keep their per-slot state rows in both cases.
    Decode mode handles K >= 1 tokens per row; ``valid`` (int32 (B,))
    marks how many of the K are real per row — attention routes the
    rest to the null page (paged) and recurrent mixers freeze their
    state past it (the speculative verify / rollback-replay contract).
    """
    if mode == "chunk" and spec.kind != "a":
        raise ValueError(
            "chunked prefill requires an attention-only stack "
            f"(got mixer kind {spec.kind!r})")
    aux = _zero_aux()
    h = L.rmsnorm(bp["ln1"], x, cfg.norm_eps)
    multi = mode == "decode" and (h.shape[1] > 1 or valid is not None)
    new_cache = None
    if spec.kind == "a":
        if mode == "train":
            mix = L.attention_block(bp["mixer"], cfg, h, positions)
        elif mode == "prefill":
            mix, new_cache = L.attention_prefill(bp["mixer"], cfg, h,
                                                 positions)
        elif mode == "chunk":
            mix, new_cache = L.attention_chunk_paged(
                bp["mixer"], cfg, h, cache, tables, hist_len, prompt_len,
                positions)
        elif tables is not None:
            mix, new_cache = L.attention_decode_paged(
                bp["mixer"], cfg, h, cache, index, positions, tables,
                valid=valid)
        else:
            # dense rows: beyond-``valid`` writes land at future
            # positions the causal mask hides until they are
            # overwritten, so no routing is needed
            mix, new_cache = L.attention_decode(bp["mixer"], cfg, h, cache,
                                                index, positions)
    elif spec.kind == "M":
        if mode == "train":
            mix = S.mamba_block(bp["mixer"], cfg, h)
        elif mode == "prefill":
            mix, new_cache = S.mamba_prefill(bp["mixer"], cfg, h)
        elif multi:
            mix, new_cache = S.mamba_decode_multi(bp["mixer"], cfg, h,
                                                  cache, valid)
        else:
            mix, new_cache = S.mamba_decode(bp["mixer"], cfg, h, cache)
    elif spec.kind == "m":
        if multi:
            mix, new_cache = X.mlstm_decode_multi(bp["mixer"], cfg, h,
                                                  cache, valid)
        elif mode == "decode":
            mix, new_cache = X.mlstm_decode(bp["mixer"], cfg, h, cache)
        else:
            mix, new_cache = X.mlstm_block(bp["mixer"], cfg, h,
                                           return_state=True)
    elif spec.kind == "s":
        if multi:
            mix, new_cache = X.slstm_decode_multi(bp["mixer"], cfg, h,
                                                  cache, valid)
        elif mode == "decode":
            mix, new_cache = X.slstm_decode(bp["mixer"], cfg, h, cache)
        else:
            mix, new_cache = X.slstm_block(bp["mixer"], cfg, h,
                                           return_state=True)
    else:
        raise ValueError(spec.kind)
    x = x + mix
    x = constrain(x, "batch", "seq_sp", "act_embed")
    if spec.ffn != "none":
        h2 = L.rmsnorm(bp["ln2"], x, cfg.norm_eps)
        if spec.ffn == "moe":
            f, moe_aux = L.moe_block(bp["ffn"], cfg, h2,
                                     dropless=(mode != "train"))
            aux = {k: aux[k] + moe_aux.get(k, 0.0) for k in aux}
        else:
            f = L.mlp_block(bp["ffn"], h2)
        x = x + f
        x = constrain(x, "batch", "seq_sp", "act_embed")
    return x, new_cache, aux


def _period_specs(cfg: ModelConfig) -> Tuple[LayerSpec, ...]:
    specs = layer_specs(cfg)
    k0, R, _ = _grouping(cfg)
    return tuple(specs[k0:k0 + R])


def _remat_wrap(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    if remat == "dots_no_batch":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # 'full'


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def _embed_in(params, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    if "embeds" in batch:                 # vlm stub frontend
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
    return constrain(x, "batch", "seq_sp", "act_embed")


def _positions_of(batch, cfg: ModelConfig, B, S, index=None):
    if "positions" in batch:
        return batch["positions"]
    if index is None:
        return L.default_positions(B, S)
    return jnp.full((B, 1), index, jnp.int32)


def _logits(params, cfg: ModelConfig, x):
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["lm_head"]
    return constrain(logits, "batch", "seq", "vocab")


def lm_forward(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
               remat: str = "none"):
    """Full forward (training). Returns (logits, aux)."""
    x = _embed_in(params, cfg, batch)
    B, S, _ = x.shape
    positions = _positions_of(batch, cfg, B, S)
    pspecs = _period_specs(cfg)
    specs = layer_specs(cfg)

    def run_stack(x, stacked, spec_list):
        # remat granularity is ONE BLOCK (not the whole period): the
        # backward pass then holds a single block's recomputed
        # activations at a time — this is what keeps the 72-layer 398B
        # hybrid period under HBM.
        def apply_one(sp):
            def f(lp, xc):
                return _apply_block(lp, cfg, sp, xc, positions, "train")
            return _remat_wrap(f, remat)

        fns = [apply_one(sp) for sp in spec_list]

        def body(xc, layer_p):
            aux_tot = _zero_aux()
            if not isinstance(layer_p, tuple):
                layer_p = (layer_p,)
            for fn, lp in zip(fns, layer_p):
                xc, _, aux = fn(lp, xc)
                aux_tot = {k: aux_tot[k] + aux[k] for k in aux_tot}
            return xc, aux_tot

        x, auxs = jax.lax.scan(body, x, stacked)
        return x, jax.tree.map(jnp.sum, auxs)

    aux = _zero_aux()
    if "prefix" in params:
        # prefix layers are homogeneous by construction (first_k_dense)
        x, a1 = run_stack(x, params["prefix"], (specs[0],))
        aux = {k: aux[k] + a1[k] for k in aux}
    x, a2 = run_stack(x, tuple(params["body"]), pspecs)
    aux = {k: aux[k] + a2[k] for k in aux}
    return _logits(params, cfg, x), aux


def lm_loss(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            remat: str = "none"):
    """Next-token cross entropy + MoE aux. Returns (loss, metrics)."""
    logits, aux = lm_forward(params, cfg, batch, remat)
    labels = batch["labels"]
    lg = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    ce = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    loss = ce
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_weight * aux["moe_load_balance"] \
            + cfg.moe.router_z_weight * aux["moe_z"]
    metrics = {"ce": ce, **aux}
    return loss, metrics


# ---------------------------------------------------------------------------
# cache init / prefill / decode
# ---------------------------------------------------------------------------


def _init_block_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                      max_len: int, pages=None):
    if spec.kind == "a":
        if pages is not None:
            return L.init_paged_attention_cache(cfg, pages[0], pages[1])
        return L.init_attention_cache(cfg, batch, max_len)
    if spec.kind == "M":
        return S.init_mamba_state(cfg, batch)
    if spec.kind == "m":
        return X.init_mlstm_state(cfg, batch)
    if spec.kind == "s":
        return X.init_slstm_state(cfg, batch)
    raise ValueError(spec.kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int = 0,
               pages: Optional[Tuple[int, int]] = None):
    """Cache pytree mirroring the stacked param structure.

    With ``pages=None`` attention layers get dense ``(batch, max_len,
    Hkv, D)`` slot rows.  With ``pages=(num_pages, block_size)`` they
    instead get ONE shared ``(num_pages + 1, block_size, Hkv, D)`` pool
    each (page ``num_pages`` is the null page; see
    :func:`repro.models.layers.init_paged_attention_cache`) addressed
    through per-request block tables, so a request's KV can be
    scattered anywhere in the pool.  Recurrent layers (mamba / xLSTM)
    carry O(1) state per request and keep ``batch`` dense rows in both
    layouts.  Returns (cache, axes); axes leaves containing ``"pages"``
    / ``"kv_seq"`` identify attention KV, everything else is the
    recurrent state that snapshot/restore (speculative rollback)
    copies.
    """
    specs = layer_specs(cfg)
    k0, R, P = _grouping(cfg)
    cache: Params = {}
    axes: Params = {}

    def make(spec):
        return _init_block_cache(cfg, spec, batch, max_len, pages)

    if k0:
        per = [make(specs[i]) for i in range(k0)]
        cache["prefix"] = _stack([c for c, _ in per])
        axes["prefix"] = _push_axis(per[0][1], "layers")
    body_c, body_a = [], []
    for j in range(R):
        per = [make(specs[k0 + pi * R + j]) for pi in range(P)]
        body_c.append(_stack([c for c, _ in per]))
        body_a.append(_push_axis(per[0][1], "period"))
    cache["body"] = tuple(body_c)
    axes["body"] = tuple(body_a)
    return cache, axes


def _prefill_chunk(params: Params, cfg: ModelConfig, tokens: jax.Array,
                   cache: Params, tables: jax.Array, hist_len: jax.Array,
                   prompt_len: jax.Array, last_pos: jax.Array):
    """One chunked-prefill slice for a single request (paged pool).

    tokens: (1, C) — prompt positions [hist_len, hist_len + C), tail
    chunk right-padded past ``prompt_len``; tables: (1, W) the
    request's block-table row; hist_len / prompt_len: int32 scalars;
    last_pos: int32 (1,) position WITHIN the chunk to read logits from
    (only meaningful on the final chunk).  Attention-only stacks —
    recurrent mixers cannot resume mid-prompt from a page pool (see
    ROADMAP "recurrent-family prompt bucketing").  Returns
    (logits (1, 1, V), new_cache).
    """
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, "batch", "seq", "act_embed")
    B, C, _ = x.shape
    hist_len = jnp.asarray(hist_len, jnp.int32)
    pos = hist_len + jnp.arange(C, dtype=jnp.int32)[None, :]
    if cfg.use_mrope:
        positions = jnp.broadcast_to(pos[None], (3, B, C))
    else:
        positions = pos
    pspecs = _period_specs(cfg)
    specs = layer_specs(cfg)

    def run_stack(x, stacked, cache_stacked, spec_list):
        def body(xc, inp):
            layer_p, layer_c = inp
            if not isinstance(layer_p, tuple):
                layer_p = (layer_p,)
                layer_c = (layer_c,)
            new_caches = []
            for sp, lp, lc in zip(spec_list, layer_p, layer_c):
                xc, nc, _ = _apply_block(
                    lp, cfg, sp, xc, positions, "chunk", cache=lc,
                    tables=tables, hist_len=hist_len,
                    prompt_len=prompt_len)
                new_caches.append(nc)
            return xc, tuple(new_caches)

        return jax.lax.scan(body, x, (stacked, cache_stacked))

    new_cache: Params = {}
    if "prefix" in params:
        x, pc = run_stack(x, params["prefix"], cache["prefix"], (specs[0],))
        new_cache["prefix"] = pc[0]
    x, bc = run_stack(x, tuple(params["body"]), tuple(cache["body"]), pspecs)
    new_cache["body"] = bc
    idx = jnp.broadcast_to(
        jnp.asarray(last_pos, jnp.int32)[:, None, None],
        (x.shape[0], 1, x.shape[2]))
    sel = jnp.take_along_axis(x, idx, axis=1)
    logits = _logits(params, cfg, sel)
    return logits, new_cache


def lm_prefill(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
               remat: str = "none", last_pos: Optional[jax.Array] = None,
               cache: Optional[Params] = None,
               tables: Optional[jax.Array] = None,
               hist_len: Optional[jax.Array] = None,
               prompt_len: Optional[jax.Array] = None):
    """Process prompt tokens; returns (selected logits, cache).

    Two modes behind one entry point:

    * **full prefill** (``tables=None``, the default): forward the
      whole prompt and build a fresh dense cache.  ``last_pos`` (int32
      (B,), optional) selects the hidden state each row's logits are
      read from instead of position ``S - 1`` — the serving scheduler
      right-pads prompts to a shape bucket and reads logits at each
      request's true last token.
    * **chunked prefill** (``tables`` given): one slice of a single
      request scattered straight into the shared paged pool passed as
      ``cache`` — see :func:`_prefill_chunk` for the slice contract.
    """
    if tables is not None:
        return _prefill_chunk(params, cfg, batch["tokens"], cache, tables,
                              hist_len, prompt_len, last_pos)
    x = _embed_in(params, cfg, batch)
    B, S, _ = x.shape
    positions = _positions_of(batch, cfg, B, S)
    pspecs = _period_specs(cfg)
    specs = layer_specs(cfg)

    def run_stack(x, stacked, spec_list):
        def body(xc, layer_p):
            if not isinstance(layer_p, tuple):
                layer_p = (layer_p,)
            caches = []
            for sp, lp in zip(spec_list, layer_p):
                xc, c, _ = _apply_block(lp, cfg, sp, xc, positions, "prefill")
                caches.append(c)
            return xc, tuple(caches)

        body = _remat_wrap(body, remat)
        return jax.lax.scan(body, x, stacked)

    caches: Params = {}
    if "prefix" in params:
        x, pc = run_stack(x, params["prefix"], (specs[0],))
        caches["prefix"] = pc[0]
    x, bc = run_stack(x, tuple(params["body"]), pspecs)
    caches["body"] = bc
    if last_pos is None:
        sel = x[:, -1:]
    else:
        idx = jnp.broadcast_to(
            jnp.asarray(last_pos, jnp.int32)[:, None, None],
            (x.shape[0], 1, x.shape[2]))
        sel = jnp.take_along_axis(x, idx, axis=1)
    logits = _logits(params, cfg, sel)
    return logits, caches


def lm_decode(params: Params, cfg: ModelConfig, tokens: jax.Array,
              cache: Params, index: jax.Array,
              positions: Optional[jax.Array] = None,
              tables: Optional[jax.Array] = None,
              valid: Optional[jax.Array] = None):
    """One decode step over K >= 1 tokens per row.

    tokens: (B, K) int32 — K = 1 is the classic single-token decode;
    K > 1 is the speculative-verify step (K consecutive tokens per row,
    logits returned for every position).  index: scalar int32 write
    position of the first token (= current KV length), or an int32 (B,)
    vector of per-row positions (continuous batching: each batch row is
    a different request at a different length); token t of row b lands
    at ``index[b] + t``.  With ``tables`` ((B, W) int32 block tables)
    attention layers run the paged gather/scatter path and a per-row
    index of -1 marks an idle row (writes route to the null page).
    ``valid`` (int32 (B,), optional) caps the real tokens per row:
    beyond it attention writes route to the null page and recurrent
    state freezes — the primitive speculative decoding's rollback
    replay is built on.  Returns (logits (B, K, V), new_cache)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, "batch", "seq", "act_embed")
    B, K = tokens.shape
    index = jnp.asarray(index, jnp.int32)
    if valid is not None:
        valid = jnp.asarray(valid, jnp.int32)
    if positions is None:
        idx_col = index[:, None] if index.ndim else \
            jnp.full((B, 1), index, jnp.int32)
        if tables is not None:     # paged: clamp the idle-row sentinel
            idx_col = jnp.maximum(idx_col, 0)
        pos = idx_col + jnp.arange(K, dtype=jnp.int32)[None, :]
        if cfg.use_mrope:
            # text decode: all three M-RoPE components advance together
            positions = jnp.broadcast_to(pos[None], (3, B, K))
        else:
            positions = pos
    pspecs = _period_specs(cfg)
    specs = layer_specs(cfg)

    def run_stack(x, stacked, cache_stacked, spec_list):
        def body(xc, inp):
            layer_p, layer_c = inp
            if not isinstance(layer_p, tuple):
                layer_p = (layer_p,)
                layer_c = (layer_c,)
            new_caches = []
            for sp, lp, lc in zip(spec_list, layer_p, layer_c):
                xc, nc, _ = _apply_block(lp, cfg, sp, xc, positions,
                                         "decode", cache=lc, index=index,
                                         tables=tables, valid=valid)
                new_caches.append(nc)
            return xc, tuple(new_caches)

        return jax.lax.scan(body, x, (stacked, cache_stacked))

    new_cache: Params = {}
    if "prefix" in params:
        x, pc = run_stack(x, params["prefix"], cache["prefix"], (specs[0],))
        new_cache["prefix"] = pc[0]
    x, bc = run_stack(x, tuple(params["body"]), tuple(cache["body"]), pspecs)
    new_cache["body"] = bc
    logits = _logits(params, cfg, x)
    return logits, new_cache
