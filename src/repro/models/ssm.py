"""Mamba-1 selective SSM block (Jamba's sequence mixer).

TPU adaptation (DESIGN.md section 2): instead of the CUDA fused selective-scan
kernel, the scan is expressed as ``lax.scan`` over fixed-size time chunks
with a parallel ``associative_scan`` inside each chunk — the chunk
intermediates are the only materialized (B, Q, d_in, N) tensors, which keeps
the working set VMEM/HBM-friendly at 4k–32k sequence lengths, and the
recurrent carry makes O(1)-state decode (long_500k) natural.

Three entry points:
  * ``mamba_block``       — full-sequence (training / prefill), returns y
  * ``mamba_prefill``     — returns (y, state) for subsequent decode
  * ``mamba_decode``      — single-token state update
  * ``mamba_ref_sequential`` — O(S) pure scan oracle for property tests
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MambaConfig, ModelConfig
from repro.models.layers import KeyGen, dense_init
from repro.parallel.sharding import constrain

Params = Dict[str, Any]

CHUNK = 128  # time-chunk for the parallel scan (bounds peak memory)


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    mc = cfg.mamba or MambaConfig()
    d_in = mc.expand * cfg.d_model
    dt_rank = mc.dt_rank or math.ceil(cfg.d_model / 16)
    return d_in, mc.d_state, mc.d_conv, dt_rank


def init_mamba(keys: KeyGen, cfg: ModelConfig) -> Tuple[Params, Params]:
    d, dt = cfg.d_model, jnp.dtype(cfg.dtype)
    d_in, N, K, R = _dims(cfg)
    # S4-style A initialization: A = -(1..N) broadcast over channels
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None], (d_in, 1))
    p: Params = {
        "in_proj": dense_init(keys(), d, 2 * d_in, dt),
        "conv_w": (jax.random.normal(keys(), (K, d_in), jnp.float32)
                   / math.sqrt(K)).astype(dt),
        "conv_b": jnp.zeros((d_in,), dt),
        "x_proj": dense_init(keys(), d_in, R + 2 * N, dt),
        "dt_proj": dense_init(keys(), R, d_in, dt),
        "dt_bias": jnp.full((d_in,), -4.6, jnp.float32),   # softplus ~= 0.01
        "A_log": jnp.log(A),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(keys(), d_in, d, dt),
    }
    a: Params = {
        "in_proj": ("embed", "mlp"),
        "conv_w": ("conv", "mlp"),
        "conv_b": ("mlp",),
        "x_proj": ("mlp", None),
        "dt_proj": (None, "mlp"),
        "dt_bias": ("mlp",),
        "A_log": ("mlp", None),
        "D": ("mlp",),
        "out_proj": ("mlp", "embed"),
    }
    return p, a


def _conv_causal(params: Params, x: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. x: (B, S, d_in)."""
    K = params["conv_w"].shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, params["conv_w"][:, None, :].astype(x.dtype),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return out + params["conv_b"].astype(x.dtype)


def _ssm_inputs(params: Params, cfg: ModelConfig, xc: jax.Array):
    """xc: (B, S, d_in) post-conv activations -> (dA_log, dBx, C)."""
    d_in, N, _, R = _dims(cfg)
    proj = xc @ params["x_proj"]                     # (B,S,R+2N)
    dt, Bmat, Cmat = jnp.split(proj.astype(jnp.float32), [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt @ params["dt_proj"].astype(jnp.float32)
                         + params["dt_bias"])        # (B,S,d_in)
    A = -jnp.exp(params["A_log"])                    # (d_in,N)
    dA_log = dt[..., None] * A                       # (B,S,d_in,N)  (= log dA)
    dBx = (dt * xc.astype(jnp.float32))[..., None] * Bmat[..., None, :]
    return dA_log, dBx, Cmat


def _mamba_core(params: Params, cfg: ModelConfig, x: jax.Array,
                return_state: bool):
    """Chunked selective scan, memory-bounded.

    The (B, Q, d_in, N) discretized-SSM tensors are built *inside* each
    time-chunk step and the output projection y = C.h happens in-chunk,
    so nothing of size (B, S, d_in, N) is ever materialized — the peak
    extra memory is O(B * CHUNK * d_in * N) per layer regardless of S.
    """
    B, S, _ = x.shape
    d_in, N, K, R = _dims(cfg)
    xz = x @ params["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = constrain(xi, "batch", "seq", "mlp_act")
    xc = jax.nn.silu(_conv_causal(params, xi))         # (B,S,d_in)

    # small per-step routing tensors (dt/B/C) for the whole sequence
    proj = xc @ params["x_proj"]                       # (B,S,R+2N)
    dt_in, Bmat, Cmat = jnp.split(proj.astype(jnp.float32), [R, R + N],
                                  axis=-1)
    A = -jnp.exp(params["A_log"])                      # (d_in,N)

    Q = min(CHUNK, max(1, S))
    pad = (-S) % Q
    if pad:
        pz = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),)
                               * (t.ndim - 2))
        xc_p, dt_p, B_p, C_p = pz(xc), pz(dt_in), pz(Bmat), pz(Cmat)
    else:
        xc_p, dt_p, B_p, C_p = xc, dt_in, Bmat, Cmat
    nch = (S + pad) // Q

    def chunks(t):
        return t.reshape(B, nch, Q, *t.shape[2:]).swapaxes(0, 1)

    def combine(l, r):
        (a1, b1), (a2, b2) = l, r
        return a1 + a2, jnp.exp(a2) * b1 + b2

    def chunk_step(h, inp):
        xc_c, dt_c, B_c, C_c = inp                      # (B,Q,...)
        dt = jax.nn.softplus(dt_c @ params["dt_proj"].astype(jnp.float32)
                             + params["dt_bias"])      # (B,Q,d_in)
        dA_log = dt[..., None] * A                      # (B,Q,d_in,N)
        dBx = (dt * xc_c.astype(jnp.float32))[..., None] \
            * B_c[..., None, :]
        cum_a, cum_b = jax.lax.associative_scan(combine, (dA_log, dBx),
                                                axis=1)
        h_all = jnp.exp(cum_a) * h[:, None] + cum_b     # (B,Q,d_in,N)
        y = jnp.einsum("bqdn,bqn->bqd", h_all, C_c)     # (B,Q,d_in)
        return h_all[:, -1], y.astype(x.dtype)

    h0 = jnp.zeros((B, d_in, N), jnp.float32)
    # checkpoint the chunk body: the scan's backward then saves only the
    # small (B, d_in, N) carry per chunk and recomputes the (B, Q, d_in,
    # N) internals — keeps training memory O(CHUNK), not O(S).
    # The named scope tags this traffic for the kernels/mamba_scan.py
    # roofline credit (VMEM-resident state on TPU).
    with jax.named_scope("mamba_scan"):
        h_last, y_chunks = jax.lax.scan(
            jax.checkpoint(chunk_step), h0,
            (chunks(xc_p), chunks(dt_p), chunks(B_p), chunks(C_p)))
    y = y_chunks.swapaxes(0, 1).reshape(B, S + pad, d_in)[:, :S]
    # keep the gating chain in the model dtype: the f32 numerics live
    # inside the (checkpointed) chunk scan; here bf16 is sufficient and
    # keeps the in_proj cotangents bf16
    y = y + (params["D"].astype(x.dtype) * xc)
    y = y * jax.nn.silu(z)
    y = constrain(y, "batch", "seq", "mlp_act")
    out = y @ params["out_proj"]
    out = constrain(out, "batch", "seq", "act_embed")
    if not return_state:
        return out
    conv_state = jnp.pad(xi, ((0, 0), (K - 1, 0), (0, 0)))[:, S:S + K - 1] \
        if S < K - 1 else xi[:, S - (K - 1):S]
    return out, {"ssm": h_last, "conv": conv_state.astype(x.dtype)}


def mamba_block(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    return _mamba_core(params, cfg, x, return_state=False)


def mamba_prefill(params: Params, cfg: ModelConfig, x: jax.Array):
    return _mamba_core(params, cfg, x, return_state=True)


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=None):
    d_in, N, K, _ = _dims(cfg)
    dt = dtype or jnp.dtype(cfg.dtype)
    state = {
        "ssm": jnp.zeros((batch, d_in, N), jnp.float32),
        "conv": jnp.zeros((batch, K - 1, d_in), dt),
    }
    axes = {"ssm": ("batch", "state", None), "conv": ("batch", None, "state")}
    return state, axes


def mamba_decode(params: Params, cfg: ModelConfig, x: jax.Array,
                 state: Params):
    """x: (B, 1, d); state from init_mamba_state/prefill."""
    B = x.shape[0]
    d_in, N, K, _ = _dims(cfg)
    xz = x[:, 0] @ params["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)                     # (B,d_in)
    window = jnp.concatenate([state["conv"], xi[:, None]], axis=1)  # (B,K,d)
    xc = jnp.einsum("bkd,kd->bd", window.astype(jnp.float32),
                    params["conv_w"].astype(jnp.float32))
    xc = jax.nn.silu(xc + params["conv_b"].astype(jnp.float32))
    dA_log, dBx, Cmat = _ssm_inputs(params, cfg, xc[:, None])
    h = jnp.exp(dA_log[:, 0]) * state["ssm"] + dBx[:, 0]   # (B,d_in,N)
    y = jnp.einsum("bdn,bn->bd", h, Cmat[:, 0])
    y = y + params["D"] * xc
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = (y @ params["out_proj"])[:, None]
    new_state = {"ssm": h, "conv": window[:, 1:].astype(state["conv"].dtype)}
    return constrain(out, "batch", "seq", "act_embed"), new_state


def mamba_decode_multi(params: Params, cfg: ModelConfig, x: jax.Array,
                       state: Params, valid=None):
    """K-token decode: x (B, K, d) -> (out (B, K, d), new_state).

    Sequential over K (K is the small speculative window, not a
    sequence); ``valid`` (int32 (B,)) freezes each row's state after
    its real tokens so verify padding / rollback replays cannot advance
    the recurrence (see :func:`repro.models.layers.decode_scan`).
    """
    from repro.models.layers import decode_scan
    return decode_scan(
        lambda xt, st: mamba_decode(params, cfg, xt, st), x, state, valid)


def mamba_ref_sequential(params: Params, cfg: ModelConfig, x: jax.Array
                         ) -> jax.Array:
    """Oracle: straight lax.scan over every timestep (no chunking)."""
    B, S, _ = x.shape
    state, _ = init_mamba_state(cfg, B)
    def step(st, xt):
        out, st = mamba_decode(params, cfg, xt[:, None], st)
        return st, out[:, 0]
    _, ys = jax.lax.scan(step, state, x.swapaxes(0, 1))
    return ys.swapaxes(0, 1)
