"""Synthetic LM token pipeline (for the assigned LM architectures).

Generates deterministic pseudo-natural token streams with learnable
n-gram structure (so smoke-training shows loss decrease), plus
``batch_for`` helpers that build train/prefill/decode batches for any
ModelConfig, including the VLM/audio stub frontends.
"""
from __future__ import annotations

import os
from typing import Dict, List

import numpy as np

from repro.configs.base import ModelConfig


def token_stream(n: int, vocab: int, seed: int = 0) -> np.ndarray:
    """Markov-ish token stream: next token depends on previous two."""
    rng = np.random.default_rng(seed)
    a, b = 6364136223846793005, 1442695040888963407
    mask = (1 << 64) - 1
    toks = np.empty(n, np.int64)
    t1, t2 = 1, 2
    noise = rng.integers(0, vocab, size=n)
    for i in range(n):
        det = (((t1 * a + t2 * b) & mask) >> 17) % vocab
        toks[i] = det if (i % 4) else int(noise[i])
        t1, t2 = int(toks[i]), t1
    return toks.astype(np.int32)


def lm_batches(num_batches: int, batch: int, seq: int, vocab: int,
               seed: int = 0):
    stream = token_stream(num_batches * batch * (seq + 1), vocab, seed)
    stream = stream.reshape(num_batches, batch, seq + 1)
    for i in range(num_batches):
        yield {"tokens": stream[i, :, :-1], "labels": stream[i, :, 1:]}


def train_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0
                ) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    out: Dict[str, np.ndarray] = {}
    if cfg.family == "vlm":
        # stub frontend: precomputed patch embeddings + M-RoPE positions
        out["embeds"] = rng.normal(0, 0.02, (batch, seq, cfg.d_model)) \
            .astype(np.float32)
        t = np.tile(np.arange(seq, dtype=np.int32), (batch, 1))
        out["positions"] = np.stack([t, t // 8, t % 8])   # (3,B,S)
        out["labels"] = rng.integers(0, cfg.vocab_size, (batch, seq)) \
            .astype(np.int32)
        return out
    toks = token_stream(batch * (seq + 1), cfg.vocab_size, seed) \
        .reshape(batch, seq + 1)
    out["tokens"] = toks[:, :-1]
    out["labels"] = toks[:, 1:]
    return out


# ---------------------------------------------------------------------------
# Bundled token shards (LM analogue of the JAG sample bundles) — on-disk
# files so the distributed DataStore can partition / preload / exchange
# LM data exactly like the scientific bundles.
# ---------------------------------------------------------------------------


def shard_path(root: str, i: int) -> str:
    return os.path.join(root, f"tokens_{i:05d}.npz")


def write_token_shards(root: str, num_samples: int, seq_len: int,
                       vocab: int, samples_per_file: int = 256,
                       seed: int = 0) -> List[str]:
    """Write `num_samples` (seq_len+1)-token rows into bundle files.

    Each row holds input tokens and next-token labels in one array
    (split by :func:`lm_shard_batch` at batch-assembly time).
    """
    os.makedirs(root, exist_ok=True)
    stream = token_stream(num_samples * (seq_len + 1), vocab, seed)
    rows = stream.reshape(num_samples, seq_len + 1)
    paths = []
    for fi in range(0, num_samples, samples_per_file):
        path = shard_path(root, fi // samples_per_file)
        np.savez(path, tokens=rows[fi:fi + samples_per_file])
        paths.append(path)
    return paths


def read_token_shard(path: str) -> Dict[str, np.ndarray]:
    with np.load(path) as z:
        return {"tokens": z["tokens"]}


def list_token_shards(root: str) -> List[str]:
    if not os.path.isdir(root):
        return []
    return sorted(os.path.join(root, f) for f in os.listdir(root)
                  if f.startswith("tokens_") and f.endswith(".npz"))


def lm_shard_batch(batch: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """DataStore batch (stacked shard rows) -> LM train batch."""
    rows = batch["tokens"]
    return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}
