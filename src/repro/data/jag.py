"""Synthetic JAG ICF simulator (stand-in for the LLNL dataset, DESIGN.md §8).

The real data: 10M simulations from the JAG semi-analytic model — each
sample is (x: 5-D input params) -> (15 scalars, 12 X-ray images 64x64:
3 lines of sight x 4 hyperspectral channels), packed 1000 samples/file.

This module regenerates data with the same structure and qualitative
behavior (deterministic, smooth but strongly non-linear in the drive
parameters; shape parameters morph the images) so the CycleGAN + LTFB
experiments have real signal to learn.

x layout: x[0] = laser drive strength, x[1] = fuel fill,
          x[2:5] = 3 shape (asymmetry) parameters.  All in [0, 1].
"""
from __future__ import annotations

import math
import os
from typing import Dict, List

import numpy as np

NUM_INPUTS = 5
NUM_SCALARS = 15
NUM_VIEWS = 3
NUM_CHANNELS = 4
NUM_IMAGES = NUM_VIEWS * NUM_CHANNELS


def sample_inputs(n: int, seed: int = 0) -> np.ndarray:
    """Quasi-random coverage of the 5-D parameter space.

    The paper uses spectral space-filling sampling [12]; a scrambled
    Halton sequence gives the same dense-coverage property.
    """
    primes = [2, 3, 5, 7, 11]
    rng = np.random.default_rng(seed)
    shift = rng.random(NUM_INPUTS)
    idx = np.arange(1, n + 1)
    cols = []
    for p in primes:
        x = np.zeros(n)
        denom, i = p, idx.copy()
        while i.max() > 0:
            x += (i % p) / denom
            i //= p
            denom *= p
        cols.append(x)
    pts = (np.stack(cols, axis=1) + shift) % 1.0
    return pts.astype(np.float32)


def _scalars(x: np.ndarray) -> np.ndarray:
    """15 scalar observables; strongly non-linear in drive (paper §II-B)."""
    d, fill = x[:, 0], x[:, 1]
    s = x[:, 2:5]
    asym = np.linalg.norm(s - 0.5, axis=1)
    out = []
    yield_ = np.exp(4.0 * d) * (1.0 - 0.8 * asym ** 2) * (0.3 + fill)
    out.append(yield_)                                 # neutron yield
    out.append(np.log1p(yield_))                       # log yield
    tion = 1.0 + 3.0 * d ** 2 - asym                   # ion temperature
    out.append(tion)
    out.append(tion ** 2 / 4.0)                        # x-ray brightness
    out.append(0.5 + 0.5 * np.tanh(6.0 * (d - 0.55)))  # ignition proxy
    rho_r = (0.4 + d) * (1.0 - 0.5 * asym) * (0.5 + 0.5 * fill)
    out.append(rho_r)                                  # areal density
    out.append(np.sin(math.pi * d) * np.cos(2 * math.pi * s[:, 0]))
    out.append(s[:, 0] * s[:, 1] - s[:, 2] ** 2)
    out.append(np.exp(-8.0 * asym ** 2))               # symmetry metric
    out.append(d * fill)
    out.append(np.sqrt(np.maximum(yield_, 0)) * 0.1)
    out.append(np.cos(3 * math.pi * (d - asym)))
    out.append((1 - d) * asym)
    out.append(np.maximum(0.0, d - 2 * asym))          # margin
    out.append(0.2 + 0.6 * fill + 0.2 * np.sin(2 * math.pi * s[:, 1]))
    return np.stack(out, axis=1).astype(np.float32)


def _images(x: np.ndarray, size: int) -> np.ndarray:
    """(B, 12, size, size) capsule self-emission images.

    Ellipse with Legendre-like mode-2/3 perturbations from the shape
    params; per-channel (hyperspectral) energy falloff scales with drive;
    3 views rotate the asymmetry.
    """
    B = x.shape[0]
    d = x[:, 0][:, None, None]
    s = x[:, 2:5]
    yy, xx = np.meshgrid(np.linspace(-1, 1, size), np.linspace(-1, 1, size),
                         indexing="ij")
    r = np.sqrt(xx ** 2 + yy ** 2) + 1e-6
    th = np.arctan2(yy, xx)
    imgs = np.empty((B, NUM_IMAGES, size, size), np.float32)
    for v in range(NUM_VIEWS):
        phase = 2.0 * math.pi * v / NUM_VIEWS
        # mode-2 and mode-3 radius perturbation per sample
        p2 = (s[:, 0] - 0.5)[:, None, None]
        p3 = (s[:, 1] - 0.5)[:, None, None]
        rot = (s[:, 2] - 0.5)[:, None, None] * math.pi
        radius = 0.55 * (1.0 + 0.35 * p2 * np.cos(2 * (th + rot + phase))
                         + 0.25 * p3 * np.cos(3 * (th + rot + phase)))
        radius = np.maximum(radius, 0.05)
        shell = np.exp(-0.5 * ((r - radius) / (0.08 + 0.05 * (1 - d))) ** 2)
        core = np.exp(-0.5 * (r / (0.15 + 0.1 * d)) ** 2) * d
        base = shell + 1.5 * core
        for c in range(NUM_CHANNELS):
            # hyperspectral falloff: higher channels need hotter implosion
            gain = np.exp(-c * (1.2 - d))
            imgs[:, v * NUM_CHANNELS + c] = (base * gain).astype(np.float32)
    return imgs


def jag_simulate(x: np.ndarray, image_size: int = 64) -> Dict[str, np.ndarray]:
    """Run the synthetic JAG model. x: (B, 5) in [0,1]."""
    assert x.ndim == 2 and x.shape[1] == NUM_INPUTS
    return {"x": x.astype(np.float32),
            "scalars": _scalars(x),
            "images": _images(x, image_size)}


def flatten_outputs(sample: Dict[str, np.ndarray]) -> np.ndarray:
    """y bundle: (B, 15 + 12*size*size), normalized to O(1)."""
    B = sample["scalars"].shape[0]
    sc = sample["scalars"] / 10.0
    im = sample["images"].reshape(B, -1)
    return np.concatenate([sc, im], axis=1).astype(np.float32)


# ---------------------------------------------------------------------------
# Bundled sample files (stand-in for the paper's 1000-sample HDF5 bundles)
# ---------------------------------------------------------------------------


def bundle_path(root: str, i: int) -> str:
    return os.path.join(root, f"jag_{i:05d}.npz")


def write_bundles(root: str, num_samples: int, samples_per_file: int = 1000,
                  image_size: int = 64, seed: int = 0) -> List[str]:
    """Generate the dataset into `num_samples/samples_per_file` bundle
    files.  Samples are written in parameter-space exploration order —
    NOT shuffled — reproducing the paper's pathological file layout
    (Section IV-C: random minibatch sampling must touch many files)."""
    os.makedirs(root, exist_ok=True)
    xs = sample_inputs(num_samples, seed)
    paths = []
    for fi in range(0, num_samples, samples_per_file):
        batch = jag_simulate(xs[fi:fi + samples_per_file], image_size)
        path = bundle_path(root, fi // samples_per_file)
        np.savez(path, **batch)
        paths.append(path)
    return paths


def read_bundle(path: str) -> Dict[str, np.ndarray]:
    with np.load(path) as z:
        return {k: z[k] for k in z.files}


def list_bundles(root: str) -> List[str]:
    """Existing bundle manifest under `root` (sorted; [] if none)."""
    if not os.path.isdir(root):
        return []
    return sorted(os.path.join(root, f) for f in os.listdir(root)
                  if f.startswith("jag_") and f.endswith(".npz"))
