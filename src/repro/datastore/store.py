"""Distributed in-memory data store (paper Section III-B, Figs. 5/10).

Each *rank* of a trainer owns a subset of the sample bundles and caches
its samples in host memory; per-mini-batch, samples are exchanged from
owner to consumer (non-blocking, overlapped — here: a background
prefetch thread).  Two population modes:

  * ``preload`` — ranks bulk-read disjoint file subsets before training
    (each file opened by exactly one rank; optimal for bundle formats).
  * ``dynamic`` — epoch 1 reads from files on demand (naive access
    pattern) but caches; epochs 2+ never touch the filesystem.
  * ``none``    — the naive reader (every access opens a file).

This is a single-process simulation of the multi-rank protocol with
faithful accounting (file opens, bytes read, exchange volume) — in a
multi-host JAX deployment, ``exchange`` becomes
``jax.make_array_from_process_local_data`` over the trainer's hosts.
"""
from __future__ import annotations

import collections
import queue
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np


class StoreStats:
    def __init__(self):
        self.file_opens = 0
        self.bytes_read = 0
        self.exchange_bytes = 0
        self.cache_hits = 0
        self.cache_misses = 0
        self.samples_fetched = 0
        self.preload_seconds = 0.0

    def as_dict(self):
        return dict(self.__dict__)


class DataStore:
    """In-memory sample store for one trainer.

    Parameters
    ----------
    files : bundle file paths (this trainer's data partition).
    reader : callable(path) -> dict[str, np.ndarray] with leading sample dim.
    num_ranks : simulated MPI ranks within the trainer.
    mode : 'preload' | 'dynamic' | 'none'.
    """

    def __init__(self, files: Sequence[str], reader: Callable,
                 num_ranks: int = 1, mode: str = "preload", seed: int = 0):
        assert mode in ("preload", "dynamic", "none")
        self.files = list(files)
        self.reader = reader
        self.num_ranks = num_ranks
        self.mode = mode
        self.seed = seed
        self.stats = StoreStats()
        # sample index: probe first file for samples/file
        first = reader(self.files[0])
        self._keys = sorted(first.keys())
        self.samples_per_file = len(first[self._keys[0]])
        self.stats.file_opens += 1
        self.stats.bytes_read += sum(v.nbytes for v in first.values())
        last = None
        if len(self.files) > 1:
            # sample-id -> file arithmetic assumes uniform bundles; a
            # short final bundle would index past its end — fail loudly
            last = reader(self.files[-1])
            self.stats.file_opens += 1
            self.stats.bytes_read += sum(v.nbytes for v in last.values())
            n_last = len(last[self._keys[0]])
            if n_last != self.samples_per_file:
                raise ValueError(
                    f"non-uniform bundle manifest: {self.files[-1]} has "
                    f"{n_last} samples, expected {self.samples_per_file} "
                    "— write num_samples as a multiple of "
                    "samples_per_file or drop the short bundle")
        self.num_samples = self.samples_per_file * len(self.files)
        # rank-owned caches: rank -> {sample_id: {key: np.ndarray}}
        self._cache: List[Dict[int, dict]] = [dict() for _ in range(num_ranks)]
        if mode != "none":
            self._adopt_file(0, first)
            if last is not None:
                self._adopt_file(len(self.files) - 1, last)

    # -- ownership ---------------------------------------------------------
    def owner_of_file(self, file_idx: int) -> int:
        return file_idx % self.num_ranks

    def owner_of_sample(self, sid: int) -> int:
        return self.owner_of_file(sid // self.samples_per_file)

    # -- population --------------------------------------------------------
    def _adopt_file(self, file_idx: int, bundle: dict):
        rank = self.owner_of_file(file_idx)
        base = file_idx * self.samples_per_file
        n = len(bundle[self._keys[0]])
        for j in range(n):
            self._cache[rank][base + j] = {k: bundle[k][j]
                                           for k in self._keys}

    def preload(self, parallel: bool = True):
        """Populate the store before training (paper: each file is opened
        by exactly one process; ranks read their files in parallel)."""
        assert self.mode == "preload"
        t0 = time.perf_counter()

        def load(fi):
            b = self.reader(self.files[fi])
            self.stats.file_opens += 1
            self.stats.bytes_read += sum(v.nbytes for v in b.values())
            return fi, b

        todo = [fi for fi in range(len(self.files))
                if fi * self.samples_per_file not in self._cache[
                    self.owner_of_file(fi)]]
        if parallel and self.num_ranks > 1:
            with ThreadPoolExecutor(max_workers=min(self.num_ranks, 16)) as ex:
                for fi, b in ex.map(load, todo):
                    self._adopt_file(fi, b)
        else:
            for fi in todo:
                self._adopt_file(*load(fi))
        self.stats.preload_seconds = time.perf_counter() - t0

    # -- access ------------------------------------------------------------
    def _fetch_sample(self, sid: int) -> dict:
        self.stats.samples_fetched += 1
        rank = self.owner_of_sample(sid)
        hit = self._cache[rank].get(sid)
        if hit is not None:
            self.stats.cache_hits += 1
            return hit
        self.stats.cache_misses += 1
        fi = sid // self.samples_per_file
        bundle = self.reader(self.files[fi])
        self.stats.file_opens += 1
        j = sid - fi * self.samples_per_file
        sample = {k: bundle[k][j] for k in self._keys}
        self.stats.bytes_read += sum(bundle[k][j].nbytes for k in self._keys)
        if self.mode == "dynamic":
            # cache the whole bundle — we already paid for the read
            self._adopt_file(fi, bundle)
        return sample

    def epoch_permutation(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 100_003 + epoch)
        return rng.permutation(self.num_samples)

    def get_batch(self, perm: np.ndarray, step: int, batch_size: int,
                  consumer_rank: int = 0) -> Dict[str, np.ndarray]:
        """Assemble a mini-batch; counts owner->consumer exchange volume."""
        lo = (step * batch_size) % self.num_samples
        idx = perm[lo:lo + batch_size]
        if len(idx) < batch_size:    # wrap
            idx = np.concatenate([idx, perm[:batch_size - len(idx)]])
        samples = []
        for sid in idx:
            s = self._fetch_sample(int(sid))
            if self.owner_of_sample(int(sid)) != consumer_rank:
                self.stats.exchange_bytes += sum(v.nbytes for v in s.values())
            samples.append(s)
        return {k: np.stack([s[k] for s in samples]) for k in self._keys}

    def steps_per_epoch(self, batch_size: int) -> int:
        return max(1, self.num_samples // batch_size)


class PrefetchLoader:
    """Background-thread batch assembly (the paper's non-blocking shuffle
    overlap).  ``depth`` is the double-buffering depth.

    ``consumer_rank`` selects which simulated rank assembles each batch:
    a fixed int, or ``None`` to rotate ranks per step (each rank takes
    its turn consuming, so owner->consumer exchange volume accrues the
    way it does across the trainer's real ranks).
    """

    def __init__(self, store: DataStore, batch_size: int, depth: int = 2,
                 epoch: int = 0, consumer_rank: Optional[int] = 0):
        self.store = store
        self.batch_size = batch_size
        self.consumer_rank = consumer_rank
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._epoch = epoch
        # prefetch-stall accounting: wall seconds the consumer spent
        # blocked in next() (queue empty = producer behind), and how
        # many of those gets actually blocked
        self.wait_seconds = 0.0
        self.stalls = 0
        self.batches_delivered = 0
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = 0
        perm = self.store.epoch_permutation(self._epoch)
        spe = self.store.steps_per_epoch(self.batch_size)
        while not self._stop.is_set():
            if step and step % spe == 0:
                self._epoch += 1
                perm = self.store.epoch_permutation(self._epoch)
            rank = self.consumer_rank if self.consumer_rank is not None \
                else step % self.store.num_ranks
            batch = self.store.get_batch(perm, step, self.batch_size,
                                         consumer_rank=rank)
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self, timeout: float = 60.0):
        try:
            batch = self._q.get_nowait()
        except queue.Empty:
            t0 = time.perf_counter()
            batch = self._q.get(timeout=timeout)
            self.wait_seconds += time.perf_counter() - t0
            self.stalls += 1
        self.batches_delivered += 1
        return batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5.0)


def partition_files(files: Sequence[str], num_trainers: int,
                    trainer_idx: int, strategy: str = "stride") -> List[str]:
    """LTFB data partitioning (disjoint, load-balanced; paper §III-C).

    ``stride``: trainer k owns files[k::num_trainers] (interleaved —
    every trainer samples the whole exploration order).
    ``block``: trainer k owns a contiguous chunk — since bundles are
    written in parameter-space exploration order this approximates the
    paper's data-silo scenario (each trainer sees one region of input
    space, and tournaments propagate the encoded partitions).
    """
    if strategy == "stride":
        return list(files[trainer_idx::num_trainers])
    if strategy == "block":
        n = len(files)
        lo = trainer_idx * n // num_trainers
        hi = (trainer_idx + 1) * n // num_trainers
        return list(files[lo:hi])
    raise ValueError(f"unknown partition strategy {strategy!r}")


def aggregate_stats(stores: Sequence[DataStore]) -> Dict[str, float]:
    """Sum StoreStats across a population of per-trainer stores."""
    total: Dict[str, float] = collections.defaultdict(float)
    for s in stores:
        for k, v in s.stats.as_dict().items():
            total[k] += v
    return dict(total)
