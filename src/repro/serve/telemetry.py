"""Serving telemetry: request tracing, Prometheus export, profiler hooks.

This module is the observability substrate for the serving stack:

* :class:`Tracer` — a bounded ring buffer of Chrome-trace events.  The
  scheduler emits one span chain per request (``enqueue`` → ``queued``
  → ``prefill`` chunk(s) → ``first_token`` → ``finish``/``shed``/
  ``cancel``) plus per-step phase spans; :func:`write_trace` or the
  gateway's ``GET /debug/trace`` export the buffer as Chrome-trace /
  Perfetto JSON so one file explains any slow request.
* :class:`ServeTelemetry` — the per-scheduler façade: owns the tracer,
  accumulates per-phase wall time (``prefill`` / ``decode`` / ``draft``
  / ``verify`` …), and arms :func:`jax.profiler.start_trace` around a
  step window (``POST /debug/profile`` / ``--profile-steps``).
* :func:`prometheus_text` / :func:`scheduler_prometheus` — Prometheus
  text-format (0.0.4) exposition of every ``[serve]`` counter, the
  bounded latency histograms, per-``data``-shard page-pool occupancy,
  and per-rank series aggregated from mesh followers.
* :func:`stats_snapshot` — the compact JSON stats delta followers ship
  to host 0 each step over the plan channel's ``gather``.
* :func:`enable_json_logs` / :func:`log_event` — one-line structured
  JSON log records (``--log-json``) for report lines and
  hot-swap/shed events.

The tracing/JSON-log primitives live in :mod:`repro.telemetry` (shared
with the training stack so both emit one dialect) and are re-exported
here for backward compatibility.  Everything here is stdlib + jax;
nothing imports the scheduler, so the scheduler (and metrics) can
import this module freely.
"""

from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional

from repro.telemetry import (  # noqa: F401  (re-exported surface)
    SCHED_TID,
    Tracer,
    enable_json_logs,
    json_logs_enabled,
    log_event,
    prom_fmt as _fmt,
    write_trace,
)

__all__ = [
    "Tracer",
    "ServeTelemetry",
    "prometheus_text",
    "scheduler_prometheus",
    "stats_snapshot",
    "write_trace",
    "enable_json_logs",
    "json_logs_enabled",
    "log_event",
]


class ServeTelemetry:
    """Per-scheduler telemetry: tracer + phase attribution + profiler.

    ``enabled=False`` turns tracing and phase spans into no-ops (the
    cheap counters in :class:`~repro.serve.metrics.ServeStats` stay on);
    the profiler window works regardless so ``--profile-steps`` composes
    with ``--no-telemetry``.
    """

    def __init__(self, enabled: bool = True, trace_capacity: int = 8192):
        self.enabled = bool(enabled)
        self.tracer = Tracer(trace_capacity)
        # cumulative wall seconds per phase: prefill / decode / draft /
        # verify / admit — the step-timeline attribution profiler runs
        # are cross-checked against
        self.phase_seconds: Dict[str, float] = {}
        self.phase_calls: Dict[str, int] = {}
        self._profile_req: Optional[tuple] = None  # (steps, outdir)
        self._profile_active = False
        self._profile_left = 0
        self._profile_dir: Optional[str] = None
        self.profiles_taken = 0
        self.profile_error: Optional[str] = None

    # ---- request lifecycle ------------------------------------------------

    def req_instant(self, rid: Any, name: str, t: Optional[float] = None,
                    **args: Any) -> None:
        """Emit an instant event on the request's trace row (if enabled)."""
        if self.enabled:
            self.tracer.req_instant(name, rid, t, **args)

    def req_span(self, rid: Any, name: str, t0: Optional[float], t1: float,
                 **args: Any) -> None:
        """Emit a complete span on the request's trace row (if enabled)."""
        if self.enabled and t0 is not None:
            self.tracer.req_span(name, rid, t0, t1, **args)

    def terminal(self, rid: Any, kind: str, t: Optional[float] = None,
                 **args: Any) -> None:
        """Emit the request's terminal instant: finish / shed / cancel."""
        if self.enabled:
            self.tracer.req_instant(kind, rid, t, terminal=True, **args)

    def event(self, name: str, **args: Any) -> None:
        """Emit a scheduler-level instant event (hot swap, drain, …)."""
        if self.enabled:
            self.tracer.instant(name, SCHED_TID, **args)

    # ---- per-step phase attribution ---------------------------------------

    def phase(self, name: str, t0: float, t1: float, emit: bool = True,
              **args: Any) -> None:
        """Accumulate phase wall time; optionally emit a scheduler span."""
        dur = max(0.0, t1 - t0)
        self.phase_seconds[name] = self.phase_seconds.get(name, 0.0) + dur
        self.phase_calls[name] = self.phase_calls.get(name, 0) + 1
        if self.enabled and emit:
            self.tracer.complete(name, SCHED_TID, t0, t1, **args)

    @contextmanager
    def timed_phase(self, name: str, emit: bool = True,
                    **args: Any) -> Iterator[None]:
        """Context manager sugar around :meth:`phase`."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.phase(name, t0, time.perf_counter(), emit=emit, **args)

    # ---- jax profiler window ----------------------------------------------

    def arm_profile(self, steps: int, outdir: str) -> None:
        """Arm ``jax.profiler`` around the next ``steps`` scheduler steps."""
        self._profile_req = (max(1, int(steps)), str(outdir))

    def profile_armed(self) -> bool:
        """Whether a profile window is pending or currently recording."""
        return self._profile_req is not None or self._profile_active

    def step_begin(self, step: int) -> None:
        """Scheduler-step hook: start the profiler if a window is armed."""
        if self._profile_req is None or self._profile_active:
            return
        steps, outdir = self._profile_req
        self._profile_req = None
        try:
            import jax

            jax.profiler.start_trace(outdir)
        except Exception as e:  # pragma: no cover - backend-dependent
            self.profile_error = f"{type(e).__name__}: {e}"
            log_event("profile_error", error=self.profile_error)
            return
        self._profile_active = True
        self._profile_left = steps
        self._profile_dir = outdir
        log_event("profile_start", steps=steps, dir=outdir, step=step)

    def step_end(self) -> None:
        """Scheduler-step hook: stop the profiler when the window closes."""
        if not self._profile_active:
            return
        self._profile_left -= 1
        if self._profile_left > 0:
            return
        self._profile_active = False
        try:
            import jax

            jax.profiler.stop_trace()
        except Exception as e:  # pragma: no cover - backend-dependent
            self.profile_error = f"{type(e).__name__}: {e}"
            log_event("profile_error", error=self.profile_error)
            return
        self.profiles_taken += 1
        log_event("profile_done", dir=self._profile_dir,
                  phase_seconds=dict(self.phase_seconds))


# ---- mesh stats snapshot --------------------------------------------------

# every [serve] counter a follower ships to host 0 (and prometheus
# exports per rank); gauges (queue/slots/pool) ride alongside
_SNAPSHOT_COUNTERS = (
    "submitted",
    "completed",
    "rejected",
    "shed_overload",
    "shed_deadline",
    "cancelled",
    "ttft_deadline_misses",
    "tpot_deadline_misses",
    "prefills",
    "prefill_chunks",
    "prefill_tokens",
    "padded_prefill_tokens",
    "decode_steps",
    "decode_tokens",
    "decode_slot_steps",
    "ragged_splits",
    "spec_rounds",
    "spec_draft_steps",
    "spec_draft_proposed",
    "spec_draft_accepted",
    "spec_replays",
    "steps",
    "hot_swaps",
    "fault_injected",
    "swap_rejected_corrupt",
    "plan_retries",
    "journal_replayed",
    "arena_matches",
    "arena_promotions",
)


def _pool_shards(sched: Any) -> List[dict]:
    """Per-``data``-shard block-manager dicts for a scheduler's pool."""
    pool = getattr(sched, "pool", None)
    if pool is None:
        return []
    shards = getattr(pool, "shards", None)
    if shards:
        return [sh.blocks.as_dict() for sh in shards]
    blocks = getattr(pool, "blocks", None)
    return [blocks.as_dict()] if blocks is not None else []


def stats_snapshot(sched: Any, rank: int = 0) -> dict:
    """Compact per-process stats delta for mesh-wide aggregation.

    Followers JSON-encode this and ship it to host 0 on the plan
    channel's ``gather`` path each step; host 0 keeps the latest
    snapshot per rank in ``sched.remote_stats`` and the Prometheus
    export emits it as per-rank series.
    """
    s = sched.stats
    snap: Dict[str, Any] = {"rank": int(rank)}
    for k in _SNAPSHOT_COUNTERS:
        snap[k] = int(getattr(s, k, 0))
    snap["queue_depth"] = len(getattr(sched, "queue", ()))
    snap["slots_busy"] = len(getattr(sched, "active", ())) + len(
        getattr(sched, "prefilling", ())
    )
    snap["shards"] = _pool_shards(sched)
    arena = getattr(sched, "arena", None)
    if arena is not None:
        snap["arena"] = arena.counters()
    return snap


# ---- prometheus exposition ------------------------------------------------

_PREFIX = "repro_serve_"

_COUNTER_HELP = {
    "submitted": "requests submitted",
    "completed": "requests completed",
    "rejected": "requests rejected at submit (queue full)",
    "shed_overload": "requests shed for overload",
    "shed_deadline": "queued requests shed on expired TTFT deadline",
    "cancelled": "requests cancelled",
    "ttft_deadline_misses": "completions whose first token was late",
    "tpot_deadline_misses": "completions whose mean TPOT was over budget",
    "prefills": "prefill dispatches",
    "prefill_chunks": "chunked-prefill slices",
    "prefill_tokens": "prompt tokens prefilled",
    "padded_prefill_tokens": "prompt tokens incl. bucket padding",
    "decode_steps": "batched decode steps",
    "decode_tokens": "tokens decoded",
    "decode_slot_steps": "per-slot decode steps",
    "ragged_splits": "ragged gather-width split dispatches",
    "spec_rounds": "speculative verify rounds",
    "spec_draft_steps": "drafter decode dispatches",
    "spec_draft_proposed": "draft tokens proposed",
    "spec_draft_accepted": "draft tokens accepted",
    "spec_replays": "speculative rollback replay steps",
    "steps": "scheduler steps",
    "hot_swaps": "weight hot swaps applied",
    "fault_injected": "harness faults fired (--fault-spec)",
    "swap_rejected_corrupt":
        "hot swaps rejected on a corrupt/torn winner checkpoint",
    "plan_retries": "mesh plan-channel fetch retries before success",
    "journal_replayed": "requests requeued from the request journal",
    "arena_matches": "online-LTFB arena match evaluations",
    "arena_promotions": "online-LTFB arena champion promotions",
}

_SHARD_GAUGES = {
    "used_blocks": "KV pages currently allocated",
    "committed_blocks": "KV pages reserved by admitted requests",
    "pinned_blocks": "KV pages pinned by the prefix pin tier",
    "high_water_blocks": "peak KV pages allocated",
    "num_blocks": "KV page capacity",
}


def _hist_lines(out: List[str], name: str, help_: str, series: Any) -> None:
    """Append one histogram family from a BoundedSeries to ``out``."""
    out.append(f"# HELP {name} {help_}")
    out.append(f"# TYPE {name} histogram")
    cum = 0
    for le, n in series.hist.bucket_counts():
        cum += n
        out.append(f'{name}_bucket{{le="{_fmt(le)}"}} {cum}')
    out.append(f'{name}_bucket{{le="+Inf"}} {series.hist.total}')
    out.append(f"{name}_sum {_fmt(series.hist.sum)}")
    out.append(f"{name}_count {series.hist.total}")


def _arena_lines(out: List[str], arena: dict) -> None:
    """Append the online-LTFB arena families (per-member accept-rate /
    served-token gauges + the promotion counter) from an
    ``Arena.counters()`` dict."""
    members = arena.get("members", {})
    fams = (
        ("accept_rate", "gauge",
         "per-member sliding-window spec accept rate",
         lambda m: m.get("accept_rate", 0.0)),
        ("served_tokens", "gauge",
         "tokens served while the member was champion",
         lambda m: int(m.get("served_tokens", 0))),
    )
    for suffix, typ, help_, get in fams:
        name = f"{_PREFIX}arena_{suffix}"
        out.append(f"# HELP {name} {help_}")
        out.append(f"# TYPE {name} {typ}")
        for member in sorted(members):
            out.append(f'{name}{{member="{member}"}} '
                       f"{_fmt(get(members[member]))}")
    name = f"{_PREFIX}arena_promotions_total"
    out.append(f"# HELP {name} arena champion promotions")
    out.append(f"# TYPE {name} counter")
    out.append(f"{name} {int(arena.get('promotions', 0))}")


def _mesh_arena_lines(out: List[str], ranked: List[tuple]) -> None:
    """Per-rank arena member series (``{rank=,member=}``) — one
    HELP/TYPE header per family, samples for every rank under it."""
    fams = (
        ("accept_rate", "gauge",
         "per-rank per-member spec accept rate",
         lambda m: m.get("accept_rate", 0.0)),
        ("served_tokens", "gauge",
         "per-rank tokens served while the member was champion",
         lambda m: int(m.get("served_tokens", 0))),
    )
    for suffix, typ, help_, get in fams:
        name = f"{_PREFIX}mesh_arena_{suffix}"
        out.append(f"# HELP {name} {help_}")
        out.append(f"# TYPE {name} {typ}")
        for rank, arena in ranked:
            for member in sorted(arena.get("members", {})):
                out.append(
                    f'{name}{{rank="{rank}",member="{member}"}} '
                    f"{_fmt(get(arena['members'][member]))}")
    name = f"{_PREFIX}mesh_arena_promotions_total"
    out.append(f"# HELP {name} per-rank arena champion promotions")
    out.append(f"# TYPE {name} counter")
    for rank, arena in ranked:
        out.append(f'{name}{{rank="{rank}"}} '
                   f"{int(arena.get('promotions', 0))}")


def prometheus_text(
    stats: Any,
    pool_shards: Optional[List[dict]] = None,
    phase_seconds: Optional[Dict[str, float]] = None,
    remote_stats: Optional[Dict[int, dict]] = None,
    queue_depth: Optional[int] = None,
    slots_busy: Optional[int] = None,
    arena: Optional[dict] = None,
) -> str:
    """Render a ServeStats (+ pool/phase/mesh context) as Prometheus text.

    Exposition format 0.0.4: ``# HELP`` / ``# TYPE`` per family,
    counters suffixed ``_total``, latency histograms with cumulative
    ``_bucket{le=...}`` + ``_sum`` + ``_count``, per-shard pool gauges
    labelled ``{shard=...}``, per-rank mesh series labelled
    ``{rank=...}`` from the follower snapshots, and — when an
    online-LTFB arena is live (``arena`` is :meth:`Arena.counters`
    output) — per-member ``{member=...}`` accept-rate / served-token
    series plus the promotion counter.
    """
    out: List[str] = []
    for k, help_ in _COUNTER_HELP.items():
        name = f"{_PREFIX}{k}_total"
        out.append(f"# HELP {name} {help_}")
        out.append(f"# TYPE {name} counter")
        out.append(f"{name} {int(getattr(stats, k, 0))}")

    wall = stats.wall
    gauges = [
        ("wall_seconds", "serving wall-clock seconds", wall),
        ("slots", "decode slot capacity", getattr(stats, "slots", 0)),
    ]
    if queue_depth is not None:
        gauges.append(("queue_depth", "requests waiting for admission",
                       queue_depth))
    if slots_busy is not None:
        gauges.append(("slots_busy", "slots prefilling or decoding",
                       slots_busy))
    d = stats.as_dict()
    for k in ("tokens_per_s", "requests_per_s", "spec_accept_rate",
              "spec_k_mean", "queue_depth_mean", "slot_occupancy"):
        v = d.get(k)
        if v is not None:
            gauges.append((k, k.replace("_", " "), v))
    for k, help_, v in gauges:
        name = f"{_PREFIX}{k}"
        out.append(f"# HELP {name} {help_}")
        out.append(f"# TYPE {name} gauge")
        out.append(f"{name} {_fmt(v)}")

    _hist_lines(out, f"{_PREFIX}ttft_seconds", "time to first token",
                stats.ttft)
    _hist_lines(out, f"{_PREFIX}tpot_seconds", "time per output token",
                stats.tpot)
    _hist_lines(out, f"{_PREFIX}latency_seconds", "request latency",
                stats.latency)

    if phase_seconds:
        name = f"{_PREFIX}phase_seconds_total"
        out.append(f"# HELP {name} cumulative wall seconds per step phase")
        out.append(f"# TYPE {name} counter")
        for ph in sorted(phase_seconds):
            out.append(f'{name}{{phase="{ph}"}} {_fmt(phase_seconds[ph])}')

    if pool_shards:
        for k, help_ in _SHARD_GAUGES.items():
            name = f"{_PREFIX}pool_{k}"
            out.append(f"# HELP {name} {help_} (per data shard)")
            out.append(f"# TYPE {name} gauge")
            for i, sh in enumerate(pool_shards):
                out.append(f'{name}{{shard="{i}"}} {int(sh.get(k, 0))}')

    if arena:
        _arena_lines(out, arena)

    if remote_stats:
        name = f"{_PREFIX}mesh"
        out.append(f"# HELP {name}_counters per-rank mesh counters")
        for k in _SNAPSHOT_COUNTERS:
            fam = f"{name}_{k}_total"
            out.append(f"# TYPE {fam} counter")
            for rank in sorted(remote_stats):
                snap = remote_stats[rank]
                out.append(f'{fam}{{rank="{rank}"}} {int(snap.get(k, 0))}')
        fam = f"{name}_pool_high_water_blocks"
        out.append(f"# HELP {fam} peak KV pages per rank and data shard")
        out.append(f"# TYPE {fam} gauge")
        for rank in sorted(remote_stats):
            for i, sh in enumerate(remote_stats[rank].get("shards", [])):
                out.append(
                    f'{fam}{{rank="{rank}",shard="{i}"}} '
                    f"{int(sh.get('high_water_blocks', 0))}"
                )
        ranked = [(r, remote_stats[r]["arena"])
                  for r in sorted(remote_stats)
                  if remote_stats[r].get("arena")]
        if ranked:
            _mesh_arena_lines(out, ranked)
    return "\n".join(out) + "\n"


def scheduler_prometheus(sched: Any) -> str:
    """Prometheus text for a live scheduler (stats + pool + mesh +
    phases + online-LTFB arena when one is attached)."""
    tel = getattr(sched, "telemetry", None)
    arena = getattr(sched, "arena", None)
    return prometheus_text(
        sched.stats,
        pool_shards=_pool_shards(sched),
        phase_seconds=tel.phase_seconds if tel is not None else None,
        remote_stats=getattr(sched, "remote_stats", None),
        queue_depth=len(getattr(sched, "queue", ())),
        slots_busy=len(getattr(sched, "active", ()))
        + len(getattr(sched, "prefilling", ())),
        arena=arena.counters() if arena is not None else None,
    )
