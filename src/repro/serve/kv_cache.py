"""Cache layouts + block/paged KV-cache management for serving.

The decode surface is ONE protocol: a :class:`CacheLayout` owns the
physical cache pytree and answers, per layer,

  * **init**     — build the cache leaves (slot rows or page pools);
  * **write**    — where a request's prefilled KV/state lands
    (``insert`` / ``insert_prefill``) and how decode steps address it
    (``tables`` for paged, per-row indices for slots);
  * **read**     — the kwargs a decode step needs (``step_kwargs``);
  * **snapshot / restore** — copy-out / masked copy-back of the
    RECURRENT leaves (mamba / xLSTM state), the rollback primitive
    speculative decoding is built on.  Attention KV needs no rollback:
    stale positions past a row's length are causally masked and
    overwritten on the next write.

Two implementations, both driven through
:class:`repro.serve.session.DecodeSession`:

``SlotLayout``
    Dense rows: ``num_slots x max_len`` attention KV + per-slot
    recurrent state (the PR-2 layout, kept as the ``layout="dense"``
    baseline the fig14 benchmark measures the paged path against).

``PagedLayout``
    Per attention layer ONE ``(num_pages + 1, block_size, n_kv_heads,
    head_dim)`` pool (``repro.models.lm.init_cache(..., pages=...)``;
    the +1 is the null page), plus the host-side block tables the
    gather-decode kernel reads.  A request's pages can live anywhere in
    the pool — there is no per-slot ``max_len`` row, so a single
    request may use the entire pool.  Recurrent-layer state (O(1) per
    request) stays in dense per-slot rows.

    **Prefix sharing (copy-on-admit):** after a request prefills, its
    fully-filled prompt pages are registered in a prefix cache keyed by
    the token chain they hold; a later request whose prompt starts with
    the same pages maps them read-only into its own table (refcount++)
    and prefills only the suffix.  Shared pages are immutable by
    construction — decode appends strictly after the prompt and the
    partially-filled tail page is never shared — so no copy is ever
    needed.  With ``pin_prefix=True`` registered prompt pages are
    additionally PINNED: they survive idle periods (no live holder) in
    an eviction-priority tier and are reclaimed oldest-first only under
    allocation pressure.

``BlockManager``
    Page accounting in units of ``block_size`` tokens over a fixed page
    pool — vLLM-style bookkeeping with two layers of truth:

    * **reservations** — admission is by token budget: a request is
      admitted only when its full reservation (prompt + max new tokens)
      fits under the pool size minus everything already committed, so
      the scheduler never has to preempt mid-stream;
    * **physical pages** — materialized lazily (``ensure``): prompt
      pages as prefill reaches them, decode pages when generation
      crosses a page boundary.  A request that stops early (EOS) never
      claims the tail of its reservation, and the high-water mark
      measures pages actually touched.

    Pages are **refcounted** so prefix sharing can map one physical
    page into several requests' tables; a page returns to the free list
    when its last holder releases it — unless it is pinned, in which
    case it idles in the reclaim tier.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Pages needed to hold `n_tokens` cache entries."""
    return max(1, -(-int(n_tokens) // int(block_size)))


@dataclass
class BlockManager:
    """Token-budget page accounting over a fixed pool of cache blocks."""

    num_blocks: int
    block_size: int
    _free: List[int] = field(default_factory=list)
    _tables: Dict[Any, List[int]] = field(default_factory=dict)
    # pages a request may still claim from the free list (its
    # reservation minus what it has already materialized); admission
    # budgets against free - sum(_pending), so shared pages cost the
    # pool ONCE no matter how many tables map them — that is the
    # prefix-sharing capacity win
    _pending: Dict[Any, int] = field(default_factory=dict)
    _refs: Dict[int, int] = field(default_factory=dict)
    # eviction-priority tier: pages held alive ONLY by a pin (insertion
    # order = pin age); reclaimed oldest-first under allocation
    # pressure, with ``on_reclaim`` notifying the owner (prefix cache)
    _pinned: Dict[int, None] = field(default_factory=dict)
    on_reclaim: Optional[Callable[[List[int]], None]] = None
    high_water: int = 0
    allocs: int = 0
    frees: int = 0
    reclaims: int = 0

    def __post_init__(self):
        self._free = list(range(self.num_blocks))

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    @property
    def pending_blocks(self) -> int:
        """Free-list pages promised to live requests but not yet
        materialized (lazy allocation)."""
        return sum(self._pending.values())

    @property
    def committed_blocks(self) -> int:
        """Blocks spoken for: materialized + promised."""
        return self.used_blocks + self.pending_blocks

    @property
    def reclaimable_blocks(self) -> int:
        """Pinned pages with no live holder — the eviction-priority
        tier: counted as capacity for admission, stolen only when the
        free list runs dry."""
        return sum(1 for b in self._pinned if self._refs.get(b) == 1)

    @property
    def available_blocks(self) -> int:
        """Pages an admission may budget against: free-list pages not
        promised to anyone, plus idle pinned pages (reclaimable)."""
        return len(self._free) + self.reclaimable_blocks \
            - self.pending_blocks

    def table(self, rid) -> List[int]:
        return list(self._tables[rid])

    def _lost_reclaimable(self, shared: Sequence[int]) -> int:
        """Idle pinned pages in `shared`: mapping them refcounts them to
        2, so they stop being reclaimable — admission must not count
        them BOTH as free prefix pages and as reclaimable capacity."""
        return sum(1 for b in set(shared)
                   if b in self._pinned and self._refs.get(b) == 1)

    def can_allocate(self, n_tokens: int,
                     shared: Sequence[int] = ()) -> bool:
        need = blocks_for(n_tokens, self.block_size) - len(shared)
        return need <= self.available_blocks \
            - self._lost_reclaimable(shared)

    # -- pinning (prefix residency) ----------------------------------------
    def pin(self, page: int) -> None:
        """Keep `page` resident after its last holder releases it (an
        extra refcount held by the pin)."""
        if page not in self._pinned and page in self._refs:
            self._refs[page] += 1
            self._pinned[page] = None

    def unpin_all(self) -> List[int]:
        """Drop every pin; returns the pages that hit refcount zero
        (returned to the free list) — the hot-swap flush path."""
        released = []
        for page in list(self._pinned):
            self._refs[page] -= 1
            if self._refs[page] == 0:
                del self._refs[page]
                self._free.append(page)
                released.append(page)
        self._pinned.clear()
        self.frees += len(released)
        return released

    def _reclaim(self, n: int) -> None:
        """Steal `n` idle pinned pages (oldest pin first) back onto the
        free list; the owner is told via ``on_reclaim`` so it can drop
        the pages from its prefix cache.  Candidates are collected
        BEFORE any mutation, so an insufficient tier raises with the
        pin bookkeeping (and the owner's prefix cache) fully intact."""
        taken = [page for page in self._pinned
                 if self._refs.get(page) == 1][:n]
        if len(taken) < n:
            raise RuntimeError(
                f"out of cache blocks: need {n - len(taken)} more, "
                f"free {len(self._free)}")
        for page in taken:
            del self._pinned[page]
            del self._refs[page]
            self._free.append(page)
        self.reclaims += len(taken)
        if self.on_reclaim is not None:
            self.on_reclaim(taken)

    def _claim(self, rid, n: int) -> List[int]:
        if n > len(self._free):
            self._reclaim(n - len(self._free))
        got = [self._free.pop() for _ in range(n)]
        for b in got:
            self._refs[b] = 1
        self._tables[rid].extend(got)
        self._pending[rid] -= n
        self.allocs += n
        self.high_water = max(self.high_water, self.used_blocks)
        return got

    def allocate(self, rid, n_tokens: int) -> List[int]:
        """Reserve AND materialize pages for `n_tokens` (eager; the
        dense pool path).  Raises if rid is live or over budget."""
        if rid in self._tables:
            raise ValueError(f"request {rid!r} already holds blocks")
        need = blocks_for(n_tokens, self.block_size)
        if not self.can_allocate(n_tokens):
            raise RuntimeError(
                f"out of cache blocks: need {need}, "
                f"available {self.available_blocks}")
        self._tables[rid] = []
        self._pending[rid] = need
        return self._claim(rid, need)

    def reserve(self, rid, n_tokens: int,
                shared: Sequence[int] = ()) -> None:
        """Budget `n_tokens` for `rid`, mapping `shared` pages (already
        live, refcounted up) as its first pages; the rest materialize
        lazily via :meth:`ensure`.  Shared pages are free — they are
        someone's materialized pages already."""
        if rid in self._tables:
            raise ValueError(f"request {rid!r} already holds blocks")
        need = blocks_for(n_tokens, self.block_size) - len(shared)
        # refcounting the shared pages removes any idle pinned ones
        # from the reclaim tier — budget as if that already happened
        usable = self.available_blocks - self._lost_reclaimable(shared)
        if need > usable:
            raise RuntimeError(
                f"out of cache blocks: need {need}, available {usable}")
        for b in shared:
            self._refs[b] += 1
        self._tables[rid] = list(shared)
        self._pending[rid] = need
        self.high_water = max(self.high_water, self.used_blocks)

    def ensure(self, rid, n_tokens: int) -> List[int]:
        """Materialize physical pages so `rid` can hold `n_tokens`;
        returns the newly claimed page ids (page-overflow allocation).
        Growing past the reservation raises — the scheduler budgets
        prompt + max_new up front precisely so this cannot happen."""
        have = self._tables[rid]
        need = blocks_for(n_tokens, self.block_size) - len(have)
        if need <= 0:
            return []
        if need > self._pending[rid]:
            raise RuntimeError(
                f"request {rid!r} overflows its reservation "
                f"({len(have) + self._pending[rid]} blocks)")
        return self._claim(rid, need)

    def extend(self, rid, n_tokens: int) -> List[int]:
        """Grow a live reservation to cover `n_tokens` total and
        materialize the new pages."""
        need = blocks_for(n_tokens, self.block_size) \
            - len(self._tables[rid])
        if need > self._pending[rid]:
            grow = need - self._pending[rid]
            if grow > self.available_blocks:
                raise RuntimeError(
                    f"out of cache blocks: need {grow}, "
                    f"available {self.available_blocks}")
            self._pending[rid] = need
        return self.ensure(rid, n_tokens)

    def free(self, rid) -> List[int]:
        """Release `rid`'s pages; returns the page ids whose refcount
        hit zero (returned to the free list)."""
        blocks = self._tables.pop(rid)
        self._pending.pop(rid)
        released = []
        for b in blocks:
            self._refs[b] -= 1
            if self._refs[b] == 0:
                del self._refs[b]
                self._free.append(b)
                released.append(b)
        self.frees += len(released)
        return released

    def refcount(self, page: int) -> int:
        return self._refs.get(page, 0)

    def as_dict(self) -> Dict[str, int]:
        return {"num_blocks": self.num_blocks,
                "block_size": self.block_size,
                "used_blocks": self.used_blocks,
                "committed_blocks": self.committed_blocks,
                "pinned_blocks": len(self._pinned),
                "block_reclaims": self.reclaims,
                "high_water_blocks": self.high_water,
                "block_allocs": self.allocs, "block_frees": self.frees}


# ---------------------------------------------------------------------------
# CacheLayout protocol
# ---------------------------------------------------------------------------


def _leaf_is_paged(axes_leaf) -> bool:
    return isinstance(axes_leaf, tuple) and "pages" in axes_leaf


def _leaf_is_kv(axes_leaf) -> bool:
    """Attention KV leaves (either layout); everything else is the
    recurrent state snapshot/restore copies."""
    return isinstance(axes_leaf, tuple) and \
        ("pages" in axes_leaf or "kv_seq" in axes_leaf)


def _axes_leaves(axes):
    is_leaf = (lambda t: isinstance(t, tuple)
               and all(x is None or isinstance(x, str) for x in t))
    return jax.tree.leaves(axes, is_leaf=is_leaf)


@partial(jax.jit, static_argnums=(2,), donate_argnums=(0,))
def _restore_rec(cache, snap, rec_mask, rows):
    """Masked copy-back of recurrent leaves: rows[b] selects the
    snapshot for slot b (leaves are (stack, num_slots, ...))."""
    flat, tree = jax.tree.flatten(cache)
    it = iter(snap)
    out = []
    for leaf, m in zip(flat, rec_mask):
        if m:
            s = next(it)
            sel = rows.reshape((1, rows.shape[0]) + (1,) * (leaf.ndim - 2))
            out.append(jnp.where(sel, s, leaf))
        else:
            out.append(leaf)
    return jax.tree.unflatten(tree, out)


class CacheLayout:
    """Family-agnostic cache protocol the serving stack decodes through.

    A layout owns the physical cache pytree and implements, per layer
    leaf, the five operations :class:`repro.serve.session.
    DecodeSession` is written against:

    ==========  =========================================================
    init        build the cache leaves (``lm.init_cache``, dense or
                ``pages=``)
    write       land prefilled KV/state (``insert`` / ``insert_prefill``)
                and route decode-step writes (slot rows / block tables)
    read        ``step_kwargs()`` — the extra arrays one decode step
                needs (``tables`` for paged, nothing for slots)
    snapshot    copy out the recurrent leaves (mamba / xLSTM state)
    restore     masked copy-back per slot — the speculative-decoding
                rollback primitive (attention KV never rolls back: stale
                positions are causally masked and overwritten)
    ==========  =========================================================

    Slot bookkeeping (`admit` / `release` / `slot_of`) is shared here;
    page accounting is the paged subclass's :class:`BlockManager`.
    """

    cfg: ModelConfig
    num_slots: int
    cache: Any
    rec_mask: Tuple[bool, ...]

    def _init_slots(self, num_slots: int) -> None:
        self.num_slots = num_slots
        self._free_slots = list(range(num_slots))
        self._slot_of: Dict[Any, int] = {}

    @property
    def free_slots(self) -> int:
        return len(self._free_slots)

    def slot_of(self, rid) -> int:
        return self._slot_of[rid]

    @property
    def has_recurrent(self) -> bool:
        """True when the stack carries per-slot recurrent state (hybrid
        / ssm families) — the leaves snapshot/restore operates on."""
        return any(self.rec_mask)

    @property
    def supports_row_subset(self) -> bool:
        """True when a decode step may cover any subset of rows (no
        cache leaf is indexed by slot) — what lets the scheduler group
        ragged rows by gather width."""
        return False

    def step_kwargs(self, width: Optional[int] = None,
                    rows: Optional[np.ndarray] = None) -> Dict[str, Any]:
        """Extra per-step arrays for :func:`repro.models.lm.lm_decode`."""
        return {}

    def snapshot(self) -> Tuple[jax.Array, ...]:
        """Copy of the recurrent leaves (empty for attention-only
        stacks, where rollback is free)."""
        flat = jax.tree.leaves(self.cache)
        return tuple(jnp.array(x, copy=True)
                     for x, m in zip(flat, self.rec_mask) if m)

    def restore(self, snap: Tuple[jax.Array, ...], rows) -> None:
        """Roll slots with ``rows[b] == True`` back to ``snap``."""
        if not snap:
            return
        self.cache = _restore_rec(self.cache, snap, self.rec_mask,
                                  jnp.asarray(np.asarray(rows, bool)))


# ---------------------------------------------------------------------------
# paged physical pool
# ---------------------------------------------------------------------------


def _insert_leaf_paged(dst, src, page_ids, offsets):
    """Scatter a (stack, 1, S, Hkv, D) dense prefill leaf into the
    (stack, P+1, bs, Hkv, D) pool at (page_ids[s], offsets[s])."""
    return dst.at[:, page_ids, offsets].set(src[:, 0].astype(dst.dtype))


def _insert_leaf_slot(dst, src, slot):
    """Write a (stack, 1, ...) recurrent-state leaf into pool row `slot`."""
    start = (0, jnp.asarray(slot, jnp.int32)) + (0,) * (dst.ndim - 2)
    return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), start)


@partial(jax.jit, static_argnums=(1,), donate_argnums=(0,))
def _insert_tree_paged(pool, paged_mask, src, page_ids, offsets, slot):
    flat_pool, tree = jax.tree.flatten(pool)
    flat_src = jax.tree.leaves(src)
    out = [
        _insert_leaf_paged(d, s, page_ids, offsets) if paged
        else _insert_leaf_slot(d, s, slot)
        for d, s, paged in zip(flat_pool, flat_src, paged_mask)]
    return jax.tree.unflatten(tree, out)


class PagedLayout(CacheLayout):
    """Paged decode cache: shared page pools + per-slot block tables.

    ``num_slots`` bounds the decode batch width (and the number of
    recurrent-state rows); memory capacity is ``num_pages *
    block_size`` tokens shared by every request.  ``max_seq`` caps a
    single request (it sizes the block-table width) and defaults to the
    whole pool — the per-slot ``max_len`` ceiling of the dense layout
    is gone.  With ``pin_prefix=True`` registered prompt pages stay
    resident after their holders release (reclaimed oldest-first under
    pressure).
    """

    def __init__(self, cfg: ModelConfig, num_slots: int, num_pages: int,
                 block_size: int = 16, max_seq: Optional[int] = None,
                 pin_prefix: bool = False):
        self.cfg = cfg
        self.block_size = block_size
        self.max_seq = min(max_seq or num_pages * block_size,
                           num_pages * block_size)
        self.max_blocks_per_seq = blocks_for(self.max_seq, block_size)
        self.blocks = BlockManager(num_pages, block_size)
        self.blocks.on_reclaim = self._evict
        self.null_page = num_pages
        self.pin_prefix = bool(pin_prefix)
        self.cache, axes = lm.init_cache(cfg, num_slots,
                                         pages=(num_pages, block_size))
        self.paged_mask = tuple(_leaf_is_paged(a)
                                for a in _axes_leaves(axes))
        self.rec_mask = tuple(not _leaf_is_kv(a)
                              for a in _axes_leaves(axes))
        self.tables = np.full((num_slots, self.max_blocks_per_seq),
                              self.null_page, np.int32)
        self._init_slots(num_slots)
        # prefix cache: chained token-chunk key -> canonical physical
        # page, plus every live page known to hold that content (a
        # follower that prefilled its own copy before the prefix was
        # registered is still a valid donor once the original dies)
        self._prefix: Dict[Any, int] = {}
        self._key_pages: Dict[Any, set] = {}
        self._page_key: Dict[int, Any] = {}
        # per-rid incremental registration cursor: (pages done, last key)
        self._reg_state: Dict[Any, Tuple[int, Any]] = {}
        # weight epoch: bumped by invalidate_prefix() on hot swap so
        # pages computed under old weights are never shared forward
        self._epoch = 0
        self._admit_epoch: Dict[Any, int] = {}
        self.prefix_hits = 0
        self.prefix_shared_tokens = 0

    # -- prefix sharing ----------------------------------------------------
    @staticmethod
    def _chunk_keys(prompt: np.ndarray, block_size: int, start: int = 0,
                    prev=None):
        """Chained keys for fully-filled prompt pages ``start..``: key_i
        commits to ALL tokens up to and including page i (so equal keys
        mean equal prefixes, not just equal pages).  ``prev`` must be
        the chain key of page ``start - 1`` when resuming."""
        keys = []
        for i in range(start, len(prompt) // block_size):
            chunk = tuple(int(t) for t in
                          prompt[i * block_size:(i + 1) * block_size])
            prev = (prev, chunk)
            keys.append(prev)
        return keys

    def find_shared_prefix(self, prompt: np.ndarray
                           ) -> Tuple[List[int], int]:
        """Longest registered prefix of `prompt` in live pages.

        Returns (page ids, shared token count).  Capped at
        ``len(prompt) - 1`` so at least one suffix token is always
        prefilled (its hidden state supplies the first sampled token).
        Keys are derived lazily page by page, so a miss on page 0 costs
        one chunk hash — this runs on every admission check.
        """
        bs = self.block_size
        max_pages = (len(prompt) - 1) // bs
        pages, key = [], None
        for i in range(max_pages):
            key = (key, tuple(int(t) for t in prompt[i * bs:(i + 1) * bs]))
            page = self._prefix.get(key)
            if page is None or self.blocks.refcount(page) == 0:
                break
            pages.append(page)
        return pages, len(pages) * bs

    def register_prefix(self, rid, prompt: np.ndarray) -> None:
        """Offer `rid`'s fully-filled prompt pages to future requests.

        Incremental: per-chunk calls during chunked prefill only hash
        the pages filled since the last call, resuming the key chain
        instead of re-deriving it from page 0 every time.  Requests
        admitted before the last weight swap are refused — their pages
        (or their pages' attention context) came from the old model.
        """
        if self._admit_epoch.get(rid, -1) != self._epoch:
            return
        table = self.blocks.table(rid)
        start, prev = self._reg_state.get(rid, (0, None))
        keys = self._chunk_keys(prompt, self.block_size, start=start,
                                prev=prev)
        for i, key in zip(range(start, start + len(keys)), keys):
            if i >= len(table):
                break
            page = table[i]
            if self._page_key.get(page) != key:
                self._page_key[page] = key
                self._key_pages.setdefault(key, set()).add(page)
                self._prefix.setdefault(key, page)
            if self.pin_prefix:
                # eviction-priority residency: the page survives its
                # holders (reclaimed oldest-first under pressure)
                self.blocks.pin(page)
            self._reg_state[rid] = (i + 1, key)

    def _evict(self, released_pages: List[int]) -> None:
        """Drop freed pages from the prefix cache; if a freed page was
        the canonical holder of its key, re-point the key at another
        live copy before giving up on it."""
        for page in released_pages:
            key = self._page_key.pop(page, None)
            if key is None:
                continue
            copies = self._key_pages.get(key, set())
            copies.discard(page)
            if self._prefix.get(key) == page:
                if copies:
                    self._prefix[key] = next(iter(copies))
                else:
                    self._prefix.pop(key, None)
            if not copies:
                self._key_pages.pop(key, None)

    # -- slot / page lifecycle ---------------------------------------------
    @property
    def supports_row_subset(self) -> bool:
        # with no recurrent rows, every cache leaf is a shared pool —
        # a decode step may cover any subset of slots (ragged grouping)
        return not self.has_recurrent

    def step_kwargs(self, width: Optional[int] = None,
                    rows: Optional[np.ndarray] = None) -> Dict[str, Any]:
        W = width if width is not None else self.max_blocks_per_seq
        tables = self.tables if rows is None else self.tables[rows]
        return {"tables": jnp.asarray(tables[:, :W])}

    def can_admit(self, n_tokens: int,
                  shared_pages: Sequence[int] = ()) -> bool:
        return bool(self._free_slots) and n_tokens <= self.max_seq \
            and self.blocks.can_allocate(n_tokens, shared=shared_pages)

    def admit(self, rid, n_tokens: int,
              prompt: Optional[np.ndarray] = None,
              shared: Optional[Tuple[List[int], int]] = None
              ) -> Tuple[int, int]:
        """Claim a slot + a token-budget reservation for `rid`.

        With `prompt` given, maps any prefix-cached pages into the new
        table (copy-on-admit sharing); pass ``shared`` to reuse a
        :meth:`find_shared_prefix` result the admission check already
        computed instead of hashing the prompt again.  Returns
        (slot, shared_len).
        """
        if not self._free_slots:
            raise RuntimeError("no free cache slots")
        if n_tokens > self.max_seq:
            raise ValueError(
                f"request needs {n_tokens} tokens > pool max_seq "
                f"{self.max_seq}")
        if shared is None:
            shared = ([], 0) if prompt is None else \
                self.find_shared_prefix(prompt)
        shared_pages, shared_len = shared
        self.blocks.reserve(rid, n_tokens, shared=shared_pages)
        slot = self._free_slots.pop()
        self._slot_of[rid] = slot
        self._admit_epoch[rid] = self._epoch
        self.tables[slot, :] = self.null_page
        if shared_pages:
            self.tables[slot, :len(shared_pages)] = shared_pages
            self.prefix_hits += 1
            self.prefix_shared_tokens += shared_len
            # registration resumes after the shared pages — their keys
            # are already in the cache
            self._reg_state[rid] = (len(shared_pages),
                                    self._page_key[shared_pages[-1]])
        return slot, shared_len

    def ensure(self, rid, n_tokens: int) -> None:
        """Materialize pages so `rid` can hold `n_tokens`; updates the
        slot's block table in place."""
        slot = self._slot_of[rid]
        have = len(self.blocks.table(rid))
        new = self.blocks.ensure(rid, n_tokens)
        if new:
            self.tables[slot, have:have + len(new)] = new

    def insert_prefill(self, rid, prefill_cache, prompt_len: int) -> None:
        """Scatter a (batch=1) dense prefill cache into the pool.

        The one-shot path for recurrent/hybrid families: attention
        leaves scatter token s into (table[s // bs], s % bs); recurrent
        state leaves overwrite the request's slot row.
        """
        self.ensure(rid, prompt_len)
        slot = self._slot_of[rid]
        table = self.blocks.table(rid)
        # per-token page targets; positions past prompt_len (padding)
        # are dropped onto the null page
        kv_len = _first_kv_len(prefill_cache, self.paged_mask)
        if kv_len is None:          # pure-recurrent stack: no KV pages
            kv_len = prompt_len
        pos = np.arange(kv_len)
        pids = np.full((kv_len,), self.null_page, np.int32)
        valid = pos < prompt_len
        pids[valid] = np.asarray(table, np.int32)[pos[valid]
                                                  // self.block_size]
        offs = (pos % self.block_size).astype(np.int32)
        self.cache = _insert_tree_paged(
            self.cache, self.paged_mask, prefill_cache,
            jnp.asarray(pids), jnp.asarray(offs), jnp.int32(slot))

    def release(self, rid) -> int:
        """Free `rid`'s slot + page refs; returns the freed slot."""
        slot = self._slot_of.pop(rid)
        self._free_slots.append(slot)
        self.tables[slot, :] = self.null_page
        self._reg_state.pop(rid, None)
        self._admit_epoch.pop(rid, None)
        self._evict(self.blocks.free(rid))
        return slot

    def invalidate_prefix(self) -> None:
        """Flush the prefix cache (hot swap): pages computed under the
        old weights must not be mapped into post-swap admissions, and
        still-prefilling pre-swap requests stop registering (their
        remaining chunks attend over old-weight history).  Pins die
        with the index — a pinned page's whole value is being shareable.
        Live tables and refcounts are untouched."""
        self._prefix.clear()
        self._key_pages.clear()
        self._page_key.clear()
        self.blocks.unpin_all()
        self._epoch += 1

    def table_width_for(self, max_tokens: int) -> int:
        """Block-table columns needed to cover `max_tokens` (the
        scheduler buckets this so gather width tracks the batch's true
        maximum instead of always paying max_blocks_per_seq)."""
        return min(self.max_blocks_per_seq,
                   blocks_for(max(max_tokens, 1), self.block_size))

    def as_dict(self) -> Dict[str, int]:
        return {"num_slots": self.num_slots, "max_seq": self.max_seq,
                "free_slots": self.free_slots,
                "prefix_hits": self.prefix_hits,
                "prefix_shared_tokens": self.prefix_shared_tokens,
                **self.blocks.as_dict()}


def _first_kv_len(prefill_cache, paged_mask) -> Optional[int]:
    """Sequence length of the first attention leaf of a dense (batch=1)
    prefill cache: leaves are (stack, 1, S, Hkv, D).  None for pure-
    recurrent stacks (xLSTM), whose cache is all per-slot state rows."""
    for leaf, paged in zip(jax.tree.leaves(prefill_cache),
                           paged_mask):
        if paged:
            return int(leaf.shape[2])
    return None


# ---------------------------------------------------------------------------
# dense slot layout (the PR-2 baseline, kept for layout="dense")
# ---------------------------------------------------------------------------


def _insert_row(dst: jax.Array, src: jax.Array, slot) -> jax.Array:
    """Write `src` (leading (layers, 1, ...)) into pool row `slot`.

    Every cache leaf is (layers, batch, *state); attention leaves carry
    a kv_seq axis shorter than the pool's max_len at prefill time — pad
    with zeros so the whole row is overwritten (slot reuse must not
    leak the previous occupant's cache).
    """
    if src.shape[2:] != dst.shape[2:]:
        pad = [(0, 0), (0, 0)] + [(0, d - s)
                                  for d, s in zip(dst.shape[2:], src.shape[2:])]
        src = jnp.pad(src, pad)
    start = (0, jnp.asarray(slot, jnp.int32)) + (0,) * (dst.ndim - 2)
    return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), start)


# the pool is donated: the caller always rebinds CachePool.cache to the
# result, so the update happens in place instead of copying the whole
# preallocated pool on every request admission
@partial(jax.jit, donate_argnums=(0,))
def _insert_tree(pool, src, slot):
    return jax.tree.map(lambda d, s: _insert_row(d, s, slot), pool, src)


@partial(jax.jit, donate_argnums=(0,))
def _insert_tree_batch(pool, src):
    return jax.tree.map(lambda d, s: _insert_row(d, s, 0), pool, src)


class SlotLayout(CacheLayout):
    """One preallocated dense decode cache shared by all requests.

    ``cache`` holds `num_slots` rows of `max_len` tokens (allocated once
    at construction via :func:`repro.models.lm.init_cache`); slot and
    page lifetime are managed here so the scheduler only deals in
    request ids.  Pages are bookkeeping only — a request's cache is its
    contiguous slot row, which is what the paged layout replaces.
    """

    def __init__(self, cfg: ModelConfig, num_slots: int, max_len: int,
                 block_size: int = 16, num_blocks: Optional[int] = None):
        self.cfg = cfg
        self.max_len = max_len
        self.blocks = BlockManager(
            num_blocks if num_blocks is not None
            else num_slots * blocks_for(max_len, block_size),
            block_size)
        self.cache, axes = lm.init_cache(cfg, num_slots, max_len)
        self.rec_mask = tuple(not _leaf_is_kv(a)
                              for a in _axes_leaves(axes))
        self._init_slots(num_slots)

    def can_admit(self, n_tokens: int) -> bool:
        """Room for a request reserving `n_tokens` (prompt + max new)?"""
        return bool(self._free_slots) and n_tokens <= self.max_len \
            and self.blocks.can_allocate(n_tokens)

    def admit(self, rid, n_tokens: int) -> int:
        """Claim a slot + pages for `rid`; returns the slot index."""
        if not self._free_slots:
            raise RuntimeError("no free cache slots")
        if n_tokens > self.max_len:
            raise ValueError(
                f"request needs {n_tokens} tokens > pool max_len "
                f"{self.max_len}")
        self.blocks.allocate(rid, n_tokens)
        slot = self._free_slots.pop()
        self._slot_of[rid] = slot
        return slot

    def insert(self, rid, prefill_cache) -> None:
        """Overwrite `rid`'s slot row with a (batch=1) prefilled cache."""
        self.cache = _insert_tree(self.cache, prefill_cache,
                                  jnp.int32(self._slot_of[rid]))

    def insert_batch(self, prefill_cache) -> None:
        """Overwrite ALL slot rows with a (batch=num_slots) prefilled
        cache — the engine path, where one uniform-length batch fills
        the whole pool at once."""
        B = jax.tree.leaves(prefill_cache)[0].shape[1]
        assert B == self.num_slots, (B, self.num_slots)
        self.cache = _insert_tree_batch(self.cache, prefill_cache)

    def release(self, rid) -> int:
        """Free `rid`'s slot + pages; returns the freed slot index."""
        slot = self._slot_of.pop(rid)
        self._free_slots.append(slot)
        self.blocks.free(rid)
        return slot

    def as_dict(self) -> Dict[str, int]:
        return {"num_slots": self.num_slots, "max_len": self.max_len,
                "free_slots": self.free_slots, **self.blocks.as_dict()}


# legacy names (PR-2/PR-3): the pools ARE the layouts now
CachePool = SlotLayout
PagedCachePool = PagedLayout
