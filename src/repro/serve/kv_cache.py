"""Block/paged KV-cache management for the serving subsystem.

Three cooperating pieces:

``BlockManager``
    Page accounting in units of ``block_size`` tokens over a fixed page
    pool — vLLM-style bookkeeping with two layers of truth:

    * **reservations** — admission is by token budget: a request is
      admitted only when its full reservation (prompt + max new tokens)
      fits under the pool size minus everything already committed, so
      the scheduler never has to preempt mid-stream;
    * **physical pages** — materialized lazily (``ensure``): prompt
      pages as prefill reaches them, decode pages when generation
      crosses a page boundary.  A request that stops early (EOS) never
      claims the tail of its reservation, and the high-water mark
      measures pages actually touched.

    Pages are **refcounted** so prefix sharing can map one physical
    page into several requests' tables; a page returns to the free list
    when its last holder releases it.

``PagedCachePool``
    The physical cache for the paged decode path: per attention layer
    ONE ``(num_pages + 1, block_size, n_kv_heads, head_dim)`` pool
    (``repro.models.lm.init_paged_cache``; the +1 is the null page),
    plus the host-side block tables that :func:`repro.models.lm.
    lm_decode_paged` gathers through.  A request's pages can live
    anywhere in the pool — there is no per-slot ``max_len`` row, so a
    single request may use the entire pool.  Recurrent-layer state
    (O(1) per request) stays in dense per-slot rows.

    **Prefix sharing (copy-on-admit):** after a request prefills, its
    fully-filled prompt pages are registered in a prefix cache keyed by
    the token chain they hold; a later request whose prompt starts with
    the same pages maps them read-only into its own table (refcount++)
    and prefills only the suffix.  Shared pages are immutable by
    construction — decode appends strictly after the prompt and the
    partially-filled tail page is never shared — so no copy is ever
    needed.  Entries live as long as some request holds the page.

``CachePool``
    The PR-2 dense layout (``num_slots`` rows x ``max_len`` tokens),
    kept as the ``layout="dense"`` baseline the fig14 benchmark
    measures the paged path against.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Pages needed to hold `n_tokens` cache entries."""
    return max(1, -(-int(n_tokens) // int(block_size)))


@dataclass
class BlockManager:
    """Token-budget page accounting over a fixed pool of cache blocks."""

    num_blocks: int
    block_size: int
    _free: List[int] = field(default_factory=list)
    _tables: Dict[Any, List[int]] = field(default_factory=dict)
    # pages a request may still claim from the free list (its
    # reservation minus what it has already materialized); admission
    # budgets against free - sum(_pending), so shared pages cost the
    # pool ONCE no matter how many tables map them — that is the
    # prefix-sharing capacity win
    _pending: Dict[Any, int] = field(default_factory=dict)
    _refs: Dict[int, int] = field(default_factory=dict)
    high_water: int = 0
    allocs: int = 0
    frees: int = 0

    def __post_init__(self):
        self._free = list(range(self.num_blocks))

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    @property
    def pending_blocks(self) -> int:
        """Free-list pages promised to live requests but not yet
        materialized (lazy allocation)."""
        return sum(self._pending.values())

    @property
    def committed_blocks(self) -> int:
        """Blocks spoken for: materialized + promised."""
        return self.used_blocks + self.pending_blocks

    @property
    def available_blocks(self) -> int:
        """Free-list pages not promised to anyone."""
        return len(self._free) - self.pending_blocks

    def table(self, rid) -> List[int]:
        return list(self._tables[rid])

    def can_allocate(self, n_tokens: int, shared_blocks: int = 0) -> bool:
        need = blocks_for(n_tokens, self.block_size) - shared_blocks
        return need <= self.available_blocks

    def _claim(self, rid, n: int) -> List[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"out of cache blocks: need {n}, free {len(self._free)}")
        got = [self._free.pop() for _ in range(n)]
        for b in got:
            self._refs[b] = 1
        self._tables[rid].extend(got)
        self._pending[rid] -= n
        self.allocs += n
        self.high_water = max(self.high_water, self.used_blocks)
        return got

    def allocate(self, rid, n_tokens: int) -> List[int]:
        """Reserve AND materialize pages for `n_tokens` (eager; the
        dense pool path).  Raises if rid is live or over budget."""
        if rid in self._tables:
            raise ValueError(f"request {rid!r} already holds blocks")
        need = blocks_for(n_tokens, self.block_size)
        if not self.can_allocate(n_tokens):
            raise RuntimeError(
                f"out of cache blocks: need {need}, "
                f"available {self.available_blocks}")
        self._tables[rid] = []
        self._pending[rid] = need
        return self._claim(rid, need)

    def reserve(self, rid, n_tokens: int,
                shared: Sequence[int] = ()) -> None:
        """Budget `n_tokens` for `rid`, mapping `shared` pages (already
        live, refcounted up) as its first pages; the rest materialize
        lazily via :meth:`ensure`.  Shared pages are free — they are
        someone's materialized pages already."""
        if rid in self._tables:
            raise ValueError(f"request {rid!r} already holds blocks")
        need = blocks_for(n_tokens, self.block_size) - len(shared)
        if need > self.available_blocks:
            raise RuntimeError(
                f"out of cache blocks: need {need}, "
                f"available {self.available_blocks}")
        for b in shared:
            self._refs[b] += 1
        self._tables[rid] = list(shared)
        self._pending[rid] = need
        self.high_water = max(self.high_water, self.used_blocks)

    def ensure(self, rid, n_tokens: int) -> List[int]:
        """Materialize physical pages so `rid` can hold `n_tokens`;
        returns the newly claimed page ids (page-overflow allocation).
        Growing past the reservation raises — the scheduler budgets
        prompt + max_new up front precisely so this cannot happen."""
        have = self._tables[rid]
        need = blocks_for(n_tokens, self.block_size) - len(have)
        if need <= 0:
            return []
        if need > self._pending[rid]:
            raise RuntimeError(
                f"request {rid!r} overflows its reservation "
                f"({len(have) + self._pending[rid]} blocks)")
        return self._claim(rid, need)

    def extend(self, rid, n_tokens: int) -> List[int]:
        """Grow a live reservation to cover `n_tokens` total and
        materialize the new pages."""
        need = blocks_for(n_tokens, self.block_size) \
            - len(self._tables[rid])
        if need > self._pending[rid]:
            grow = need - self._pending[rid]
            if grow > self.available_blocks:
                raise RuntimeError(
                    f"out of cache blocks: need {grow}, "
                    f"available {self.available_blocks}")
            self._pending[rid] = need
        return self.ensure(rid, n_tokens)

    def free(self, rid) -> List[int]:
        """Release `rid`'s pages; returns the page ids whose refcount
        hit zero (returned to the free list)."""
        blocks = self._tables.pop(rid)
        self._pending.pop(rid)
        released = []
        for b in blocks:
            self._refs[b] -= 1
            if self._refs[b] == 0:
                del self._refs[b]
                self._free.append(b)
                released.append(b)
        self.frees += len(released)
        return released

    def refcount(self, page: int) -> int:
        return self._refs.get(page, 0)

    def as_dict(self) -> Dict[str, int]:
        return {"num_blocks": self.num_blocks,
                "block_size": self.block_size,
                "used_blocks": self.used_blocks,
                "committed_blocks": self.committed_blocks,
                "high_water_blocks": self.high_water,
                "block_allocs": self.allocs, "block_frees": self.frees}


# ---------------------------------------------------------------------------
# paged physical pool
# ---------------------------------------------------------------------------


def _leaf_is_paged(axes_leaf) -> bool:
    return isinstance(axes_leaf, tuple) and "pages" in axes_leaf


def _axes_leaves(axes):
    is_leaf = (lambda t: isinstance(t, tuple)
               and all(x is None or isinstance(x, str) for x in t))
    return jax.tree.leaves(axes, is_leaf=is_leaf)


def _insert_leaf_paged(dst, src, page_ids, offsets):
    """Scatter a (stack, 1, S, Hkv, D) dense prefill leaf into the
    (stack, P+1, bs, Hkv, D) pool at (page_ids[s], offsets[s])."""
    return dst.at[:, page_ids, offsets].set(src[:, 0].astype(dst.dtype))


def _insert_leaf_slot(dst, src, slot):
    """Write a (stack, 1, ...) recurrent-state leaf into pool row `slot`."""
    start = (0, jnp.asarray(slot, jnp.int32)) + (0,) * (dst.ndim - 2)
    return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), start)


@partial(jax.jit, static_argnums=(1,), donate_argnums=(0,))
def _insert_tree_paged(pool, paged_mask, src, page_ids, offsets, slot):
    flat_pool, tree = jax.tree.flatten(pool)
    flat_src = jax.tree.leaves(src)
    out = [
        _insert_leaf_paged(d, s, page_ids, offsets) if paged
        else _insert_leaf_slot(d, s, slot)
        for d, s, paged in zip(flat_pool, flat_src, paged_mask)]
    return jax.tree.unflatten(tree, out)


class PagedCachePool:
    """Paged decode cache: shared page pools + per-slot block tables.

    ``num_slots`` bounds the decode batch width (and the number of
    recurrent-state rows); memory capacity is ``num_pages *
    block_size`` tokens shared by every request.  ``max_seq`` caps a
    single request (it sizes the block-table width) and defaults to the
    whole pool — the per-slot ``max_len`` ceiling of the dense layout
    is gone.
    """

    def __init__(self, cfg: ModelConfig, num_slots: int, num_pages: int,
                 block_size: int = 16, max_seq: Optional[int] = None):
        self.cfg = cfg
        self.num_slots = num_slots
        self.block_size = block_size
        self.max_seq = min(max_seq or num_pages * block_size,
                           num_pages * block_size)
        self.max_blocks_per_seq = blocks_for(self.max_seq, block_size)
        self.blocks = BlockManager(num_pages, block_size)
        self.null_page = num_pages
        self.cache, axes = lm.init_paged_cache(cfg, num_slots, num_pages,
                                               block_size)
        self.paged_mask = tuple(_leaf_is_paged(a)
                                for a in _axes_leaves(axes))
        self.tables = np.full((num_slots, self.max_blocks_per_seq),
                              self.null_page, np.int32)
        self._free_slots = list(range(num_slots))
        self._slot_of: Dict[Any, int] = {}
        # prefix cache: chained token-chunk key -> canonical physical
        # page, plus every live page known to hold that content (a
        # follower that prefilled its own copy before the prefix was
        # registered is still a valid donor once the original dies)
        self._prefix: Dict[Any, int] = {}
        self._key_pages: Dict[Any, set] = {}
        self._page_key: Dict[int, Any] = {}
        # per-rid incremental registration cursor: (pages done, last key)
        self._reg_state: Dict[Any, Tuple[int, Any]] = {}
        # weight epoch: bumped by invalidate_prefix() on hot swap so
        # pages computed under old weights are never shared forward
        self._epoch = 0
        self._admit_epoch: Dict[Any, int] = {}
        self.prefix_hits = 0
        self.prefix_shared_tokens = 0

    # -- prefix sharing ----------------------------------------------------
    @staticmethod
    def _chunk_keys(prompt: np.ndarray, block_size: int, start: int = 0,
                    prev=None):
        """Chained keys for fully-filled prompt pages ``start..``: key_i
        commits to ALL tokens up to and including page i (so equal keys
        mean equal prefixes, not just equal pages).  ``prev`` must be
        the chain key of page ``start - 1`` when resuming."""
        keys = []
        for i in range(start, len(prompt) // block_size):
            chunk = tuple(int(t) for t in
                          prompt[i * block_size:(i + 1) * block_size])
            prev = (prev, chunk)
            keys.append(prev)
        return keys

    def find_shared_prefix(self, prompt: np.ndarray
                           ) -> Tuple[List[int], int]:
        """Longest registered prefix of `prompt` in live pages.

        Returns (page ids, shared token count).  Capped at
        ``len(prompt) - 1`` so at least one suffix token is always
        prefilled (its hidden state supplies the first sampled token).
        Keys are derived lazily page by page, so a miss on page 0 costs
        one chunk hash — this runs on every admission check.
        """
        bs = self.block_size
        max_pages = (len(prompt) - 1) // bs
        pages, key = [], None
        for i in range(max_pages):
            key = (key, tuple(int(t) for t in prompt[i * bs:(i + 1) * bs]))
            page = self._prefix.get(key)
            if page is None or self.blocks.refcount(page) == 0:
                break
            pages.append(page)
        return pages, len(pages) * bs

    def register_prefix(self, rid, prompt: np.ndarray) -> None:
        """Offer `rid`'s fully-filled prompt pages to future requests.

        Incremental: per-chunk calls during chunked prefill only hash
        the pages filled since the last call, resuming the key chain
        instead of re-deriving it from page 0 every time.  Requests
        admitted before the last weight swap are refused — their pages
        (or their pages' attention context) came from the old model.
        """
        if self._admit_epoch.get(rid, -1) != self._epoch:
            return
        table = self.blocks.table(rid)
        start, prev = self._reg_state.get(rid, (0, None))
        keys = self._chunk_keys(prompt, self.block_size, start=start,
                                prev=prev)
        for i, key in zip(range(start, start + len(keys)), keys):
            if i >= len(table):
                break
            page = table[i]
            if self._page_key.get(page) != key:
                self._page_key[page] = key
                self._key_pages.setdefault(key, set()).add(page)
                self._prefix.setdefault(key, page)
            self._reg_state[rid] = (i + 1, key)

    def _evict(self, released_pages: List[int]) -> None:
        """Drop freed pages from the prefix cache; if a freed page was
        the canonical holder of its key, re-point the key at another
        live copy before giving up on it."""
        for page in released_pages:
            key = self._page_key.pop(page, None)
            if key is None:
                continue
            copies = self._key_pages.get(key, set())
            copies.discard(page)
            if self._prefix.get(key) == page:
                if copies:
                    self._prefix[key] = next(iter(copies))
                else:
                    self._prefix.pop(key, None)
            if not copies:
                self._key_pages.pop(key, None)

    # -- slot / page lifecycle ---------------------------------------------
    @property
    def free_slots(self) -> int:
        return len(self._free_slots)

    def can_admit(self, n_tokens: int, shared_blocks: int = 0) -> bool:
        return bool(self._free_slots) and n_tokens <= self.max_seq \
            and self.blocks.can_allocate(n_tokens, shared_blocks)

    def admit(self, rid, n_tokens: int,
              prompt: Optional[np.ndarray] = None,
              shared: Optional[Tuple[List[int], int]] = None
              ) -> Tuple[int, int]:
        """Claim a slot + a token-budget reservation for `rid`.

        With `prompt` given, maps any prefix-cached pages into the new
        table (copy-on-admit sharing); pass ``shared`` to reuse a
        :meth:`find_shared_prefix` result the admission check already
        computed instead of hashing the prompt again.  Returns
        (slot, shared_len).
        """
        if not self._free_slots:
            raise RuntimeError("no free cache slots")
        if n_tokens > self.max_seq:
            raise ValueError(
                f"request needs {n_tokens} tokens > pool max_seq "
                f"{self.max_seq}")
        if shared is None:
            shared = ([], 0) if prompt is None else \
                self.find_shared_prefix(prompt)
        shared_pages, shared_len = shared
        self.blocks.reserve(rid, n_tokens, shared=shared_pages)
        slot = self._free_slots.pop()
        self._slot_of[rid] = slot
        self._admit_epoch[rid] = self._epoch
        self.tables[slot, :] = self.null_page
        if shared_pages:
            self.tables[slot, :len(shared_pages)] = shared_pages
            self.prefix_hits += 1
            self.prefix_shared_tokens += shared_len
            # registration resumes after the shared pages — their keys
            # are already in the cache
            self._reg_state[rid] = (len(shared_pages),
                                    self._page_key[shared_pages[-1]])
        return slot, shared_len

    def slot_of(self, rid) -> int:
        return self._slot_of[rid]

    def ensure(self, rid, n_tokens: int) -> None:
        """Materialize pages so `rid` can hold `n_tokens`; updates the
        slot's block table in place."""
        slot = self._slot_of[rid]
        have = len(self.blocks.table(rid))
        new = self.blocks.ensure(rid, n_tokens)
        if new:
            self.tables[slot, have:have + len(new)] = new

    def insert_prefill(self, rid, prefill_cache, prompt_len: int) -> None:
        """Scatter a (batch=1) dense prefill cache into the pool.

        The one-shot path for recurrent/hybrid families: attention
        leaves scatter token s into (table[s // bs], s % bs); recurrent
        state leaves overwrite the request's slot row.
        """
        self.ensure(rid, prompt_len)
        slot = self._slot_of[rid]
        table = self.blocks.table(rid)
        # per-token page targets; positions past prompt_len (padding)
        # are dropped onto the null page
        kv_len = _first_kv_len(prefill_cache, self.paged_mask)
        if kv_len is None:          # pure-recurrent stack: no KV pages
            kv_len = prompt_len
        pos = np.arange(kv_len)
        pids = np.full((kv_len,), self.null_page, np.int32)
        valid = pos < prompt_len
        pids[valid] = np.asarray(table, np.int32)[pos[valid]
                                                  // self.block_size]
        offs = (pos % self.block_size).astype(np.int32)
        self.cache = _insert_tree_paged(
            self.cache, self.paged_mask, prefill_cache,
            jnp.asarray(pids), jnp.asarray(offs), jnp.int32(slot))

    def release(self, rid) -> int:
        """Free `rid`'s slot + page refs; returns the freed slot."""
        slot = self._slot_of.pop(rid)
        self._free_slots.append(slot)
        self.tables[slot, :] = self.null_page
        self._reg_state.pop(rid, None)
        self._admit_epoch.pop(rid, None)
        self._evict(self.blocks.free(rid))
        return slot

    def invalidate_prefix(self) -> None:
        """Flush the prefix cache (hot swap): pages computed under the
        old weights must not be mapped into post-swap admissions, and
        still-prefilling pre-swap requests stop registering (their
        remaining chunks attend over old-weight history).  Live tables
        and refcounts are untouched — only the sharing index dies."""
        self._prefix.clear()
        self._key_pages.clear()
        self._page_key.clear()
        self._epoch += 1

    def table_width_for(self, max_tokens: int) -> int:
        """Block-table columns needed to cover `max_tokens` (the
        scheduler buckets this so gather width tracks the batch's true
        maximum instead of always paying max_blocks_per_seq)."""
        return min(self.max_blocks_per_seq,
                   blocks_for(max(max_tokens, 1), self.block_size))

    def as_dict(self) -> Dict[str, int]:
        return {"num_slots": self.num_slots, "max_seq": self.max_seq,
                "free_slots": self.free_slots,
                "prefix_hits": self.prefix_hits,
                "prefix_shared_tokens": self.prefix_shared_tokens,
                **self.blocks.as_dict()}


def _first_kv_len(prefill_cache, paged_mask) -> Optional[int]:
    """Sequence length of the first attention leaf of a dense (batch=1)
    prefill cache: leaves are (stack, 1, S, Hkv, D).  None for pure-
    recurrent stacks (xLSTM), whose cache is all per-slot state rows."""
    for leaf, paged in zip(jax.tree.leaves(prefill_cache),
                           paged_mask):
        if paged:
            return int(leaf.shape[2])
    return None


# ---------------------------------------------------------------------------
# dense legacy pool (the PR-2 baseline, kept for layout="dense")
# ---------------------------------------------------------------------------


def _insert_row(dst: jax.Array, src: jax.Array, slot) -> jax.Array:
    """Write `src` (leading (layers, 1, ...)) into pool row `slot`.

    Every cache leaf is (layers, batch, *state); attention leaves carry
    a kv_seq axis shorter than the pool's max_len at prefill time — pad
    with zeros so the whole row is overwritten (slot reuse must not
    leak the previous occupant's cache).
    """
    if src.shape[2:] != dst.shape[2:]:
        pad = [(0, 0), (0, 0)] + [(0, d - s)
                                  for d, s in zip(dst.shape[2:], src.shape[2:])]
        src = jnp.pad(src, pad)
    start = (0, jnp.asarray(slot, jnp.int32)) + (0,) * (dst.ndim - 2)
    return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), start)


# the pool is donated: the caller always rebinds CachePool.cache to the
# result, so the update happens in place instead of copying the whole
# preallocated pool on every request admission
@partial(jax.jit, donate_argnums=(0,))
def _insert_tree(pool, src, slot):
    return jax.tree.map(lambda d, s: _insert_row(d, s, slot), pool, src)


class CachePool:
    """One preallocated dense decode cache shared by all requests.

    ``cache`` holds `num_slots` rows of `max_len` tokens (allocated once
    at construction via :func:`repro.models.lm.init_cache`); slot and
    page lifetime are managed here so the scheduler only deals in
    request ids.  Pages are bookkeeping only — a request's cache is its
    contiguous slot row, which is what the paged layout replaces.
    """

    def __init__(self, cfg: ModelConfig, num_slots: int, max_len: int,
                 block_size: int = 16, num_blocks: Optional[int] = None):
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.blocks = BlockManager(
            num_blocks if num_blocks is not None
            else num_slots * blocks_for(max_len, block_size),
            block_size)
        self.cache, _ = lm.init_cache(cfg, num_slots, max_len)
        self._free_slots = list(range(num_slots))
        self._slot_of: Dict[Any, int] = {}

    @property
    def free_slots(self) -> int:
        return len(self._free_slots)

    def can_admit(self, n_tokens: int) -> bool:
        """Room for a request reserving `n_tokens` (prompt + max new)?"""
        return bool(self._free_slots) and n_tokens <= self.max_len \
            and self.blocks.can_allocate(n_tokens)

    def admit(self, rid, n_tokens: int) -> int:
        """Claim a slot + pages for `rid`; returns the slot index."""
        if not self._free_slots:
            raise RuntimeError("no free cache slots")
        if n_tokens > self.max_len:
            raise ValueError(
                f"request needs {n_tokens} tokens > pool max_len "
                f"{self.max_len}")
        self.blocks.allocate(rid, n_tokens)
        slot = self._free_slots.pop()
        self._slot_of[rid] = slot
        return slot

    def slot_of(self, rid) -> int:
        return self._slot_of[rid]

    def insert(self, rid, prefill_cache) -> None:
        """Overwrite `rid`'s slot row with a (batch=1) prefilled cache."""
        self.cache = _insert_tree(self.cache, prefill_cache,
                                  jnp.int32(self._slot_of[rid]))

    def release(self, rid) -> int:
        """Free `rid`'s slot + pages; returns the freed slot index."""
        slot = self._slot_of.pop(rid)
        self._free_slots.append(slot)
        self.blocks.free(rid)
        return slot

    def as_dict(self) -> Dict[str, int]:
        return {"num_slots": self.num_slots, "max_len": self.max_len,
                "free_slots": self.free_slots, **self.blocks.as_dict()}
