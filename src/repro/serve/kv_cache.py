"""Block/paged KV-cache management for the serving subsystem.

Two cooperating pieces:

``BlockManager``
    Logical page accounting in units of ``block_size`` tokens over a
    fixed page pool — admission by token budget, per-request block
    tables, free-list reuse, and high-water-mark stats.  This is the
    vLLM-style bookkeeping layer: a request is admitted only when its
    full reservation (prompt + max new tokens) fits in free pages, so
    the scheduler never has to preempt mid-stream.

``CachePool``
    The physical cache: ONE preallocated ``lm.init_cache`` pytree of
    ``num_slots`` rows x ``max_len`` tokens, shared by every request for
    the lifetime of the server (this replaces the old
    ``Engine._pad_cache`` path that re-allocated a full-length cache per
    ``generate`` call).  A finished request's slot row is simply handed
    to the next request; ``insert`` overwrites the whole row with the
    newcomer's prefilled cache (zero-padded to ``max_len``), so no stale
    state survives slot reuse.

Emulation note: pages are stored contiguously inside a request's slot
row rather than scattered across the pool (the dense
``attention_decode`` path indexes caches by position, not by page
table).  The BlockManager still governs admission and accounting, which
is the part the scheduler and the fig14 benchmark measure.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Pages needed to hold `n_tokens` cache entries."""
    return max(1, -(-int(n_tokens) // int(block_size)))


@dataclass
class BlockManager:
    """Token-budget page accounting over a fixed pool of cache blocks."""

    num_blocks: int
    block_size: int
    _free: List[int] = field(default_factory=list)
    _tables: Dict[Any, List[int]] = field(default_factory=dict)
    high_water: int = 0
    allocs: int = 0
    frees: int = 0

    def __post_init__(self):
        self._free = list(range(self.num_blocks))

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    def table(self, rid) -> List[int]:
        return list(self._tables[rid])

    def can_allocate(self, n_tokens: int) -> bool:
        return blocks_for(n_tokens, self.block_size) <= len(self._free)

    def allocate(self, rid, n_tokens: int) -> List[int]:
        """Reserve pages for `n_tokens`; raises if rid is live or the
        pool cannot cover the reservation."""
        if rid in self._tables:
            raise ValueError(f"request {rid!r} already holds blocks")
        need = blocks_for(n_tokens, self.block_size)
        if need > len(self._free):
            raise RuntimeError(
                f"out of cache blocks: need {need}, free {len(self._free)}")
        got = [self._free.pop() for _ in range(need)]
        self._tables[rid] = got
        self.allocs += need
        self.high_water = max(self.high_water, self.used_blocks)
        return list(got)

    def extend(self, rid, n_tokens: int) -> List[int]:
        """Grow a live reservation to cover `n_tokens` total."""
        have = self._tables[rid]
        need = blocks_for(n_tokens, self.block_size) - len(have)
        if need <= 0:
            return []
        if need > len(self._free):
            raise RuntimeError(
                f"out of cache blocks: need {need}, free {len(self._free)}")
        got = [self._free.pop() for _ in range(need)]
        have.extend(got)
        self.allocs += need
        self.high_water = max(self.high_water, self.used_blocks)
        return got

    def free(self, rid) -> int:
        """Release a request's pages back to the pool."""
        blocks = self._tables.pop(rid)
        self._free.extend(blocks)
        self.frees += len(blocks)
        return len(blocks)

    def as_dict(self) -> Dict[str, int]:
        return {"num_blocks": self.num_blocks,
                "block_size": self.block_size,
                "used_blocks": self.used_blocks,
                "high_water_blocks": self.high_water,
                "block_allocs": self.allocs, "block_frees": self.frees}


def _insert_row(dst: jax.Array, src: jax.Array, slot) -> jax.Array:
    """Write `src` (leading (layers, 1, ...)) into pool row `slot`.

    Every cache leaf is (layers, batch, *state); attention leaves carry
    a kv_seq axis shorter than the pool's max_len at prefill time — pad
    with zeros so the whole row is overwritten (slot reuse must not
    leak the previous occupant's cache).
    """
    if src.shape[2:] != dst.shape[2:]:
        pad = [(0, 0), (0, 0)] + [(0, d - s)
                                  for d, s in zip(dst.shape[2:], src.shape[2:])]
        src = jnp.pad(src, pad)
    start = (0, jnp.asarray(slot, jnp.int32)) + (0,) * (dst.ndim - 2)
    return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), start)


# the pool is donated: the caller always rebinds CachePool.cache to the
# result, so the update happens in place instead of copying the whole
# preallocated pool on every request admission
@partial(jax.jit, donate_argnums=(0,))
def _insert_tree(pool, src, slot):
    return jax.tree.map(lambda d, s: _insert_row(d, s, slot), pool, src)


class CachePool:
    """One preallocated decode cache shared by all requests.

    ``cache`` holds `num_slots` rows of `max_len` tokens (allocated once
    at construction via :func:`repro.models.lm.init_cache`); slot and
    page lifetime are managed here so the scheduler only deals in
    request ids.
    """

    def __init__(self, cfg: ModelConfig, num_slots: int, max_len: int,
                 block_size: int = 16, num_blocks: Optional[int] = None):
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.blocks = BlockManager(
            num_blocks if num_blocks is not None
            else num_slots * blocks_for(max_len, block_size),
            block_size)
        self.cache, _ = lm.init_cache(cfg, num_slots, max_len)
        self._free_slots = list(range(num_slots))
        self._slot_of: Dict[Any, int] = {}

    @property
    def free_slots(self) -> int:
        return len(self._free_slots)

    def can_admit(self, n_tokens: int) -> bool:
        """Room for a request reserving `n_tokens` (prompt + max new)?"""
        return bool(self._free_slots) and n_tokens <= self.max_len \
            and self.blocks.can_allocate(n_tokens)

    def admit(self, rid, n_tokens: int) -> int:
        """Claim a slot + pages for `rid`; returns the slot index."""
        if not self._free_slots:
            raise RuntimeError("no free cache slots")
        if n_tokens > self.max_len:
            raise ValueError(
                f"request needs {n_tokens} tokens > pool max_len "
                f"{self.max_len}")
        self.blocks.allocate(rid, n_tokens)
        slot = self._free_slots.pop()
        self._slot_of[rid] = slot
        return slot

    def slot_of(self, rid) -> int:
        return self._slot_of[rid]

    def insert(self, rid, prefill_cache) -> None:
        """Overwrite `rid`'s slot row with a (batch=1) prefilled cache."""
        self.cache = _insert_tree(self.cache, prefill_cache,
                                  jnp.int32(self._slot_of[rid]))

    def release(self, rid) -> int:
        """Free `rid`'s slot + pages; returns the freed slot index."""
        slot = self._slot_of.pop(rid)
        self._free_slots.append(slot)
        self.blocks.free(rid)
        return slot

    def as_dict(self) -> Dict[str, int]:
        return {"num_slots": self.num_slots, "max_len": self.max_len,
                "free_slots": self.free_slots, **self.blocks.as_dict()}
