"""Cache layouts + block/paged KV-cache management for serving.

The decode surface is ONE protocol: a :class:`CacheLayout` owns the
physical cache pytree and answers, per layer,

  * **init**     — build the cache leaves (slot rows or page pools);
  * **write**    — where a request's prefilled KV/state lands
    (``insert`` / ``insert_prefill``) and how decode steps address it
    (``tables`` for paged, per-row indices for slots);
  * **read**     — the kwargs a decode step needs (``step_kwargs``);
  * **snapshot / restore** — copy-out / masked copy-back of the
    RECURRENT leaves (mamba / xLSTM state), the rollback primitive
    speculative decoding is built on.  Attention KV needs no rollback:
    stale positions past a row's length are causally masked and
    overwritten on the next write.

Two implementations, both driven through
:class:`repro.serve.session.DecodeSession`:

``SlotLayout``
    Dense rows: ``num_slots x max_len`` attention KV + per-slot
    recurrent state (the PR-2 layout, kept as the ``layout="dense"``
    baseline the fig14 benchmark measures the paged path against).

``PagedLayout``
    Per attention layer ONE ``(num_pages + 1, block_size, n_kv_heads,
    head_dim)`` pool (``repro.models.lm.init_cache(..., pages=...)``;
    the +1 is the null page), plus the host-side block tables the
    gather-decode kernel reads.  A request's pages can live anywhere in
    the pool — there is no per-slot ``max_len`` row, so a single
    request may use the entire pool.  Recurrent-layer state (O(1) per
    request) stays in dense per-slot rows.

    **Prefix sharing (copy-on-admit):** after a request prefills, its
    fully-filled prompt pages are registered in a prefix cache keyed by
    the token chain they hold; a later request whose prompt starts with
    the same pages maps them read-only into its own table (refcount++)
    and prefills only the suffix.  Shared pages are immutable by
    construction — decode appends strictly after the prompt and the
    partially-filled tail page is never shared — so no copy is ever
    needed.  With ``pin_prefix=True`` registered prompt pages are
    additionally PINNED: they survive idle periods (no live holder) in
    an eviction-priority tier and are reclaimed oldest-first only under
    allocation pressure.

``BlockManager``
    Page accounting in units of ``block_size`` tokens over a fixed page
    pool — vLLM-style bookkeeping with two layers of truth:

    * **reservations** — admission is by token budget: a request is
      admitted only when its full reservation (prompt + max new tokens)
      fits under the pool size minus everything already committed, so
      the scheduler never has to preempt mid-stream;
    * **physical pages** — materialized lazily (``ensure``): prompt
      pages as prefill reaches them, decode pages when generation
      crosses a page boundary.  A request that stops early (EOS) never
      claims the tail of its reservation, and the high-water mark
      measures pages actually touched.

    Pages are **refcounted** so prefix sharing can map one physical
    page into several requests' tables; a page returns to the free list
    when its last holder releases it — unless it is pinned, in which
    case it idles in the reclaim tier.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Pages needed to hold `n_tokens` cache entries."""
    return max(1, -(-int(n_tokens) // int(block_size)))


@dataclass
class BlockManager:
    """Token-budget page accounting over a fixed pool of cache blocks."""

    num_blocks: int
    block_size: int
    _free: List[int] = field(default_factory=list)
    _tables: Dict[Any, List[int]] = field(default_factory=dict)
    # pages a request may still claim from the free list (its
    # reservation minus what it has already materialized); admission
    # budgets against free - sum(_pending), so shared pages cost the
    # pool ONCE no matter how many tables map them — that is the
    # prefix-sharing capacity win
    _pending: Dict[Any, int] = field(default_factory=dict)
    _refs: Dict[int, int] = field(default_factory=dict)
    # eviction-priority tier: pages held alive ONLY by a pin (insertion
    # order = pin age); reclaimed oldest-first under allocation
    # pressure, with ``on_reclaim`` notifying the owner (prefix cache)
    _pinned: Dict[int, None] = field(default_factory=dict)
    on_reclaim: Optional[Callable[[List[int]], None]] = None
    high_water: int = 0
    allocs: int = 0
    frees: int = 0
    reclaims: int = 0

    def __post_init__(self):
        self._free = list(range(self.num_blocks))

    @property
    def free_blocks(self) -> int:
        """Pages on the free list (excludes pinned-idle pages)."""
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        """Pages materialized to some request (incl. pinned/shared)."""
        return self.num_blocks - len(self._free)

    @property
    def pending_blocks(self) -> int:
        """Free-list pages promised to live requests but not yet
        materialized (lazy allocation)."""
        return sum(self._pending.values())

    @property
    def committed_blocks(self) -> int:
        """Blocks spoken for: materialized + promised."""
        return self.used_blocks + self.pending_blocks

    @property
    def reclaimable_blocks(self) -> int:
        """Pinned pages with no live holder — the eviction-priority
        tier: counted as capacity for admission, stolen only when the
        free list runs dry."""
        return sum(1 for b in self._pinned if self._refs.get(b) == 1)

    @property
    def available_blocks(self) -> int:
        """Pages an admission may budget against: free-list pages not
        promised to anyone, plus idle pinned pages (reclaimable)."""
        return len(self._free) + self.reclaimable_blocks \
            - self.pending_blocks

    def table(self, rid) -> List[int]:
        """The request's page table: global page ids, in order."""
        return list(self._tables[rid])

    def _lost_reclaimable(self, shared: Sequence[int]) -> int:
        """Idle pinned pages in `shared`: mapping them refcounts them to
        2, so they stop being reclaimable — admission must not count
        them BOTH as free prefix pages and as reclaimable capacity."""
        return sum(1 for b in set(shared)
                   if b in self._pinned and self._refs.get(b) == 1)

    def can_allocate(self, n_tokens: int,
                     shared: Sequence[int] = ()) -> bool:
        """Would an allocation of ``n_tokens`` (minus ``shared`` prefix
        pages) fit the available capacity right now?"""
        need = blocks_for(n_tokens, self.block_size) - len(shared)
        return need <= self.available_blocks \
            - self._lost_reclaimable(shared)

    # -- pinning (prefix residency) ----------------------------------------
    def pin(self, page: int) -> None:
        """Keep `page` resident after its last holder releases it (an
        extra refcount held by the pin)."""
        if page not in self._pinned and page in self._refs:
            self._refs[page] += 1
            self._pinned[page] = None

    def unpin_all(self) -> List[int]:
        """Drop every pin; returns the pages that hit refcount zero
        (returned to the free list) — the hot-swap flush path."""
        released = []
        for page in list(self._pinned):
            self._refs[page] -= 1
            if self._refs[page] == 0:
                del self._refs[page]
                self._free.append(page)
                released.append(page)
        self._pinned.clear()
        self.frees += len(released)
        return released

    def _reclaim(self, n: int) -> None:
        """Steal `n` idle pinned pages (oldest pin first) back onto the
        free list; the owner is told via ``on_reclaim`` so it can drop
        the pages from its prefix cache.  Candidates are collected
        BEFORE any mutation, so an insufficient tier raises with the
        pin bookkeeping (and the owner's prefix cache) fully intact."""
        taken = [page for page in self._pinned
                 if self._refs.get(page) == 1][:n]
        if len(taken) < n:
            raise RuntimeError(
                f"out of cache blocks: need {n - len(taken)} more, "
                f"free {len(self._free)}")
        for page in taken:
            del self._pinned[page]
            del self._refs[page]
            self._free.append(page)
        self.reclaims += len(taken)
        if self.on_reclaim is not None:
            self.on_reclaim(taken)

    def _claim(self, rid, n: int) -> List[int]:
        if n > len(self._free):
            self._reclaim(n - len(self._free))
        got = [self._free.pop() for _ in range(n)]
        for b in got:
            self._refs[b] = 1
        self._tables[rid].extend(got)
        self._pending[rid] -= n
        self.allocs += n
        self.high_water = max(self.high_water, self.used_blocks)
        return got

    def allocate(self, rid, n_tokens: int) -> List[int]:
        """Reserve AND materialize pages for `n_tokens` (eager; the
        dense pool path).  Raises if rid is live or over budget."""
        if rid in self._tables:
            raise ValueError(f"request {rid!r} already holds blocks")
        need = blocks_for(n_tokens, self.block_size)
        if not self.can_allocate(n_tokens):
            raise RuntimeError(
                f"out of cache blocks: need {need}, "
                f"available {self.available_blocks}")
        self._tables[rid] = []
        self._pending[rid] = need
        return self._claim(rid, need)

    def reserve(self, rid, n_tokens: int,
                shared: Sequence[int] = ()) -> None:
        """Budget `n_tokens` for `rid`, mapping `shared` pages (already
        live, refcounted up) as its first pages; the rest materialize
        lazily via :meth:`ensure`.  Shared pages are free — they are
        someone's materialized pages already."""
        if rid in self._tables:
            raise ValueError(f"request {rid!r} already holds blocks")
        need = blocks_for(n_tokens, self.block_size) - len(shared)
        # refcounting the shared pages removes any idle pinned ones
        # from the reclaim tier — budget as if that already happened
        usable = self.available_blocks - self._lost_reclaimable(shared)
        if need > usable:
            raise RuntimeError(
                f"out of cache blocks: need {need}, available {usable}")
        for b in shared:
            self._refs[b] += 1
        self._tables[rid] = list(shared)
        self._pending[rid] = need
        self.high_water = max(self.high_water, self.used_blocks)

    def ensure(self, rid, n_tokens: int) -> List[int]:
        """Materialize physical pages so `rid` can hold `n_tokens`;
        returns the newly claimed page ids (page-overflow allocation).
        Growing past the reservation raises — the scheduler budgets
        prompt + max_new up front precisely so this cannot happen."""
        have = self._tables[rid]
        need = blocks_for(n_tokens, self.block_size) - len(have)
        if need <= 0:
            return []
        if need > self._pending[rid]:
            raise RuntimeError(
                f"request {rid!r} overflows its reservation "
                f"({len(have) + self._pending[rid]} blocks)")
        return self._claim(rid, need)

    def extend(self, rid, n_tokens: int) -> List[int]:
        """Grow a live reservation to cover `n_tokens` total and
        materialize the new pages."""
        need = blocks_for(n_tokens, self.block_size) \
            - len(self._tables[rid])
        if need > self._pending[rid]:
            grow = need - self._pending[rid]
            if grow > self.available_blocks:
                raise RuntimeError(
                    f"out of cache blocks: need {grow}, "
                    f"available {self.available_blocks}")
            self._pending[rid] = need
        return self.ensure(rid, n_tokens)

    def free(self, rid) -> List[int]:
        """Release `rid`'s pages; returns the page ids whose refcount
        hit zero (returned to the free list)."""
        blocks = self._tables.pop(rid)
        self._pending.pop(rid)
        released = []
        for b in blocks:
            self._refs[b] -= 1
            if self._refs[b] == 0:
                del self._refs[b]
                self._free.append(b)
                released.append(b)
        self.frees += len(released)
        return released

    def refcount(self, page: int) -> int:
        """Number of page tables (plus pins) referencing ``page``."""
        return self._refs.get(page, 0)

    def as_dict(self) -> Dict[str, int]:
        """Counters for the ``[serve] pool`` summary line."""
        return {"num_blocks": self.num_blocks,
                "block_size": self.block_size,
                "used_blocks": self.used_blocks,
                "committed_blocks": self.committed_blocks,
                "pinned_blocks": len(self._pinned),
                "block_reclaims": self.reclaims,
                "high_water_blocks": self.high_water,
                "block_allocs": self.allocs, "block_frees": self.frees}


# ---------------------------------------------------------------------------
# CacheLayout protocol
# ---------------------------------------------------------------------------


def _leaf_is_paged(axes_leaf) -> bool:
    return isinstance(axes_leaf, tuple) and "pages" in axes_leaf


def _leaf_is_kv(axes_leaf) -> bool:
    """Attention KV leaves (either layout); everything else is the
    recurrent state snapshot/restore copies."""
    return isinstance(axes_leaf, tuple) and \
        ("pages" in axes_leaf or "kv_seq" in axes_leaf)


def _axes_leaves(axes):
    is_leaf = (lambda t: isinstance(t, tuple)
               and all(x is None or isinstance(x, str) for x in t))
    return jax.tree.leaves(axes, is_leaf=is_leaf)


@partial(jax.jit, static_argnums=(2,), donate_argnums=(0,))
def _restore_rec(cache, snap, rec_mask, rows):
    """Masked copy-back of recurrent leaves: rows[b] selects the
    snapshot for slot b (leaves are (stack, num_slots, ...))."""
    flat, tree = jax.tree.flatten(cache)
    it = iter(snap)
    out = []
    for leaf, m in zip(flat, rec_mask):
        if m:
            s = next(it)
            sel = rows.reshape((1, rows.shape[0]) + (1,) * (leaf.ndim - 2))
            out.append(jnp.where(sel, s, leaf))
        else:
            out.append(leaf)
    return jax.tree.unflatten(tree, out)


class CacheLayout:
    """Family-agnostic cache protocol the serving stack decodes through.

    A layout owns the physical cache pytree and implements, per layer
    leaf, the five operations :class:`repro.serve.session.
    DecodeSession` is written against:

    ==========  =========================================================
    init        build the cache leaves (``lm.init_cache``, dense or
                ``pages=``)
    write       land prefilled KV/state (``insert`` / ``insert_prefill``)
                and route decode-step writes (slot rows / block tables)
    read        ``step_kwargs()`` — the extra arrays one decode step
                needs (``tables`` for paged, nothing for slots)
    snapshot    copy out the recurrent leaves (mamba / xLSTM state)
    restore     masked copy-back per slot — the speculative-decoding
                rollback primitive (attention KV never rolls back: stale
                positions are causally masked and overwritten)
    ==========  =========================================================

    Slot bookkeeping (`admit` / `release` / `slot_of`) is shared here;
    page accounting is the paged subclass's :class:`BlockManager`.
    """

    cfg: ModelConfig
    num_slots: int
    cache: Any
    rec_mask: Tuple[bool, ...]

    def _init_slots(self, num_slots: int) -> None:
        self.num_slots = num_slots
        self._free_slots = list(range(num_slots))
        self._slot_of: Dict[Any, int] = {}

    @property
    def free_slots(self) -> int:
        """Decode slots not currently assigned to a request."""
        return len(self._free_slots)

    def slot_of(self, rid) -> int:
        """The decode-batch row assigned to ``rid``."""
        return self._slot_of[rid]

    @property
    def has_recurrent(self) -> bool:
        """True when the stack carries per-slot recurrent state (hybrid
        / ssm families) — the leaves snapshot/restore operates on."""
        return any(self.rec_mask)

    @property
    def supports_row_subset(self) -> bool:
        """True when a decode step may cover any subset of rows (no
        cache leaf is indexed by slot) — what lets the scheduler group
        ragged rows by gather width."""
        return False

    def step_kwargs(self, width: Optional[int] = None,
                    rows: Optional[np.ndarray] = None) -> Dict[str, Any]:
        """Extra per-step arrays for :func:`repro.models.lm.lm_decode`."""
        return {}

    def snapshot(self) -> Tuple[jax.Array, ...]:
        """Copy of the recurrent leaves (empty for attention-only
        stacks, where rollback is free)."""
        flat = jax.tree.leaves(self.cache)
        return tuple(jnp.array(x, copy=True)
                     for x, m in zip(flat, self.rec_mask) if m)

    def restore(self, snap: Tuple[jax.Array, ...], rows) -> None:
        """Roll slots with ``rows[b] == True`` back to ``snap``."""
        if not snap:
            return
        self.cache = _restore_rec(self.cache, snap, self.rec_mask,
                                  jnp.asarray(np.asarray(rows, bool)))


# ---------------------------------------------------------------------------
# paged physical pool
# ---------------------------------------------------------------------------


class PageShard:
    """Host-side accounting for ONE page-pool shard.

    A :class:`BlockManager` over shard-LOCAL page ids plus the prefix
    cache (copy-on-admit sharing, pinning, weight-epoch invalidation).
    The ordinary single-device :class:`PagedLayout` holds exactly one;
    the serving mesh holds one per ``data`` shard, each the private
    accountant of that shard's slice of the physical pool — admission,
    eviction and prefix decisions never consult another shard, which is
    what keeps them host-local on a multi-host mesh.  ``offset`` is the
    shard's base in the GLOBAL page-id space block tables use: local
    page ``p`` is global page ``offset + p`` and the shard's null page
    is ``offset + num_pages``.
    """

    def __init__(self, num_pages: int, block_size: int,
                 pin_prefix: bool = False, offset: int = 0):
        self.blocks = BlockManager(num_pages, block_size)
        self.blocks.on_reclaim = self._evict
        self.null_page = num_pages              # local id
        self.offset = offset
        self.pin_prefix = bool(pin_prefix)
        # prefix cache: chained token-chunk key -> canonical physical
        # page, plus every live page known to hold that content (a
        # follower that prefilled its own copy before the prefix was
        # registered is still a valid donor once the original dies)
        self._prefix: Dict[Any, int] = {}
        self._key_pages: Dict[Any, set] = {}
        self._page_key: Dict[int, Any] = {}
        # per-rid incremental registration cursor: (pages done, last key)
        self._reg_state: Dict[Any, Tuple[int, Any]] = {}
        # weight epoch: bumped by invalidate_prefix() on hot swap so
        # pages computed under old weights are never shared forward
        self._epoch = 0
        self._admit_epoch: Dict[Any, int] = {}
        self.prefix_hits = 0
        self.prefix_shared_tokens = 0

    # -- prefix sharing ----------------------------------------------------
    @staticmethod
    def _chunk_keys(prompt: np.ndarray, block_size: int, start: int = 0,
                    prev=None):
        """Chained keys for fully-filled prompt pages ``start..``: key_i
        commits to ALL tokens up to and including page i (so equal keys
        mean equal prefixes, not just equal pages).  ``prev`` must be
        the chain key of page ``start - 1`` when resuming."""
        keys = []
        for i in range(start, len(prompt) // block_size):
            chunk = tuple(int(t) for t in
                          prompt[i * block_size:(i + 1) * block_size])
            prev = (prev, chunk)
            keys.append(prev)
        return keys

    def probe_prefix(self, key_at, max_pages: int) -> List[int]:
        """Longest live prefix run in this shard using externally
        derived chain keys (``key_at(i)`` -> key of page i).  The
        sharded layout derives the keys ONCE (memoized) and probes
        every shard with the same supplier, so a D-shard admission
        check hashes the prompt once, not D times."""
        pages = []
        for i in range(max_pages):
            page = self._prefix.get(key_at(i))
            if page is None or self.blocks.refcount(page) == 0:
                break
            pages.append(page)
        return pages

    def find_shared_prefix(self, prompt: np.ndarray
                           ) -> Tuple[List[int], int]:
        """Longest registered prefix of `prompt` in live LOCAL pages.

        Returns (local page ids, shared token count).  Capped at
        ``len(prompt) - 1`` so at least one suffix token is always
        prefilled (its hidden state supplies the first sampled token).
        Keys are derived lazily page by page, so a miss on page 0 costs
        one chunk hash — this runs on every admission check.
        """
        bs = self.blocks.block_size
        max_pages = (len(prompt) - 1) // bs
        pages = self.probe_prefix(_prefix_key_memo(prompt, bs),
                                  max_pages)
        return pages, len(pages) * bs

    def admit(self, rid, n_tokens: int,
              shared: Tuple[List[int], int]) -> None:
        """Page-budget side of an admission: reserve ``n_tokens`` with
        ``shared`` (local prefix pages) mapped in, stamp the weight
        epoch, and resume the registration cursor past the shared
        pages."""
        shared_pages, shared_len = shared
        self.blocks.reserve(rid, n_tokens, shared=shared_pages)
        self._admit_epoch[rid] = self._epoch
        if shared_pages:
            self.prefix_hits += 1
            self.prefix_shared_tokens += shared_len
            # registration resumes after the shared pages — their keys
            # are already in the cache
            self._reg_state[rid] = (len(shared_pages),
                                    self._page_key[shared_pages[-1]])

    def register_prefix(self, rid, prompt: np.ndarray) -> None:
        """Offer `rid`'s fully-filled prompt pages to future requests.

        Incremental: per-chunk calls during chunked prefill only hash
        the pages filled since the last call, resuming the key chain
        instead of re-deriving it from page 0 every time.  Requests
        admitted before the last weight swap are refused — their pages
        (or their pages' attention context) came from the old model.
        """
        if self._admit_epoch.get(rid, -1) != self._epoch:
            return
        table = self.blocks.table(rid)
        start, prev = self._reg_state.get(rid, (0, None))
        keys = self._chunk_keys(prompt, self.blocks.block_size,
                                start=start, prev=prev)
        for i, key in zip(range(start, start + len(keys)), keys):
            if i >= len(table):
                break
            page = table[i]
            if self._page_key.get(page) != key:
                self._page_key[page] = key
                self._key_pages.setdefault(key, set()).add(page)
                self._prefix.setdefault(key, page)
            if self.pin_prefix:
                # eviction-priority residency: the page survives its
                # holders (reclaimed oldest-first under pressure)
                self.blocks.pin(page)
            self._reg_state[rid] = (i + 1, key)

    def _evict(self, released_pages: List[int]) -> None:
        """Drop freed pages from the prefix cache; if a freed page was
        the canonical holder of its key, re-point the key at another
        live copy before giving up on it."""
        for page in released_pages:
            key = self._page_key.pop(page, None)
            if key is None:
                continue
            copies = self._key_pages.get(key, set())
            copies.discard(page)
            if self._prefix.get(key) == page:
                if copies:
                    self._prefix[key] = next(iter(copies))
                else:
                    self._prefix.pop(key, None)
            if not copies:
                self._key_pages.pop(key, None)

    def release(self, rid) -> None:
        """Drop the request's pages (prefix-shared ones survive as
        cache entries until evicted or invalidated)."""
        self._reg_state.pop(rid, None)
        self._admit_epoch.pop(rid, None)
        self._evict(self.blocks.free(rid))

    def invalidate_prefix(self) -> None:
        """Flush the prefix cache (hot swap): pages computed under the
        old weights must not be mapped into post-swap admissions, and
        still-prefilling pre-swap requests stop registering (their
        remaining chunks attend over old-weight history).  Pins die
        with the index — a pinned page's whole value is being shareable.
        Live tables and refcounts are untouched."""
        self._prefix.clear()
        self._key_pages.clear()
        self._page_key.clear()
        self.blocks.unpin_all()
        self._epoch += 1


def _prefix_key_memo(prompt: np.ndarray, block_size: int):
    """Lazy chain-key supplier for ``prompt``: ``key_at(i)`` hashes
    chunks only up to page i, memoized — a page-0 miss still costs one
    hash, and multiple shard probes share one derivation."""
    keys: List[Any] = []

    def key_at(i: int):
        while len(keys) <= i:
            j = len(keys)
            prev = keys[-1] if keys else None
            chunk = tuple(int(t) for t in
                          prompt[j * block_size:(j + 1) * block_size])
            keys.append((prev, chunk))
        return keys[i]

    return key_at


def _insert_leaf_paged(dst, src, page_ids, offsets):
    """Scatter a (stack, 1, S, Hkv, D) dense prefill leaf into the
    (stack, P+1, bs, Hkv, D) pool at (page_ids[s], offsets[s])."""
    return dst.at[:, page_ids, offsets].set(src[:, 0].astype(dst.dtype))


def _insert_leaf_slot(dst, src, slot):
    """Write a (stack, 1, ...) recurrent-state leaf into pool row `slot`."""
    start = (0, jnp.asarray(slot, jnp.int32)) + (0,) * (dst.ndim - 2)
    return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), start)


@partial(jax.jit, static_argnums=(1,), donate_argnums=(0,))
def _insert_tree_paged(pool, paged_mask, src, page_ids, offsets, slot):
    flat_pool, tree = jax.tree.flatten(pool)
    flat_src = jax.tree.leaves(src)
    out = [
        _insert_leaf_paged(d, s, page_ids, offsets) if paged
        else _insert_leaf_slot(d, s, slot)
        for d, s, paged in zip(flat_pool, flat_src, paged_mask)]
    return jax.tree.unflatten(tree, out)


class PagedLayout(CacheLayout):
    """Paged decode cache: shared page pools + per-slot block tables.

    ``num_slots`` bounds the decode batch width (and the number of
    recurrent-state rows); memory capacity is ``num_pages *
    block_size`` tokens shared by every request.  ``max_seq`` caps a
    single request (it sizes the block-table width) and defaults to the
    whole pool — the per-slot ``max_len`` ceiling of the dense layout
    is gone.  With ``pin_prefix=True`` registered prompt pages stay
    resident after their holders release (reclaimed oldest-first under
    pressure).

    **Sharded mode** (``data_shards > 1``, the serving mesh): slots and
    pages split into ``data_shards`` equal groups; group i's slots can
    only map group i's pages, each group is accounted by its own
    host-local :class:`PageShard` (admission, prefix cache, pinning,
    reclaim), and each group ends with its own null page — block
    tables hold GLOBAL page ids ``shard.offset + local``, which is how
    the shard_map gather (:func:`repro.kernels.ops.paged_attention`)
    rebases to a shard-local index without ever touching another
    shard's pool.  ``placer`` (mesh use) maps the freshly initialized
    cache pytree + its logical axes to device-placed arrays.
    """

    def __init__(self, cfg: ModelConfig, num_slots: int, num_pages: int,
                 block_size: int = 16, max_seq: Optional[int] = None,
                 pin_prefix: bool = False, data_shards: int = 1,
                 placer=None):
        self.cfg = cfg
        self.block_size = block_size
        self.data_shards = int(data_shards)
        if num_slots % self.data_shards or num_pages % self.data_shards:
            raise ValueError(
                f"num_slots ({num_slots}) and num_pages ({num_pages}) "
                f"must be divisible by data_shards ({data_shards})")
        pps = num_pages // self.data_shards     # usable pages per shard
        self._slots_per_shard = num_slots // self.data_shards
        shard_tokens = pps * block_size
        self.max_seq = min(max_seq or shard_tokens, shard_tokens)
        self.max_blocks_per_seq = blocks_for(self.max_seq, block_size)
        self.shards = tuple(
            PageShard(pps, block_size, pin_prefix=pin_prefix,
                      offset=i * (pps + 1))
            for i in range(self.data_shards))
        # shard 0's global null page — THE null page in the single-shard
        # layout (== num_pages, as before); sharded callers use
        # null_page_of(slot)
        self.null_page = pps
        self.pin_prefix = bool(pin_prefix)
        # physical pool: every shard's pages + its null page,
        # contiguous in global id order
        total = self.data_shards * (pps + 1)
        self.cache, axes = lm.init_cache(cfg, num_slots,
                                         pages=(total - 1, block_size))
        self.paged_mask = tuple(_leaf_is_paged(a)
                                for a in _axes_leaves(axes))
        self.rec_mask = tuple(not _leaf_is_kv(a)
                              for a in _axes_leaves(axes))
        if placer is not None:
            self.cache = placer(self.cache, axes)
        self._init_slots(num_slots)
        self.tables = np.empty((num_slots, self.max_blocks_per_seq),
                               np.int32)
        for s in range(num_slots):
            self.tables[s, :] = self.null_page_of(s)
        self._shard_of_rid: Dict[Any, int] = {}
        self._share_shard: Optional[int] = None
        # pool-WIDE concurrent page peak (sharded mode): summing the
        # per-shard high waters would overstate it when shards peak at
        # different times
        self._hw_total = 0

    # -- shard routing -----------------------------------------------------
    def shard_of_slot(self, slot: int) -> int:
        """The data shard whose sub-pool holds this slot's pages."""
        return slot // self._slots_per_shard

    def null_page_of(self, slot: int) -> int:
        """The slot's shard-local scratch page (global id) — where
        idle rows scatter their dead writes."""
        shard = self.shards[self.shard_of_slot(slot)]
        return shard.offset + shard.null_page

    @property
    def blocks(self) -> BlockManager:
        """Shard 0's manager — THE manager in the single-shard layout;
        geometry reference (block_size / num_blocks are per-shard and
        identical across shards) for sharded callers."""
        return self.shards[0].blocks

    @property
    def prefix_hits(self) -> int:
        """Prefix-cache hits, summed over shards."""
        return sum(s.prefix_hits for s in self.shards)

    @property
    def prefix_shared_tokens(self) -> int:
        """Prompt tokens served from shared prefix pages, all shards."""
        return sum(s.prefix_shared_tokens for s in self.shards)

    def _free_slots_in(self, shard_i: int) -> List[int]:
        lo = shard_i * self._slots_per_shard
        hi = lo + self._slots_per_shard
        return [s for s in self._free_slots if lo <= s < hi]

    def _choose_shard(self, n_tokens: int,
                      shared_pages: Sequence[int] = (),
                      hint: Optional[int] = None) -> Optional[int]:
        """Deterministic admission target: the prefix-hinted shard when
        it still fits, else the free-slot shard with the most available
        pages (lowest index on ties) — None when nowhere fits."""
        if hint is not None and self._free_slots_in(hint) and \
                self.shards[hint].blocks.can_allocate(
                    n_tokens, shared=shared_pages):
            return hint
        best, best_avail = None, -1
        for i, shard in enumerate(self.shards):
            if not self._free_slots_in(i):
                continue
            if not shard.blocks.can_allocate(n_tokens):
                continue
            if shard.blocks.available_blocks > best_avail:
                best, best_avail = i, shard.blocks.available_blocks
        return best

    def peek_shard(self, n_tokens: int,
                   shared_pages: Sequence[int] = ()) -> Optional[int]:
        """The shard :meth:`admit` would pick right now (no mutation) —
        lets the mesh scheduler pre-check the drafter's mirror pool in
        the SAME shard before committing an admission."""
        hint = self._share_shard if shared_pages else None
        return self._choose_shard(n_tokens, shared_pages, hint)

    # -- prefix sharing ----------------------------------------------------
    def find_shared_prefix(self, prompt: np.ndarray
                           ) -> Tuple[List[int], int]:
        """Longest registered prefix of `prompt` over the shards an
        admission could land in (LOCAL page ids of the winning shard,
        recorded for the admit that follows).  Single-shard: exactly
        the PR-3/4 behavior.  The chain keys are derived once and
        shared by every shard's probe."""
        bs = self.block_size
        max_pages = (len(prompt) - 1) // bs
        key_at = _prefix_key_memo(prompt, bs)
        best, best_shard = ([], 0), None
        for i, shard in enumerate(self.shards):
            if self.data_shards > 1 and not self._free_slots_in(i):
                continue        # a match in a slot-full shard is unusable
            pages = shard.probe_prefix(key_at, max_pages)
            if len(pages) * bs > best[1]:
                best, best_shard = (pages, len(pages) * bs), i
        self._share_shard = best_shard if best[0] else None
        return best

    def register_prefix(self, rid, prompt: np.ndarray) -> None:
        """Publish ``rid``'s prompt pages into its shard's prefix cache."""
        self.shards[self._shard_of_rid[rid]].register_prefix(rid, prompt)

    # -- slot / page lifecycle ---------------------------------------------
    @property
    def supports_row_subset(self) -> bool:
        """Whether a decode step may cover an arbitrary subset of rows."""
        # with no recurrent rows, every cache leaf is a shared pool —
        # a decode step may cover any subset of slots (ragged grouping;
        # single-shard only: sharded steps must keep every row in its
        # shard's batch partition)
        return not self.has_recurrent and self.data_shards == 1

    def step_kwargs(self, width: Optional[int] = None,
                    rows: Optional[np.ndarray] = None) -> Dict[str, Any]:
        """Page tables (optionally width-clipped / row-subset) for the
        decode dispatch."""
        W = width if width is not None else self.max_blocks_per_seq
        tables = self.tables if rows is None else self.tables[rows]
        return {"tables": jnp.asarray(tables[:, :W])}

    def can_admit(self, n_tokens: int,
                  shared_pages: Sequence[int] = ()) -> bool:
        """Can some shard hold ``n_tokens`` (given ``shared_pages``
        already mapped) with a free slot to go with it?"""
        if not self._free_slots or n_tokens > self.max_seq:
            return False
        hint = self._share_shard if shared_pages else None
        return self._choose_shard(n_tokens, shared_pages, hint) is not None

    def admit(self, rid, n_tokens: int,
              prompt: Optional[np.ndarray] = None,
              shared: Optional[Tuple[List[int], int]] = None,
              slot: Optional[int] = None) -> Tuple[int, int]:
        """Claim a slot + a token-budget reservation for `rid`.

        With `prompt` given, maps any prefix-cached pages into the new
        table (copy-on-admit sharing); pass ``shared`` to reuse a
        :meth:`find_shared_prefix` result the admission check already
        computed instead of hashing the prompt again.  ``slot`` forces
        a specific slot (the drafter's mirror pool must admit into the
        target's slot so the two decode batches stay row-aligned).
        Returns (slot, shared_len).
        """
        if not self._free_slots:
            raise RuntimeError("no free cache slots")
        if n_tokens > self.max_seq:
            raise ValueError(
                f"request needs {n_tokens} tokens > pool max_seq "
                f"{self.max_seq}")
        if shared is None:
            shared = ([], 0) if prompt is None else \
                self.find_shared_prefix(prompt)
        shared_pages, shared_len = shared
        hint = self._share_shard if shared_pages else None
        if slot is None:
            shard_i = self._choose_shard(n_tokens, shared_pages, hint)
            if shard_i is None:
                shard_i = self.shard_of_slot(self._free_slots[-1])
            # LIFO within the shard, matching the old single-list pop()
            slot = self._free_slots_in(shard_i)[-1]
        else:
            if slot not in self._free_slots:
                raise RuntimeError(f"slot {slot} is not free")
            shard_i = self.shard_of_slot(slot)
        if hint is not None and hint != shard_i:
            # the prefix lives in another shard's pool — unusable here
            shared_pages, shared_len = [], 0
        self.shards[shard_i].admit(rid, n_tokens,
                                   (shared_pages, shared_len))
        self._free_slots.remove(slot)
        self._slot_of[rid] = slot
        self._shard_of_rid[rid] = shard_i
        off = self.shards[shard_i].offset
        self.tables[slot, :] = self.null_page_of(slot)
        if shared_pages:
            self.tables[slot, :len(shared_pages)] = \
                off + np.asarray(shared_pages, np.int32)
        self._note_usage()
        return slot, shared_len

    def ensure(self, rid, n_tokens: int) -> None:
        """Materialize pages so `rid` can hold `n_tokens`; updates the
        slot's block table in place (global ids)."""
        slot = self._slot_of[rid]
        shard = self.shards[self._shard_of_rid[rid]]
        have = len(shard.blocks.table(rid))
        new = shard.blocks.ensure(rid, n_tokens)
        if new:
            self.tables[slot, have:have + len(new)] = \
                shard.offset + np.asarray(new, np.int32)
            self._note_usage()

    def _note_usage(self) -> None:
        if self.data_shards > 1:
            used = sum(s.blocks.used_blocks for s in self.shards)
            self._hw_total = max(self._hw_total, used)

    def insert_prefill(self, rid, prefill_cache, prompt_len: int) -> None:
        """Scatter a (batch=1) dense prefill cache into the pool.

        The one-shot path for recurrent/hybrid families: attention
        leaves scatter token s into (table[s // bs], s % bs); recurrent
        state leaves overwrite the request's slot row.
        """
        self.ensure(rid, prompt_len)
        slot = self._slot_of[rid]
        shard = self.shards[self._shard_of_rid[rid]]
        table = [shard.offset + p for p in shard.blocks.table(rid)]
        # per-token page targets; positions past prompt_len (padding)
        # are dropped onto the row's shard's null page
        kv_len = _first_kv_len(prefill_cache, self.paged_mask)
        if kv_len is None:          # pure-recurrent stack: no KV pages
            kv_len = prompt_len
        pos = np.arange(kv_len)
        pids = np.full((kv_len,), self.null_page_of(slot), np.int32)
        valid = pos < prompt_len
        pids[valid] = np.asarray(table, np.int32)[pos[valid]
                                                  // self.block_size]
        offs = (pos % self.block_size).astype(np.int32)
        self.cache = _insert_tree_paged(
            self.cache, self.paged_mask, prefill_cache,
            jnp.asarray(pids), jnp.asarray(offs), jnp.int32(slot))

    def release(self, rid) -> int:
        """Free `rid`'s slot + page refs; returns the freed slot."""
        slot = self._slot_of.pop(rid)
        self._free_slots.append(slot)
        self.tables[slot, :] = self.null_page_of(slot)
        self.shards[self._shard_of_rid.pop(rid)].release(rid)
        return slot

    def invalidate_prefix(self) -> None:
        """Flush every shard's prefix cache + pins (hot swap)."""
        for shard in self.shards:
            shard.invalidate_prefix()

    def table_width_for(self, max_tokens: int) -> int:
        """Block-table columns needed to cover `max_tokens` (the
        scheduler buckets this so gather width tracks the batch's true
        maximum instead of always paying max_blocks_per_seq)."""
        return min(self.max_blocks_per_seq,
                   blocks_for(max(max_tokens, 1), self.block_size))

    def as_dict(self) -> Dict[str, int]:
        """Pool summary: slot/prefix counters + shard-aggregated block
        accounting."""
        d = {"num_slots": self.num_slots, "max_seq": self.max_seq,
             "free_slots": self.free_slots,
             "prefix_hits": self.prefix_hits,
             "prefix_shared_tokens": self.prefix_shared_tokens,
             "data_shards": self.data_shards}
        agg = self.shards[0].blocks.as_dict()
        for shard in self.shards[1:]:
            for k, v in shard.blocks.as_dict().items():
                if k != "block_size":
                    agg[k] += v
        if self.data_shards > 1:
            # the pool-wide CONCURRENT peak, not the sum of per-shard
            # peaks (which overstates when shards peak at different
            # times)
            agg["high_water_blocks"] = self._hw_total
        return {**d, **agg}


def _first_kv_len(prefill_cache, paged_mask) -> Optional[int]:
    """Sequence length of the first attention leaf of a dense (batch=1)
    prefill cache: leaves are (stack, 1, S, Hkv, D).  None for pure-
    recurrent stacks (xLSTM), whose cache is all per-slot state rows."""
    for leaf, paged in zip(jax.tree.leaves(prefill_cache),
                           paged_mask):
        if paged:
            return int(leaf.shape[2])
    return None


# ---------------------------------------------------------------------------
# dense slot layout (the PR-2 baseline, kept for layout="dense")
# ---------------------------------------------------------------------------


def _insert_row(dst: jax.Array, src: jax.Array, slot) -> jax.Array:
    """Write `src` (leading (layers, 1, ...)) into pool row `slot`.

    Every cache leaf is (layers, batch, *state); attention leaves carry
    a kv_seq axis shorter than the pool's max_len at prefill time — pad
    with zeros so the whole row is overwritten (slot reuse must not
    leak the previous occupant's cache).
    """
    if src.shape[2:] != dst.shape[2:]:
        pad = [(0, 0), (0, 0)] + [(0, d - s)
                                  for d, s in zip(dst.shape[2:], src.shape[2:])]
        src = jnp.pad(src, pad)
    start = (0, jnp.asarray(slot, jnp.int32)) + (0,) * (dst.ndim - 2)
    return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), start)


# the pool is donated: the caller always rebinds CachePool.cache to the
# result, so the update happens in place instead of copying the whole
# preallocated pool on every request admission
@partial(jax.jit, donate_argnums=(0,))
def _insert_tree(pool, src, slot):
    return jax.tree.map(lambda d, s: _insert_row(d, s, slot), pool, src)


@partial(jax.jit, donate_argnums=(0,))
def _insert_tree_batch(pool, src):
    return jax.tree.map(lambda d, s: _insert_row(d, s, 0), pool, src)


class SlotLayout(CacheLayout):
    """One preallocated dense decode cache shared by all requests.

    ``cache`` holds `num_slots` rows of `max_len` tokens (allocated once
    at construction via :func:`repro.models.lm.init_cache`); slot and
    page lifetime are managed here so the scheduler only deals in
    request ids.  Pages are bookkeeping only — a request's cache is its
    contiguous slot row, which is what the paged layout replaces.
    """

    def __init__(self, cfg: ModelConfig, num_slots: int, max_len: int,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 placer=None):
        self.cfg = cfg
        self.max_len = max_len
        self.blocks = BlockManager(
            num_blocks if num_blocks is not None
            else num_slots * blocks_for(max_len, block_size),
            block_size)
        self.cache, axes = lm.init_cache(cfg, num_slots, max_len)
        self.rec_mask = tuple(not _leaf_is_kv(a)
                              for a in _axes_leaves(axes))
        if placer is not None:
            self.cache = placer(self.cache, axes)
        self._init_slots(num_slots)

    def can_admit(self, n_tokens: int) -> bool:
        """Room for a request reserving `n_tokens` (prompt + max new)?"""
        return bool(self._free_slots) and n_tokens <= self.max_len \
            and self.blocks.can_allocate(n_tokens)

    def admit(self, rid, n_tokens: int,
              slot: Optional[int] = None) -> int:
        """Claim a slot + pages for `rid`; returns the slot index.
        ``slot`` forces a specific one (drafter mirror pools must stay
        row-aligned with the target's)."""
        if not self._free_slots:
            raise RuntimeError("no free cache slots")
        if n_tokens > self.max_len:
            raise ValueError(
                f"request needs {n_tokens} tokens > pool max_len "
                f"{self.max_len}")
        self.blocks.allocate(rid, n_tokens)
        if slot is None:
            slot = self._free_slots.pop()
        else:
            self._free_slots.remove(slot)
        self._slot_of[rid] = slot
        return slot

    def insert(self, rid, prefill_cache) -> None:
        """Overwrite `rid`'s slot row with a (batch=1) prefilled cache."""
        self.cache = _insert_tree(self.cache, prefill_cache,
                                  jnp.int32(self._slot_of[rid]))

    def insert_batch(self, prefill_cache) -> None:
        """Overwrite ALL slot rows with a (batch=num_slots) prefilled
        cache — the engine path, where one uniform-length batch fills
        the whole pool at once."""
        B = jax.tree.leaves(prefill_cache)[0].shape[1]
        assert B == self.num_slots, (B, self.num_slots)
        self.cache = _insert_tree_batch(self.cache, prefill_cache)

    def release(self, rid) -> int:
        """Free `rid`'s slot + pages; returns the freed slot index."""
        slot = self._slot_of.pop(rid)
        self._free_slots.append(slot)
        self.blocks.free(rid)
        return slot

    def as_dict(self) -> Dict[str, int]:
        """Counters for the ``[serve] pool`` summary line."""
        return {"num_slots": self.num_slots, "max_len": self.max_len,
                "free_slots": self.free_slots, **self.blocks.as_dict()}



# legacy names (PR-2/PR-3): the pools ARE the layouts now
CachePool = SlotLayout
PagedCachePool = PagedLayout
