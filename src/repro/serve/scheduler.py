"""Continuous-batching LM serving scheduler over a PAGED KV cache.

The serving analogue of ``core/tournament.py``'s training orchestrator:
a request queue in front of a slot-based decode batch backed by ONE
preallocated :class:`repro.serve.kv_cache.PagedCachePool` (or the PR-2
dense :class:`~repro.serve.kv_cache.CachePool` with ``layout="dense"``,
kept as the benchmark baseline).

Per scheduler step:

  1. *hot-swap check* — if a :class:`repro.serve.registry.ModelRegistry`
     is attached, poll it every ``watch_every`` steps.
     ``swap_mode="immediate"`` swaps a newer tournament winner in
     between steps (in-flight KV caches remain valid: cache layout
     depends only on the config, not the weights);
     ``swap_mode="drain"`` holds the new weights pending, stops
     admitting, lets every in-flight request finish on the old weights,
     then swaps and resumes — strict per-request weight reproducibility.
  2. *admission* — pop queued requests while a slot AND a full
     token-budget page reservation (prompt + max new tokens) are
     available.  On the paged layout a prompt whose prefix is already
     resident (another live request's registered prompt pages) maps
     those pages read-only into its block table and skips their
     prefill compute entirely (copy-on-admit prefix sharing).
  3. *chunked prefill* — attention-only stacks prefill in
     ``prefill_chunk``-token slices, one slice per prefilling request
     per step, interleaved with decode, so admitting a long prompt
     never stalls in-flight decodes.  Each slice scatters its KV
     straight into the request's pages and attends over the gathered
     page history under one causal mask.  Recurrent families (mamba /
     xLSTM) prefill one-shot at exact length — their state cannot
     resume mid-prompt — and scatter into pages afterwards.
  4. *decode* — ONE batched gather-decode step over the whole pool
     through the per-slot block tables
     (:func:`repro.models.lm.lm_decode_paged`; Pallas kernel on TPU,
     jnp gather twin elsewhere).  The table width passed to the kernel
     is bucketed to the batch's true maximum page count, so short
     requests never pay max_seq-width attention.  Pages materialize
     lazily: a request crossing a page boundary claims its next page
     right before the step (page-overflow allocation).
  5. *completion* — requests hitting EOS or their token budget free
     their slot + page refs immediately; the batch never stalls on its
     slowest member.

``policy="static"`` degrades admission to classic static batching
(admit only when the pool is empty) — the baseline the fig14 benchmark
compares against, sharing every compiled kernel with the continuous
path.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.serve.kv_cache import CachePool, PagedCachePool, blocks_for
from repro.serve.metrics import ServeStats


@dataclass
class Request:
    rid: Any
    prompt: np.ndarray              # (P,) int32 token ids
    max_new: int
    eos_id: Optional[int] = None
    temperature: float = 0.0
    seed: Optional[int] = None

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))


@dataclass
class _Active:
    req: Request
    slot: int
    ntok: int = 0                   # tokens generated so far
    pf_pos: int = 0                 # prompt tokens prefilled so far
    tokens: List[int] = field(default_factory=list)
    submit_t: float = 0.0
    first_token_t: Optional[float] = None


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


# module-level jits (config is a hashable frozen dataclass): compiled
# executables are shared across Scheduler instances, so spinning up a
# server — or the fig14 policy comparison — never re-pays compilation
@partial(jax.jit, static_argnums=(1,))
def _prefill_fn(params, cfg, toks, last_pos):
    return lm.lm_prefill(params, cfg, {"tokens": toks}, last_pos=last_pos)


@partial(jax.jit, static_argnums=(1,), donate_argnums=(3,))
def _decode_fn(params, cfg, tokens, cache, index):
    return lm.lm_decode(params, cfg, tokens, cache, index)


@partial(jax.jit, static_argnums=(1,), donate_argnums=(3,))
def _decode_paged_fn(params, cfg, tokens, cache, index, tables):
    return lm.lm_decode_paged(params, cfg, tokens, cache, index, tables)


@partial(jax.jit, static_argnums=(1,), donate_argnums=(3,))
def _chunk_fn(params, cfg, toks, cache, tables, hist, plen, last_pos):
    return lm.lm_prefill_chunk(params, cfg, toks, cache, tables, hist,
                               plen, last_pos)


class Scheduler:
    """Continuous-batching scheduler over a paged KV-cache pool."""

    def __init__(self, cfg: ModelConfig, params, num_slots: int = 8,
                 max_len: int = 1024, block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 max_seq: Optional[int] = None,
                 layout: str = "paged",
                 policy: str = "continuous",
                 prefill_chunk: int = 0,
                 prefix_sharing: bool = True,
                 max_prefills_per_step: int = 1,
                 min_prefill_bucket: int = 8,
                 registry=None, watch_every: int = 0,
                 swap_mode: str = "immediate"):
        if policy not in ("continuous", "static"):
            raise ValueError(f"unknown policy {policy!r}")
        if layout not in ("paged", "dense"):
            raise ValueError(f"unknown layout {layout!r}")
        if swap_mode not in ("immediate", "drain"):
            raise ValueError(f"unknown swap_mode {swap_mode!r}")
        if cfg.family == "vlm":
            raise ValueError(
                "serving scheduler supports token-input families only "
                "(vlm prompts need precomputed embeddings)")
        self.cfg = cfg
        self.params = params
        self.policy = policy
        self.layout = layout
        self.paged = layout == "paged"
        self.prefill_chunk = int(prefill_chunk)
        self.max_prefills_per_step = max_prefills_per_step
        self.min_prefill_bucket = min_prefill_bucket
        self.registry = registry
        self.watch_every = watch_every
        self.swap_mode = swap_mode
        n_blocks = num_blocks if num_blocks is not None \
            else num_slots * blocks_for(max_len, block_size)
        if self.paged:
            self.pool = PagedCachePool(cfg, num_slots, n_blocks,
                                       block_size=block_size,
                                       max_seq=max_seq or max_len)
            self.max_seq = self.pool.max_seq
        else:
            if max_seq is not None and max_seq != max_len:
                raise ValueError("layout='dense' caps requests at max_len")
            self.pool = CachePool(cfg, num_slots, max_len,
                                  block_size=block_size,
                                  num_blocks=num_blocks)
            self.max_seq = max_len
        # right-padding prompts is only sound for pure-attention stacks:
        # recurrent layers (mamba/xLSTM) would fold padding into their
        # state, so those families prefill at exact prompt length
        # (one compile per distinct length instead of per bucket) —
        # and one-shot: chunked prefill needs mid-prompt resume, which
        # only the paged attention path supports.
        self._can_pad = all(s.kind == "a" for s in lm.layer_specs(cfg))
        self._chunked = self.paged and self._can_pad
        self.prefix_sharing = bool(prefix_sharing) and self._chunked
        self.queue: deque[Request] = deque()
        self.active: Dict[Any, _Active] = {}
        self.prefilling: Dict[Any, _Active] = {}
        self._by_slot: Dict[int, _Active] = {}
        self._next_token = np.zeros((num_slots,), np.int32)
        # paged decode uses -1 as the "row holds no request" sentinel
        # (KV writes route to the null page); dense keeps 0 (the row is
        # the slot's own, writes are harmless)
        self._idle_index = -1 if self.paged else 0
        self._index = np.full((num_slots,), self._idle_index, np.int32)
        self.results: Dict[Any, np.ndarray] = {}
        self.stats = ServeStats(slots=num_slots)
        self._pending_params = None
        self._head_share = None
        self._step_count = 0

    # -- request intake ----------------------------------------------------
    def _reject(self, msg: str):
        self.stats.rejected += 1
        raise ValueError(msg)

    def submit(self, req: Request) -> None:
        total = req.prompt_len + req.max_new
        if req.rid in self.active or req.rid in self.prefilling or \
                req.rid in self.results or \
                any(q.rid == req.rid for q in self.queue):
            self._reject(f"duplicate request id {req.rid!r}")
        if req.prompt_len < 1 or req.max_new < 1:
            self._reject("need a non-empty prompt and max_new >= 1")
        if total > self.max_seq:
            self._reject(
                f"request {req.rid!r} needs {total} tokens > the "
                f"per-request cap (max_len/max_seq {self.max_seq})")
        if blocks_for(total, self.pool.blocks.block_size) \
                > self.pool.blocks.num_blocks:
            self._reject(
                f"request {req.rid!r} exceeds the pool's total token "
                "budget")
        if req.temperature > 0.0 and req.seed is None:
            self._reject(
                f"request {req.rid!r}: temperature > 0 requires a seed "
                "(refusing to silently fall back to greedy)")
        self.stats.submitted += 1
        req._submit_t = time.perf_counter()   # TTFT includes queueing delay
        self.queue.append(req)

    # -- scheduling ---------------------------------------------------------
    def _bucket(self, n: int, cap: Optional[int] = None) -> int:
        if not self._can_pad:
            return n
        cap = cap or self.max_seq
        return min(max(self.min_prefill_bucket, _next_pow2(n)), cap)

    def _can_admit_head(self) -> bool:
        req = self.queue[0]
        total = req.prompt_len + req.max_new
        if not self.paged:
            return self.pool.can_admit(total)
        if not self.pool.free_slots:    # skip prefix hashing when full
            return False
        self._head_share = None
        if self.prefix_sharing:
            # cache the match: _admit reuses it instead of re-hashing
            self._head_share = (req.rid,
                                self.pool.find_shared_prefix(req.prompt))
        shared = len(self._head_share[1][0]) if self._head_share else 0
        return self.pool.can_admit(total, shared_blocks=shared)

    def _admit(self, req: Request) -> None:
        P = req.prompt_len
        total = P + req.max_new
        if not self.paged:
            self.pool.admit(req.rid, total)
            slot = self.pool.slot_of(req.rid)
            self._prefill_dense(req, slot)
            return
        head = getattr(self, "_head_share", None)
        shared = head[1] if head is not None and head[0] == req.rid \
            else None
        self._head_share = None
        slot, shared_len = self.pool.admit(
            req.rid, total, shared=shared,
            prompt=req.prompt if self.prefix_sharing else None)
        act = _Active(req=req, slot=slot, pf_pos=shared_len,
                      submit_t=getattr(req, "_submit_t",
                                       time.perf_counter()))
        if self._chunked:
            # chunk slices run in _prefill_step, interleaved with decode
            self.prefilling[req.rid] = act
        else:
            self._prefill_onepass_paged(act)

    def _prefill_dense(self, req: Request, slot: int) -> None:
        P = req.prompt_len
        bucket = self._bucket(P)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :P] = req.prompt
        logits, cache = _prefill_fn(
            self.params, self.cfg, jnp.asarray(toks),
            jnp.asarray([P - 1], jnp.int32))
        self.pool.insert(req.rid, cache)
        act = _Active(req=req, slot=slot, submit_t=getattr(
            req, "_submit_t", time.perf_counter()))
        self.stats.prefills += 1
        self.stats.prefill_tokens += P
        self.stats.padded_prefill_tokens += bucket
        self._start_decoding(act, np.asarray(logits[0, -1]
                                             .astype(jnp.float32)))

    def _prefill_onepass_paged(self, act: _Active) -> None:
        """Exact-length one-shot prefill + page scatter (recurrent /
        hybrid families: their state cannot resume mid-prompt)."""
        req = act.req
        P = req.prompt_len
        toks = req.prompt[None, :].astype(np.int32)
        logits, cache = _prefill_fn(
            self.params, self.cfg, jnp.asarray(toks),
            jnp.asarray([P - 1], jnp.int32))
        self.pool.insert_prefill(req.rid, cache, P)
        self.stats.prefills += 1
        self.stats.prefill_tokens += P
        self.stats.padded_prefill_tokens += P
        self._start_decoding(act, np.asarray(logits[0, -1]
                                             .astype(jnp.float32)))

    def _prefill_step(self) -> None:
        """Advance chunked prefills: one chunk per prefilling request,
        at most ``max_prefills_per_step`` chunk calls per step."""
        done = 0
        for act in list(self.prefilling.values()):
            if done >= self.max_prefills_per_step:
                break
            self._prefill_chunk_once(act)
            done += 1

    def _prefill_chunk_once(self, act: _Active) -> None:
        req = act.req
        P = req.prompt_len
        # one-shot (prefill_chunk=0) still buckets the chunk size, so a
        # mixed-length trace compiles per pow2 bucket, not per length
        chunk = self.prefill_chunk if self.prefill_chunk > 0 \
            else self._bucket(P)
        n = min(chunk, P - act.pf_pos)
        final = act.pf_pos + n >= P
        Cb = chunk if (not final or n == chunk) \
            else self._bucket(n, cap=chunk)
        toks = np.zeros((1, Cb), np.int32)
        toks[0, :n] = req.prompt[act.pf_pos:act.pf_pos + n]
        self.pool.ensure(req.rid, act.pf_pos + n)
        W = self._table_bucket(act.pf_pos + n)
        logits, self.pool.cache = _chunk_fn(
            self.params, self.cfg, jnp.asarray(toks), self.pool.cache,
            jnp.asarray(self.pool.tables[act.slot:act.slot + 1, :W]),
            jnp.int32(act.pf_pos), jnp.int32(P),
            jnp.asarray([n - 1], jnp.int32))
        act.pf_pos += n
        self.stats.prefills += 1
        self.stats.prefill_chunks += 1
        self.stats.prefill_tokens += n
        self.stats.padded_prefill_tokens += Cb
        if self.prefix_sharing:
            # pages fully covered by prefilled prompt tokens are
            # immutable from here on — offer them to future admissions
            # immediately, not only when the whole prompt is done
            self.pool.register_prefix(req.rid, req.prompt[:act.pf_pos])
        if final:
            del self.prefilling[req.rid]
            self._start_decoding(act, np.asarray(logits[0, -1]
                                                 .astype(jnp.float32)))

    def _start_decoding(self, act: _Active, last_logits: np.ndarray) -> None:
        """Sample the first token off the prefill logits and move the
        request into the decode batch."""
        req = act.req
        self.active[req.rid] = act
        self._by_slot[act.slot] = act
        tok = self._sample(last_logits, req, 0)
        act.first_token_t = time.perf_counter()
        self.stats.ttft.append(act.first_token_t - act.submit_t)
        self._accept_token(act, tok)

    def _sample(self, logits_row, req: Request, ntok: int) -> int:
        """logits_row: (V,) host array.  Sampling stays on host (Gumbel
        trick for temperature > 0) so the only device dispatch per step
        is the batched decode itself."""
        if req.temperature <= 0.0:
            return int(np.argmax(logits_row))
        rng = np.random.default_rng([req.seed, ntok])
        g = rng.gumbel(size=logits_row.shape[-1])
        return int(np.argmax(
            np.asarray(logits_row, np.float64) / req.temperature + g))

    def _accept_token(self, act: _Active, tok: int) -> None:
        act.tokens.append(tok)
        act.ntok += 1
        self.stats.decode_tokens += 1
        # write position of `tok`'s KV on the NEXT decode step
        self._index[act.slot] = act.req.prompt_len + act.ntok - 1
        self._next_token[act.slot] = tok
        done = act.ntok >= act.req.max_new or \
            (act.req.eos_id is not None and tok == act.req.eos_id)
        if done:
            self._finish(act)

    def _finish(self, act: _Active) -> None:
        rid = act.req.rid
        self.results[rid] = np.asarray(act.tokens, np.int32)
        self.stats.completed += 1
        self.stats.latency.append(time.perf_counter() - act.submit_t)
        slot = self.pool.release(rid)
        del self.active[rid]
        del self._by_slot[slot]
        self._next_token[slot] = 0
        self._index[slot] = self._idle_index

    def set_params(self, params) -> None:
        """Hot-swap model weights between steps (cache layout unchanged;
        the prefix cache is flushed — old-weight pages must not be
        shared into post-swap admissions)."""
        self.params = params
        if self.paged:
            self.pool.invalidate_prefix()
            self._head_share = None
        self.stats.hot_swaps += 1

    @property
    def draining(self) -> bool:
        """True while new weights wait for in-flight requests to finish."""
        return self._pending_params is not None

    def _maybe_hot_swap(self) -> None:
        if self.registry is not None and self.watch_every > 0 \
                and self._step_count % self.watch_every == 0 \
                and self.registry.refresh():
            if self.swap_mode == "drain" and (self.active
                                              or self.prefilling):
                self._pending_params = self.registry.params
            else:
                self._pending_params = None
                self.set_params(self.registry.params)
        if self._pending_params is not None and not self.active \
                and not self.prefilling:
            self.set_params(self._pending_params)
            self._pending_params = None

    def step(self) -> None:
        """One scheduler iteration: hot-swap check, admission, chunked
        prefill, one batched decode step, completion."""
        self.stats.start()
        self._maybe_hot_swap()
        self._step_count += 1
        # -- admission (paused while draining onto new weights)
        in_flight = bool(self.active or self.prefilling)
        if self.draining:
            pass
        elif self.policy == "static":
            if not in_flight:
                while self.queue and self._can_admit_head():
                    self._admit(self.queue.popleft())
        else:
            admitted = 0
            while (admitted < self.max_prefills_per_step and self.queue
                   and self._can_admit_head()):
                self._admit(self.queue.popleft())
                admitted += 1
        # -- chunked prefill slices (interleaved with decode)
        if self.prefilling:
            self._prefill_step()
        # -- one decode step over the pool (per-slot write indices)
        if self.active:
            tokens = jnp.asarray(self._next_token[:, None])
            index = jnp.asarray(self._index)
            if self.paged:
                bs = self.pool.block_size
                for act in self.active.values():
                    # a new page is only ever needed when the write
                    # position lands on a page boundary (ensure is
                    # idempotent; skip the bookkeeping otherwise)
                    idx = int(self._index[act.slot])
                    if idx % bs == 0:
                        self.pool.ensure(act.req.rid, idx + 1)
                W = self._table_bucket(int(self._index.max()) + 1)
                tables = jnp.asarray(self.pool.tables[:, :W])
                logits, self.pool.cache = _decode_paged_fn(
                    self.params, self.cfg, tokens, self.pool.cache,
                    index, tables)
            else:
                logits, self.pool.cache = _decode_fn(
                    self.params, self.cfg, tokens, self.pool.cache, index)
            rows = np.asarray(logits.astype(jnp.float32))
            self.stats.decode_steps += 1
            self.stats.decode_slot_steps += self.pool.num_slots
            # sample per active slot; finishing frees the slot in-place
            for act in list(self.active.values()):
                tok = self._sample(rows[act.slot, 0], act.req, act.ntok)
                self._accept_token(act, tok)
        self.stats.sample_step(len(self.queue),
                               len(self.active) + len(self.prefilling))

    def _table_bucket(self, max_tokens: int) -> int:
        """Gather width (block-table columns) for this step: pow2-
        bucketed so compile count stays logarithmic while short batches
        never pay max_seq-width attention."""
        w = self.pool.table_width_for(max_tokens)
        return min(_next_pow2(w), self.pool.max_blocks_per_seq)

    def run(self, max_steps: Optional[int] = None) -> Dict[Any, np.ndarray]:
        """Drive until the queue and the batch drain; returns results
        (rid -> generated token ids)."""
        steps = 0
        while self.queue or self.active or self.prefilling:
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        self.stats.stop()
        return self.results

    def full_sequence(self, req: Request) -> np.ndarray:
        """Prompt + generated tokens for a completed request."""
        return np.concatenate([np.asarray(req.prompt, np.int32),
                               self.results[req.rid]])
