"""Continuous-batching LM serving scheduler.

The serving analogue of ``core/tournament.py``'s training orchestrator:
a request queue in front of a slot-based decode batch backed by ONE
preallocated :class:`repro.serve.kv_cache.CachePool`.

Per scheduler step:

  1. *hot-swap check* — if a :class:`repro.serve.registry.ModelRegistry`
     is attached, poll it every ``watch_every`` steps and swap in a
     newer tournament winner between steps (in-flight KV caches remain
     valid: cache layout depends only on the config, not the weights).
  2. *admission* — pop queued requests while a cache slot AND a full
     token-budget page reservation (prompt + max new tokens) are
     available; prefill each admitted request (prompt right-padded to a
     shape bucket so jit recompiles are bounded), write its cache into
     the claimed slot row, and sample its first token.
  3. *decode* — one batched decode step over the whole pool with
     per-slot write indices (``lm_decode`` vector-index path); sample
     one token per active slot.
  4. *completion* — requests hitting EOS or their token budget free
     their slot + pages immediately; the batch never stalls on its
     slowest member.

``policy="static"`` degrades step 2 to classic static batching (admit
only when the pool is empty, i.e. the whole batch runs to completion
before the queue moves) — the baseline the fig14 benchmark compares
against, sharing every compiled kernel with the continuous path.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.serve.kv_cache import CachePool, blocks_for
from repro.serve.metrics import ServeStats


@dataclass
class Request:
    rid: Any
    prompt: np.ndarray              # (P,) int32 token ids
    max_new: int
    eos_id: Optional[int] = None
    temperature: float = 0.0
    seed: Optional[int] = None

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))


@dataclass
class _Active:
    req: Request
    slot: int
    ntok: int = 0                   # tokens generated so far
    tokens: List[int] = field(default_factory=list)
    submit_t: float = 0.0
    first_token_t: Optional[float] = None


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


# module-level jits (config is a hashable frozen dataclass): compiled
# executables are shared across Scheduler instances, so spinning up a
# server — or the fig14 policy comparison — never re-pays compilation
@partial(jax.jit, static_argnums=(1,))
def _prefill_fn(params, cfg, toks, last_pos):
    return lm.lm_prefill(params, cfg, {"tokens": toks}, last_pos=last_pos)


@partial(jax.jit, static_argnums=(1,), donate_argnums=(3,))
def _decode_fn(params, cfg, tokens, cache, index):
    return lm.lm_decode(params, cfg, tokens, cache, index)


class Scheduler:
    """Continuous-batching scheduler over a slot-based KV-cache pool."""

    def __init__(self, cfg: ModelConfig, params, num_slots: int = 8,
                 max_len: int = 1024, block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 policy: str = "continuous",
                 max_prefills_per_step: int = 1,
                 min_prefill_bucket: int = 8,
                 registry=None, watch_every: int = 0):
        if policy not in ("continuous", "static"):
            raise ValueError(f"unknown policy {policy!r}")
        if cfg.family == "vlm":
            raise ValueError(
                "serving scheduler supports token-input families only "
                "(vlm prompts need precomputed embeddings)")
        self.cfg = cfg
        self.params = params
        self.policy = policy
        self.max_prefills_per_step = max_prefills_per_step
        self.min_prefill_bucket = min_prefill_bucket
        self.registry = registry
        self.watch_every = watch_every
        self.pool = CachePool(cfg, num_slots, max_len,
                              block_size=block_size, num_blocks=num_blocks)
        # right-padding prompts is only sound for pure-attention stacks:
        # recurrent layers (mamba/xLSTM) would fold padding into their
        # state, so those families prefill at exact prompt length
        # (one compile per distinct length instead of per bucket).
        self._can_pad = all(s.kind == "a" for s in lm.layer_specs(cfg))
        self.queue: deque[Request] = deque()
        self.active: Dict[Any, _Active] = {}
        self._by_slot: Dict[int, _Active] = {}
        self._next_token = np.zeros((num_slots,), np.int32)
        self._index = np.zeros((num_slots,), np.int32)
        self.results: Dict[Any, np.ndarray] = {}
        self.stats = ServeStats(slots=num_slots)
        self._step_count = 0

    # -- request intake ----------------------------------------------------
    def _reject(self, msg: str):
        self.stats.rejected += 1
        raise ValueError(msg)

    def submit(self, req: Request) -> None:
        total = req.prompt_len + req.max_new
        if req.rid in self.active or req.rid in self.results or \
                any(q.rid == req.rid for q in self.queue):
            self._reject(f"duplicate request id {req.rid!r}")
        if req.prompt_len < 1 or req.max_new < 1:
            self._reject("need a non-empty prompt and max_new >= 1")
        if total > self.pool.max_len:
            self._reject(
                f"request {req.rid!r} needs {total} tokens > pool max_len "
                f"{self.pool.max_len}")
        if blocks_for(total, self.pool.blocks.block_size) \
                > self.pool.blocks.num_blocks:
            self._reject(
                f"request {req.rid!r} exceeds the pool's total token "
                "budget")
        if req.temperature > 0.0 and req.seed is None:
            self._reject(
                f"request {req.rid!r}: temperature > 0 requires a seed "
                "(refusing to silently fall back to greedy)")
        self.stats.submitted += 1
        req._submit_t = time.perf_counter()   # TTFT includes queueing delay
        self.queue.append(req)

    # -- scheduling ---------------------------------------------------------
    def _bucket(self, n: int) -> int:
        if not self._can_pad:
            return n
        return min(max(self.min_prefill_bucket, _next_pow2(n)),
                   self.pool.max_len)

    def _admit(self, req: Request) -> None:
        P = req.prompt_len
        self.pool.admit(req.rid, P + req.max_new)
        slot = self.pool.slot_of(req.rid)
        bucket = self._bucket(P)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :P] = req.prompt
        logits, cache = _prefill_fn(
            self.params, self.cfg, jnp.asarray(toks),
            jnp.asarray([P - 1], jnp.int32))
        self.pool.insert(req.rid, cache)
        act = _Active(req=req, slot=slot, submit_t=getattr(
            req, "_submit_t", time.perf_counter()))
        self.active[req.rid] = act
        self._by_slot[slot] = act
        self.stats.prefills += 1
        self.stats.prefill_tokens += P
        self.stats.padded_prefill_tokens += bucket
        tok = self._sample(np.asarray(logits[0, -1].astype(jnp.float32)),
                           req, 0)
        act.first_token_t = time.perf_counter()
        self.stats.ttft.append(act.first_token_t - act.submit_t)
        self._accept_token(act, tok)

    def _sample(self, logits_row, req: Request, ntok: int) -> int:
        """logits_row: (V,) host array.  Sampling stays on host (Gumbel
        trick for temperature > 0) so the only device dispatch per step
        is the batched decode itself."""
        if req.temperature <= 0.0:
            return int(np.argmax(logits_row))
        rng = np.random.default_rng([req.seed, ntok])
        g = rng.gumbel(size=logits_row.shape[-1])
        return int(np.argmax(
            np.asarray(logits_row, np.float64) / req.temperature + g))

    def _accept_token(self, act: _Active, tok: int) -> None:
        act.tokens.append(tok)
        act.ntok += 1
        self.stats.decode_tokens += 1
        # write position of `tok`'s KV on the NEXT decode step
        self._index[act.slot] = act.req.prompt_len + act.ntok - 1
        self._next_token[act.slot] = tok
        done = act.ntok >= act.req.max_new or \
            (act.req.eos_id is not None and tok == act.req.eos_id)
        if done:
            self._finish(act)

    def _finish(self, act: _Active) -> None:
        rid = act.req.rid
        self.results[rid] = np.asarray(act.tokens, np.int32)
        self.stats.completed += 1
        self.stats.latency.append(time.perf_counter() - act.submit_t)
        slot = self.pool.release(rid)
        del self.active[rid]
        del self._by_slot[slot]
        self._next_token[slot] = 0
        self._index[slot] = 0

    def set_params(self, params) -> None:
        """Hot-swap model weights between steps (cache layout unchanged)."""
        self.params = params
        self.stats.hot_swaps += 1

    def _maybe_hot_swap(self) -> None:
        if self.registry is None or self.watch_every <= 0:
            return
        if self._step_count % self.watch_every:
            return
        if self.registry.refresh():
            self.set_params(self.registry.params)

    def step(self) -> None:
        """One scheduler iteration: hot-swap check, admission (prefill),
        one batched decode step, completion."""
        self.stats.start()
        self._maybe_hot_swap()
        self._step_count += 1
        # -- admission
        if self.policy == "static":
            if not self.active:
                while self.queue and self.pool.can_admit(
                        self.queue[0].prompt_len + self.queue[0].max_new):
                    self._admit(self.queue.popleft())
        else:
            admitted = 0
            while (admitted < self.max_prefills_per_step and self.queue
                   and self.pool.can_admit(
                       self.queue[0].prompt_len + self.queue[0].max_new)):
                self._admit(self.queue.popleft())
                admitted += 1
        # -- one decode step over the pool (per-slot write indices)
        if self.active:
            tokens = jnp.asarray(self._next_token[:, None])
            index = jnp.asarray(self._index)
            logits, self.pool.cache = _decode_fn(
                self.params, self.cfg, tokens, self.pool.cache, index)
            rows = np.asarray(logits.astype(jnp.float32))
            self.stats.decode_steps += 1
            self.stats.decode_slot_steps += self.pool.num_slots
            # sample per active slot; finishing frees the slot in-place
            for act in list(self.active.values()):
                tok = self._sample(rows[act.slot, 0], act.req, act.ntok)
                self._accept_token(act, tok)
        self.stats.sample_step(len(self.queue), len(self.active))

    def run(self, max_steps: Optional[int] = None) -> Dict[Any, np.ndarray]:
        """Drive until the queue and the batch drain; returns results
        (rid -> generated token ids)."""
        steps = 0
        while self.queue or self.active:
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        self.stats.stop()
        return self.results

    def full_sequence(self, req: Request) -> np.ndarray:
        """Prompt + generated tokens for a completed request."""
        return np.concatenate([np.asarray(req.prompt, np.int32),
                               self.results[req.rid]])
