"""Continuous-batching LM serving scheduler over a PAGED KV cache.

The serving analogue of ``core/tournament.py``'s training orchestrator:
a request queue in front of a slot-based decode batch backed by ONE
preallocated :class:`repro.serve.kv_cache.PagedLayout` (or the PR-2
dense :class:`~repro.serve.kv_cache.SlotLayout` with
``layout="dense"``, kept as the benchmark baseline).  ALL model calls
go through one :class:`repro.serve.session.DecodeSession` per set of
weights — the scheduler never picks a decode entry point by layout.

Per scheduler step:

  1. *hot-swap check* — if a :class:`repro.serve.registry.ModelRegistry`
     is attached, poll it every ``watch_every`` steps.
     ``swap_mode="immediate"`` swaps a newer tournament winner in
     between steps (in-flight KV caches remain valid: cache layout
     depends only on the config, not the weights);
     ``swap_mode="drain"`` holds the new weights pending, stops
     admitting, lets every in-flight request finish on the old weights,
     then swaps and resumes — strict per-request weight reproducibility.
  2. *admission* — pop queued requests while a slot AND a full
     token-budget page reservation (prompt + max new tokens) are
     available.  On the paged layout a prompt whose prefix is already
     resident (another live request's registered prompt pages) maps
     those pages read-only into its block table and skips their
     prefill compute entirely (copy-on-admit prefix sharing; with
     ``pin_prefix=True`` registered prompt pages additionally survive
     idle periods in an eviction-priority tier).
  3. *chunked prefill* — attention-only stacks prefill in
     ``prefill_chunk``-token slices, one slice per prefilling request
     per step, interleaved with decode, so admitting a long prompt
     never stalls in-flight decodes.  Recurrent families (mamba /
     xLSTM) prefill one-shot at exact length — their state cannot
     resume mid-prompt — and scatter into pages afterwards.
  4. *decode* — batched ``session.step`` over the in-flight rows.
     Plain rounds write one token per row; with a drafter attached
     (``draft_params`` + ``spec_tokens K``) each round runs
     **population speculative decoding**: the drafter (an
     earlier/smaller LTFB population checkpoint) proposes K tokens per
     row, the target verifies all K + 1 in ONE multi-token
     ``session.step``, the per-row accepted prefix is kept, and
     rejected rows roll recurrent state back via
     ``session.restore`` + a ``valid``-masked replay.  At any
     temperature the output is token-identical to target-only decoding
     (sampling is a deterministic function of (seed, ntok) and the
     target logits).  On CPU the jnp gather oracle pays the full
     bucketed table width per row, so when one row's pow2 width is
     >= 4x everyone else's (the one-long-request pathology) the round
     splits into (narrow, wide) groups stepped separately — the long
     request no longer widens every row's gather, while the common
     case stays a single dispatch.
  5. *completion* — requests hitting EOS or their token budget free
     their slot + page refs immediately; the batch never stalls on its
     slowest member.

``policy="static"`` degrades admission to classic static batching
(admit only when the pool is empty) — the baseline the fig14 benchmark
compares against, sharing every compiled kernel with the continuous
path.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.serve.kv_cache import PagedLayout, SlotLayout, blocks_for
from repro.serve.metrics import ServeStats
from repro.serve.session import DecodeSession
from repro.serve.telemetry import ServeTelemetry, log_event


class Overloaded(RuntimeError):
    """Raised by :meth:`Scheduler.submit` when the request queue is at
    its ``max_queue`` bound — the load-shedding signal the gateway maps
    to HTTP 429 instead of queueing unboundedly."""


@dataclass
class Request:
    """One generation request.

    ``prompt`` is a (P,) int32 token-id array; ``max_new`` bounds the
    generated tokens; ``temperature > 0`` requires ``seed`` (sampling
    is host-side and deterministic in ``(seed, ntok)``).  The optional
    deadlines are SLO declarations in milliseconds: a queued request
    whose ``ttft_deadline_ms`` has already expired is shed by
    :meth:`Scheduler.shed_expired` instead of admitted late, and a
    completed request that missed its TTFT/TPOT deadline increments
    the corresponding ``[serve]`` miss counter.

    ``ntok_base`` offsets the sampler's rng stream: a journal resume
    re-submits a request with ``k`` already-emitted tokens folded into
    the prompt and ``ntok_base=k``, so its first new sample draws
    ``rng([seed, k])`` — exactly the draw the uninterrupted run would
    have made (see ``repro.serve.journal``).  ``idem_key`` carries the
    gateway's ``Idempotency-Key`` header into the journal so client
    retries after a restart don't double-admit.
    """

    rid: Any
    prompt: np.ndarray              # (P,) int32 token ids
    max_new: int
    eos_id: Optional[int] = None
    temperature: float = 0.0
    seed: Optional[int] = None
    ttft_deadline_ms: Optional[float] = None   # first token due (ms)
    tpot_deadline_ms: Optional[float] = None   # mean ms/token budget
    ntok_base: int = 0              # rng-stream offset (journal resume)
    idem_key: Optional[str] = None  # gateway Idempotency-Key, journaled

    @property
    def prompt_len(self) -> int:
        """Prompt length P in tokens."""
        return int(len(self.prompt))


@dataclass
class _Active:
    req: Request
    slot: int
    ntok: int = 0                   # tokens generated so far
    pf_pos: int = 0                 # prompt tokens prefilled so far
    tokens: List[int] = field(default_factory=list)
    submit_t: float = 0.0
    first_token_t: Optional[float] = None


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class Scheduler:
    """Continuous-batching scheduler over a paged KV-cache pool."""

    def __init__(self, cfg: ModelConfig, params, num_slots: int = 8,
                 max_len: int = 1024, block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 max_seq: Optional[int] = None,
                 layout: str = "paged",
                 policy: str = "continuous",
                 prefill_chunk: int = 0,
                 prefix_sharing: bool = True,
                 pin_prefix: bool = False,
                 max_prefills_per_step: int = 1,
                 min_prefill_bucket: int = 8,
                 registry=None, watch_every: int = 0,
                 swap_mode: str = "immediate",
                 draft_params=None, spec_tokens: int = 0,
                 draft_cfg: Optional[ModelConfig] = None,
                 spec_fused: bool = True,
                 spec_adapt: bool = False,
                 max_queue: Optional[int] = None,
                 telemetry: bool = True,
                 trace_capacity: int = 8192,
                 journal=None, faults=None, arena=None):
        if policy not in ("continuous", "static"):
            raise ValueError(f"unknown policy {policy!r}")
        if layout not in ("paged", "dense"):
            raise ValueError(f"unknown layout {layout!r}")
        if swap_mode not in ("immediate", "drain"):
            raise ValueError(f"unknown swap_mode {swap_mode!r}")
        if cfg.family == "vlm":
            raise ValueError(
                "serving scheduler supports token-input families only "
                "(vlm prompts need precomputed embeddings)")
        if spec_tokens > 0 and draft_params is None:
            raise ValueError("spec_tokens > 0 needs draft_params "
                             "(the population drafter)")
        self.cfg = cfg
        self.policy = policy
        self.layout = layout
        self.paged = layout == "paged"
        self.prefill_chunk = int(prefill_chunk)
        self.max_prefills_per_step = max_prefills_per_step
        self.min_prefill_bucket = min_prefill_bucket
        self.registry = registry
        self.watch_every = watch_every
        self.swap_mode = swap_mode
        self.spec_tokens = int(spec_tokens) if draft_params is not None \
            else 0
        self.spec_fused = bool(spec_fused)
        self.spec_adapt = bool(spec_adapt)
        self.max_queue = max_queue if max_queue is None else int(max_queue)
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None)")
        # the drafter may be a SMALLER arch than the target (per-session
        # configs); vocab compatibility is a hard precondition — draft
        # token ids index the target's embedding
        self.draft_cfg = draft_cfg if draft_cfg is not None else cfg
        if draft_params is not None and self.draft_cfg is not cfg:
            from repro.serve.registry import check_draft_compat
            check_draft_compat(cfg, self.draft_cfg)
        if max_seq is not None and max_seq != max_len and not self.paged:
            raise ValueError("layout='dense' caps requests at max_len")
        n_blocks = num_blocks if num_blocks is not None \
            else num_slots * blocks_for(max_len, block_size)
        # geometry the layout factory reads (subclasses reuse it when
        # building mesh-sharded pools)
        self._geom = {"num_slots": num_slots, "max_len": max_len,
                      "block_size": block_size, "n_blocks": n_blocks,
                      "num_blocks": num_blocks,
                      "max_seq": max_seq or max_len,
                      "pin_prefix": pin_prefix}
        self.pool = self._make_layout(cfg)
        self.max_seq = self.pool.max_seq if self.paged else max_len
        # ALL model calls go through sessions; the drafter is a second
        # session over its own (mirror-geometry) pool — same decode API
        self.session = self._make_session(cfg, params, self.pool)
        self.draft: Optional[DecodeSession] = None
        if draft_params is not None:
            self.draft = self._make_session(
                self.draft_cfg, draft_params,
                self._make_layout(self.draft_cfg))
        # right-padding prompts is only sound for pure-attention stacks:
        # recurrent layers (mamba/xLSTM) would fold padding into their
        # state, so those families prefill at exact prompt length
        # (one compile per distinct length instead of per bucket) —
        # and one-shot: chunked prefill needs mid-prompt resume, which
        # only the paged attention path supports.
        self._can_pad = all(s.kind == "a" for s in lm.layer_specs(cfg))
        self._draft_can_pad = all(
            s.kind == "a" for s in lm.layer_specs(self.draft_cfg))
        self._chunked = self.paged and self._can_pad
        self.prefix_sharing = bool(prefix_sharing) and self._chunked
        # ragged gather-width grouping only pays on the CPU oracle (the
        # Pallas kernel already skips per-row via pl.when) and needs
        # every cache leaf slot-free (attention-only paged stacks)
        self._group_decode = self.paged and self.pool.supports_row_subset \
            and jax.default_backend() != "tpu"
        self.queue: deque[Request] = deque()
        self.active: Dict[Any, _Active] = {}
        self.prefilling: Dict[Any, _Active] = {}
        # one-shot prefills admitted this step, run AFTER the admission
        # phase completes (on a mesh: after host 0's decisions are
        # broadcast — device work must be identical on every host)
        self._pending_onepass: List[_Active] = []
        self._pending_draft: List[Request] = []
        self._by_slot: Dict[int, _Active] = {}
        self._next_token = np.zeros((num_slots,), np.int32)
        # paged decode uses -1 as the "row holds no request" sentinel
        # (KV writes route to the null page); dense keeps 0 (the row is
        # the slot's own, writes are harmless)
        self._idle_index = -1 if self.paged else 0
        self._index = np.full((num_slots,), self._idle_index, np.int32)
        # per-row speculative depth (spec_adapt): proposals offered next
        # round for the request in each slot, adapted from its accept
        # history within [1, spec_tokens]
        self._spec_k = np.full((num_slots,), max(self.spec_tokens, 1),
                               np.int32)
        self.spec_k_by_rid: Dict[Any, int] = {}
        self.results: Dict[Any, np.ndarray] = {}
        self.stats = ServeStats(slots=num_slots)
        # request tracing + phase attribution + profiler window;
        # telemetry=False keeps the counters but drops the spans
        self.telemetry = ServeTelemetry(enabled=telemetry,
                                        trace_capacity=trace_capacity)
        # rank -> latest follower stats snapshot (mesh aggregation;
        # stays {} on a single-process scheduler)
        self.remote_stats: Dict[int, dict] = {}
        # fault tolerance: an optional write-ahead RequestJournal (one
        # fsync per step, batched below) and an optional FaultInjector
        # fired at the top of each step
        self.journal = journal
        self.faults = faults
        # online LTFB: the resident population roster + tournament
        # (serve/arena.py); drives drafter rotation and champion
        # promotions from inside step()
        self.arena = arena
        if arena is not None and (self.draft is None
                                  or self.spec_tokens <= 0):
            raise ValueError(
                "an online-LTFB arena scores challengers through the "
                "speculative path: pass draft_params (the active "
                "challenger's weights) and spec_tokens > 0")
        self._journal_tokens: Dict[Any, List[int]] = {}
        self._journal_finished: List[Any] = []
        self._pending_params = None
        self._head_share = None
        self._step_count = 0

    # -- construction hooks (the mesh scheduler overrides these) ------------
    def _make_layout(self, cfg: ModelConfig):
        g = self._geom
        if self.paged:
            return PagedLayout(cfg, g["num_slots"], g["n_blocks"],
                               block_size=g["block_size"],
                               max_seq=g["max_seq"],
                               pin_prefix=g["pin_prefix"])
        return SlotLayout(cfg, g["num_slots"], g["max_len"],
                          block_size=g["block_size"],
                          num_blocks=g["num_blocks"])

    def _make_session(self, cfg: ModelConfig, params,
                      layout) -> DecodeSession:
        return DecodeSession(cfg, params, layout)

    @property
    def params(self):
        """The TARGET weights currently serving (the session's tree)."""
        return self.session.params

    # -- request intake ----------------------------------------------------
    def _reject(self, msg: str):
        self.stats.rejected += 1
        raise ValueError(msg)

    def submit(self, req: Request) -> None:
        """Validate + enqueue a request (host-side only, non-blocking).

        Raises :class:`Overloaded` when the queue is at ``max_queue``
        (the caller should shed/backpressure, not retry in a loop) and
        ``ValueError`` for malformed requests (duplicate rid, empty
        prompt, budget over the pool ceiling, missing seed); both are
        counted in the ``[serve]`` stats.  Admission to the decode
        batch happens later, inside :meth:`step`.
        """
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            self.stats.shed_overload += 1
            raise Overloaded(
                f"request queue is at max_queue={self.max_queue}; "
                f"request {req.rid!r} shed (retry with backoff)")
        total = req.prompt_len + req.max_new
        if req.rid in self.active or req.rid in self.prefilling or \
                req.rid in self.results or \
                any(q.rid == req.rid for q in self.queue):
            self._reject(f"duplicate request id {req.rid!r}")
        if req.prompt_len < 1 or req.max_new < 1:
            self._reject("need a non-empty prompt and max_new >= 1")
        if total > self.max_seq:
            self._reject(
                f"request {req.rid!r} needs {total} tokens > the "
                f"per-request cap (max_len/max_seq {self.max_seq})")
        if blocks_for(total, self.pool.blocks.block_size) \
                > self.pool.blocks.num_blocks:
            self._reject(
                f"request {req.rid!r} exceeds the pool's total token "
                "budget")
        if req.temperature > 0.0 and req.seed is None:
            self._reject(
                f"request {req.rid!r}: temperature > 0 requires a seed "
                "(refusing to silently fall back to greedy)")
        self.stats.submitted += 1
        if self.journal is not None:
            self.journal.record_submit(req)
        req._submit_t = time.perf_counter()   # TTFT includes queueing delay
        self.queue.append(req)
        self.telemetry.req_instant(req.rid, "enqueue", t=req._submit_t,
                                   queue_depth=len(self.queue))

    # -- scheduling ---------------------------------------------------------
    def _bucket(self, n: int, cap: Optional[int] = None,
                can_pad: Optional[bool] = None) -> int:
        if not (self._can_pad if can_pad is None else can_pad):
            return n
        cap = cap or self.max_seq
        return min(max(self.min_prefill_bucket, _next_pow2(n)), cap)

    def _can_admit_head(self) -> bool:
        req = self.queue[0]
        total = req.prompt_len + req.max_new
        if self.draft is not None and \
                not self._pool_can_admit(self.draft.layout, total):
            return False
        return self._pool_can_admit(self.pool, total, head=True)

    def _pool_can_admit(self, pool, total: int, head: bool = False) -> bool:
        if not self.paged:
            return pool.can_admit(total)
        if not pool.free_slots:         # skip prefix hashing when full
            return False
        shared = ()
        if head:
            self._head_share = None
            if self.prefix_sharing:
                # cache the match: _admit reuses it instead of re-hashing
                req = self.queue[0]
                self._head_share = (req.rid,
                                    pool.find_shared_prefix(req.prompt))
                shared = self._head_share[1][0]
        return pool.can_admit(total, shared_pages=shared)

    def _admit(self, req: Request) -> None:
        """Claim slot + pages (host-side accounting ONLY — the prefill
        dispatch is deferred to :meth:`_prefill_phase`, so on a mesh
        every host issues identical device work after the admission
        decisions are broadcast)."""
        P = req.prompt_len
        total = P + req.max_new
        now = time.perf_counter()
        self.telemetry.req_span(req.rid, "queued",
                                getattr(req, "_submit_t", None), now)
        if not self.paged:
            slot = self.pool.admit(req.rid, total)
            self._admit_draft(req, slot, total)
            act = _Active(req=req, slot=slot, submit_t=getattr(
                req, "_submit_t", time.perf_counter()))
            self._spec_k[slot] = max(self.spec_tokens, 1)
            self._pending_onepass.append(act)
            self.telemetry.req_instant(req.rid, "admit", t=now, slot=slot)
            return
        head = getattr(self, "_head_share", None)
        shared = head[1] if head is not None and head[0] == req.rid \
            else None
        self._head_share = None
        slot, shared_len = self.pool.admit(
            req.rid, total, shared=shared,
            prompt=req.prompt if self.prefix_sharing else None)
        self._admit_draft(req, slot, total)
        act = _Active(req=req, slot=slot, pf_pos=shared_len,
                      submit_t=getattr(req, "_submit_t",
                                       time.perf_counter()))
        self._spec_k[slot] = max(self.spec_tokens, 1)
        self.telemetry.req_instant(req.rid, "admit", t=now, slot=slot,
                                   shared_prefix_tokens=shared_len)
        if self._chunked:
            # chunk slices run in _prefill_step, interleaved with decode
            self.prefilling[req.rid] = act
        else:
            self._pending_onepass.append(act)

    def _admit_draft(self, req: Request, slot: int, total: int) -> None:
        """Mirror an admission into the drafter's pool at the SAME slot
        (the two decode batches must stay row-aligned); the drafter's
        one-shot prompt prefill is deferred with the target's."""
        if self.draft is None:
            return
        if self.paged:
            d_slot, _ = self.draft.layout.admit(req.rid, total, slot=slot)
        else:
            d_slot = self.draft.layout.admit(req.rid, total, slot=slot)
        assert d_slot == slot, (d_slot, slot)
        self._pending_draft.append(req)

    def _prefill_draft(self, req: Request) -> None:
        bucket = self._bucket(req.prompt_len,
                              can_pad=self._draft_can_pad) \
            if self._draft_can_pad else None
        self.draft.prefill(req.rid, req.prompt, bucket=bucket)

    def _prefill_dense(self, act: _Active) -> None:
        req = act.req
        P = req.prompt_len
        bucket = self._bucket(P)
        t0 = time.perf_counter()
        last = self.session.prefill(req.rid, req.prompt, bucket=bucket)
        self.telemetry.req_span(req.rid, "prefill", t0, time.perf_counter(),
                                tokens=P, bucket=bucket)
        self.stats.prefills += 1
        self.stats.prefill_tokens += P
        self.stats.padded_prefill_tokens += bucket
        self._start_decoding(act, last)

    def _prefill_onepass_paged(self, act: _Active) -> None:
        """Exact-length one-shot prefill + page scatter (recurrent /
        hybrid families: their state cannot resume mid-prompt)."""
        req = act.req
        P = req.prompt_len
        t0 = time.perf_counter()
        last = self.session.prefill(req.rid, req.prompt, bucket=None)
        self.telemetry.req_span(req.rid, "prefill", t0, time.perf_counter(),
                                tokens=P)
        self.stats.prefills += 1
        self.stats.prefill_tokens += P
        self.stats.padded_prefill_tokens += P
        self._start_decoding(act, last)

    def _prefill_step(self) -> None:
        """Advance chunked prefills: one chunk per prefilling request,
        at most ``max_prefills_per_step`` chunk calls per step."""
        done = 0
        for act in list(self.prefilling.values()):
            if done >= self.max_prefills_per_step:
                break
            self._prefill_chunk_once(act)
            done += 1

    def _prefill_chunk_once(self, act: _Active) -> None:
        req = act.req
        P = req.prompt_len
        # one-shot (prefill_chunk=0) still buckets the chunk size, so a
        # mixed-length trace compiles per pow2 bucket, not per length
        chunk = self.prefill_chunk if self.prefill_chunk > 0 \
            else self._bucket(P)
        n = min(chunk, P - act.pf_pos)
        final = act.pf_pos + n >= P
        Cb = chunk if (not final or n == chunk) \
            else self._bucket(n, cap=chunk)
        self.pool.ensure(req.rid, act.pf_pos + n)
        W = self._table_bucket(act.pf_pos + n)
        t0 = time.perf_counter()
        last = self.session.prefill_chunk(
            req.rid, req.prompt[act.pf_pos:act.pf_pos + n],
            hist_len=act.pf_pos, prompt_len=P, chunk_bucket=Cb, width=W)
        self.telemetry.req_span(
            req.rid, "prefill_chunk", t0, time.perf_counter(),
            tokens=n, pos=act.pf_pos, prompt_len=P)
        act.pf_pos += n
        self.stats.prefills += 1
        self.stats.prefill_chunks += 1
        self.stats.prefill_tokens += n
        self.stats.padded_prefill_tokens += Cb
        if self.prefix_sharing:
            # pages fully covered by prefilled prompt tokens are
            # immutable from here on — offer them to future admissions
            # immediately, not only when the whole prompt is done
            self.pool.register_prefix(req.rid, req.prompt[:act.pf_pos])
        if final:
            del self.prefilling[req.rid]
            self._start_decoding(act, last)

    def _start_decoding(self, act: _Active, last_logits: np.ndarray) -> None:
        """Sample the first token off the prefill logits and move the
        request into the decode batch."""
        req = act.req
        self.active[req.rid] = act
        self._by_slot[act.slot] = act
        tok = self._sample(last_logits, req, 0)
        act.first_token_t = time.perf_counter()
        self.stats.ttft.append(act.first_token_t - act.submit_t)
        self.telemetry.req_instant(
            req.rid, "first_token", t=act.first_token_t,
            ttft_s=act.first_token_t - act.submit_t)
        self._accept_token(act, tok)

    def _sample(self, logits_row, req: Request, ntok: int) -> int:
        """logits_row: (V,) host array.  Sampling stays on host (Gumbel
        trick for temperature > 0) so the only device dispatch per step
        is the batched decode itself.  Deterministic in (seed, ntok) —
        which is what makes speculative decoding output-identical to
        target-only decoding at ANY temperature, not just greedy.
        ``ntok_base`` shifts the stream for journal-resumed requests,
        so sample k of the resumed run draws the same rng the
        uninterrupted run drew at position ntok_base + k."""
        if req.temperature <= 0.0:
            return int(np.argmax(logits_row))
        rng = np.random.default_rng([req.seed, req.ntok_base + ntok])
        g = rng.gumbel(size=logits_row.shape[-1])
        return int(np.argmax(
            np.asarray(logits_row, np.float64) / req.temperature + g))

    def _accept_token(self, act: _Active, tok: int) -> None:
        act.tokens.append(tok)
        act.ntok += 1
        self.stats.decode_tokens += 1
        if self.journal is not None:
            self._journal_tokens.setdefault(act.req.rid, []).append(tok)
        # write position of `tok`'s KV on the NEXT decode step
        self._index[act.slot] = act.req.prompt_len + act.ntok - 1
        self._next_token[act.slot] = tok
        done = act.ntok >= act.req.max_new or \
            (act.req.eos_id is not None and tok == act.req.eos_id)
        if done:
            self._finish(act)

    def _finish(self, act: _Active) -> None:
        rid = act.req.rid
        self.results[rid] = np.asarray(act.tokens, np.int32)
        if self.arena is not None:
            self.arena.record_finished(rid, act.req.prompt, act.tokens)
        if self.journal is not None:
            self._journal_finished.append(rid)
        if self.spec_adapt:
            self.spec_k_by_rid[rid] = int(self._spec_k[act.slot])
        self.stats.completed += 1
        now = time.perf_counter()
        self.stats.latency.append(now - act.submit_t)
        ttft = (act.first_token_t or now) - act.submit_t
        tpot = None
        if act.ntok > 1 and act.first_token_t is not None:
            tpot = (now - act.first_token_t) / (act.ntok - 1)
            self.stats.tpot.append(tpot)
        if act.req.ttft_deadline_ms is not None \
                and ttft * 1e3 > act.req.ttft_deadline_ms:
            self.stats.ttft_deadline_misses += 1
        if act.req.tpot_deadline_ms is not None and tpot is not None \
                and tpot * 1e3 > act.req.tpot_deadline_ms:
            self.stats.tpot_deadline_misses += 1
        self.telemetry.terminal(rid, "finish", t=now, ntok=act.ntok,
                                latency_s=now - act.submit_t)
        slot = self.pool.release(rid)
        if self.draft is not None:
            self.draft.layout.release(rid)
        del self.active[rid]
        del self._by_slot[slot]
        self._next_token[slot] = 0
        self._index[slot] = self._idle_index

    # -- cancellation / load shedding ---------------------------------------
    def cancel(self, rid) -> bool:
        """Drop a request wherever it is in its lifecycle.

        Queued requests leave the queue; prefilling/active requests
        release their slot and page reservations immediately (their
        partial tokens are NOT recorded in ``results`` — a streaming
        caller has already received them).  Returns True if the rid was
        found, False if it is unknown or already completed.  Host-side
        only and non-blocking; counted as ``cancelled``.
        """
        return self._cancel_now(rid, "cancel")

    def shed_expired(self) -> List[Any]:
        """Shed QUEUED requests whose TTFT deadline has already passed.

        A request that declared ``ttft_deadline_ms`` and has been
        queued longer than that can no longer meet its SLO, so
        admitting it wastes decode slots; it is dropped and counted as
        ``shed_deadline``.  Returns the shed rids (the gateway turns
        each into a 429-style deadline response).  In-flight requests
        are never shed — deadline misses there are counted at
        completion instead.
        """
        now = time.perf_counter()
        shed = [q.rid for q in self.queue
                if q.ttft_deadline_ms is not None
                and (now - getattr(q, "_submit_t", now)) * 1e3
                > q.ttft_deadline_ms]
        for rid in shed:
            self._cancel_now(rid, "deadline")
        return shed

    def _cancel_now(self, rid, reason: str) -> bool:
        """Immediately remove ``rid``; ``reason`` picks the counter
        ("cancel" -> cancelled, "deadline" -> shed_deadline)."""
        found = False
        for i, q in enumerate(self.queue):
            if q.rid == rid:
                del self.queue[i]
                found = True
                break
        if not found:
            act = self.active.get(rid) or self.prefilling.get(rid) or next(
                (a for a in self._pending_onepass if a.req.rid == rid),
                None)
            if act is None:
                return False
            # deferred device work for this rid must not run
            self._pending_onepass = [a for a in self._pending_onepass
                                     if a.req.rid != rid]
            self._pending_draft = [r for r in self._pending_draft
                                   if r.rid != rid]
            slot = self.pool.release(rid)
            if self.draft is not None:
                self.draft.layout.release(rid)
            self.active.pop(rid, None)
            self.prefilling.pop(rid, None)
            self._by_slot.pop(slot, None)
            self._next_token[slot] = 0
            self._index[slot] = self._idle_index
        if self._head_share is not None and self._head_share[0] == rid:
            self._head_share = None
        kind = "shed" if reason == "deadline" else "cancel"
        if self.journal is not None:
            self.journal.record_cancel(rid, reason)
            self._journal_tokens.pop(rid, None)
        self.telemetry.terminal(rid, kind, reason=reason)
        log_event(kind, rid=rid, reason=reason)
        if reason == "deadline":
            self.stats.shed_deadline += 1
        else:
            self.stats.cancelled += 1
        return True

    def set_params(self, params) -> None:
        """Hot-swap TARGET weights between steps (cache layout
        unchanged; the prefix cache is flushed — old-weight pages must
        not be shared into post-swap admissions).  The drafter keeps
        its own weights: draft tokens are only proposals, verified
        against the new target before acceptance."""
        self.session.set_params(params)
        if self.paged:
            self.pool.invalidate_prefix()
            self._head_share = None
        self.stats.hot_swaps += 1
        self.telemetry.event("hot_swap", step=self._step_count,
                             swaps=self.stats.hot_swaps)
        log_event("hot_swap", step=self._step_count,
                  swaps=self.stats.hot_swaps)

    @property
    def draining(self) -> bool:
        """True while new weights wait for in-flight requests to finish."""
        return self._pending_params is not None

    def _poll_registry(self) -> Optional[int]:
        """Poll for a newer winner; returns its step when one was
        loaded.  The ONLY nondeterministic scheduler decision (it reads
        the filesystem) — on a mesh, host 0 polls and broadcasts the
        answer so every host swaps to the same winner on the same step."""
        if self.registry is not None and self.watch_every > 0 \
                and self._step_count % self.watch_every == 0:
            found = self.registry.refresh()
            # mirror the registry's corrupt-swap rejections into the
            # step stats (exported at /metrics as a counter)
            self.stats.swap_rejected_corrupt = getattr(
                self.registry, "rejected_corrupt", 0)
            if found:
                return getattr(self.registry, "step", 0)
        return None

    def _apply_swap(self, winner: Optional[int]) -> None:
        """Deterministic half of the hot-swap: given host 0's poll
        result, apply/defer the swap per ``swap_mode``."""
        if winner is not None:
            if self.swap_mode == "drain" and (self.active
                                              or self.prefilling):
                self._pending_params = self.registry.params
            else:
                self._pending_params = None
                self.set_params(self.registry.params)
        if self._pending_params is not None and not self.active \
                and not self.prefilling:
            self.set_params(self._pending_params)
            self._pending_params = None

    def _maybe_hot_swap(self) -> None:
        self._apply_swap(self._poll_registry())

    # -- online LTFB arena (serve/arena.py) ----------------------------------
    def _arena_rotate(self) -> None:
        """Rotate the drafter session to the policy's pick for this
        step.  Pure function of (step, arena state) — every mesh host
        computes the same rotation without a broadcast."""
        if self.arena is None:
            return
        want = self.arena.drafter_for_step(self._step_count)
        if want != self.arena.active_drafter:
            self.arena.set_drafter(want)
            self.draft.set_params(self.arena.params[want])

    def _arena_decide(self) -> Optional[str]:
        """Host-0 half of a promotion: run the match evaluation, journal
        it, and — when the rule fires — run the transactional registry
        archive (checksum-verified) BEFORE anything mutates.  Returns
        the winner to broadcast, or None."""
        if self.arena is None:
            return None
        a = self.arena
        if a.forced is None and self._step_count % a.cfg.check_every != 0:
            return None
        winner = a.decide(self._step_count)
        self.stats.arena_matches = a.matches
        if self.journal is not None:
            self.journal.record_match(self._step_count, a.snapshot())
        if winner is None:
            return None
        prepared = a.prepare_promotion(winner)
        if prepared is None:
            # archive/export failed verification: abort, keep serving
            self.stats.swap_rejected_corrupt += 1
            return None
        return prepared

    def _arena_apply(self, winner: Optional[str]) -> None:
        """All-hosts half of a promotion: mutate arena state, journal
        the promotion (host 0; ordered BEFORE the weight swap so a torn
        record implies no swap), then hot-swap the target to the new
        champion — drain-aware, in-flight requests finish on the old
        weights."""
        if self.arena is None or winner is None:
            return
        a = self.arena
        loser = a.champion
        new_params = a.promote(winner, self._step_count)
        rec = a.last_promotion
        self.stats.arena_promotions = a.promotions
        if self.journal is not None:
            self.journal.record_promotion(
                self._step_count, winner, loser, rec["rate"],
                a.last_forced, a.snapshot())
        if self.swap_mode == "drain" and (self.active or self.prefilling):
            self._pending_params = new_params
        else:
            self._pending_params = None
            self.set_params(new_params)
        # the promotion recomputed the rotation; resync the drafter
        self.draft.set_params(a.params[a.active_drafter])
        log_event("arena_promotion", step=self._step_count,
                  winner=winner, loser=loser, rate=rec["rate"],
                  generation=a.generation)

    def arena_force(self, member: str) -> None:
        """Queue an admin promotion override (``POST /arena/promote``):
        the next match evaluation promotes ``member`` unconditionally —
        still through the transactional archive + drain-aware swap."""
        if self.arena is None:
            raise ValueError("no arena attached to this scheduler")
        if member not in self.arena.members:
            raise ValueError(
                f"unknown arena member {member!r}; roster is "
                f"{sorted(self.arena.members)}")
        self.arena.forced = member

    def _admission_phase(self) -> List[Any]:
        """Pop admissible queued requests and claim their slots/pages
        (host accounting only); returns the admitted rids in order —
        the decision record a mesh broadcasts."""
        admitted: List[Any] = []
        in_flight = bool(self.active or self.prefilling)
        if self.draining:
            return admitted
        if self.faults is not None \
                and self.faults.admission_blocked(self._step_count):
            return admitted       # injected pool exhaustion (oom@step)
        if self.policy == "static":
            if not in_flight:
                while self.queue and self._can_admit_head():
                    admitted.append(self.queue[0].rid)
                    self._admit(self.queue.popleft())
        else:
            while (len(admitted) < self.max_prefills_per_step
                   and self.queue and self._can_admit_head()):
                admitted.append(self.queue[0].rid)
                self._admit(self.queue.popleft())
        return admitted

    def _prefill_phase(self) -> None:
        """Run the device work admission deferred: drafter mirrors,
        one-shot prefills, then one round of chunked-prefill slices."""
        for req in self._pending_draft:
            self._prefill_draft(req)
        self._pending_draft.clear()
        for act in self._pending_onepass:
            if self.paged:
                self._prefill_onepass_paged(act)
            else:
                self._prefill_dense(act)
        self._pending_onepass.clear()
        if self.prefilling:
            self._prefill_step()

    def _decode_phase(self) -> None:
        if self.active:
            if self.spec_tokens > 0:
                self._spec_round()
            else:
                self._decode_round()

    def _timed_phases(self) -> None:
        """Run admission → prefill → decode with per-phase wall-time
        attribution (``telemetry.phase_seconds`` + step-timeline spans;
        spans are emitted only for phases that had work)."""
        tel = self.telemetry
        t0 = time.perf_counter()
        admitted = self._admission_phase()
        t1 = time.perf_counter()
        tel.phase("admit", t0, t1, emit=bool(admitted))
        had_pf = bool(self._pending_draft or self._pending_onepass
                      or self.prefilling)
        t0 = t1
        self._prefill_phase()
        t1 = time.perf_counter()
        tel.phase("prefill", t0, t1, emit=had_pf)
        had_dec = bool(self.active)
        t0 = t1
        self._decode_phase()
        tel.phase("decode", t0, time.perf_counter(), emit=had_dec)

    def profile_steps(self, steps: int, outdir: str) -> None:
        """Arm ``jax.profiler`` around the next ``steps`` scheduler
        steps (``--profile-steps`` / ``POST /debug/profile``): the
        trace starts at the next :meth:`step` and stops after the
        window closes; artifacts land under ``outdir``."""
        self.telemetry.arm_profile(steps, outdir)

    def _journal_step(self) -> None:
        """Commit this step's token emission + completions to the WAL
        — one batched write + fsync (see ``repro.serve.journal``)."""
        if self.journal is None:
            return
        self.journal.step_commit(self._journal_tokens,
                                 self._journal_finished)
        self._journal_tokens = {}
        self._journal_finished = []

    def step(self) -> None:
        """One scheduler iteration: hot-swap check, admission, chunked
        prefill, one batched decode (or speculative) round,
        completion."""
        self.stats.start()
        self.telemetry.step_begin(self._step_count + 1)
        if self.faults is not None:
            self.faults.on_step(self, self._step_count + 1)
        self._maybe_hot_swap()
        self._step_count += 1
        self._arena_rotate()
        self._arena_apply(self._arena_decide())
        self._timed_phases()
        self.stats.sample_step(len(self.queue),
                               len(self.active) + len(self.prefilling))
        self._journal_step()
        self.telemetry.step_end()

    # -- plain decode --------------------------------------------------------
    def _ensure_decode_pages(self, pool, last_token_pos: Dict[int, int]
                             ) -> None:
        """Materialize any page a row's upcoming writes land on.
        ``last_token_pos[slot]`` is the LAST write position of the
        round (ensure is idempotent; page boundaries are the only
        times new pages appear)."""
        bs = pool.block_size
        for act in self.active.values():
            first = int(self._index[act.slot])
            last = last_token_pos[act.slot]
            if first // bs != (first - 1) // bs or last // bs != first // bs:
                pool.ensure(act.req.rid, last + 1)

    def _width_split(self) -> List[tuple]:
        """Partition active rows for the ragged-gather fix: when one
        long request's pow2 table width is >= ``_SPLIT_RATIO``x every
        other row's, split the round into (narrow, wide) groups so the
        jnp oracle stops paying the long row's gather width for the
        whole batch.  Everything else stays ONE dispatch — per-call
        overhead beats gather savings until the spread is pathological.
        Returns [(width_bucket, [slots])]."""
        buckets = {act.slot: self._table_bucket(
            int(self._index[act.slot]) + 1)
            for act in self.active.values()}
        wide_w = max(buckets.values())
        narrow = [s for s, w in buckets.items() if w < wide_w]
        narrow_w = max((buckets[s] for s in narrow), default=0)
        if not self._group_decode or not narrow \
                or wide_w < self._SPLIT_RATIO * narrow_w:
            return [(wide_w, list(buckets))]
        wide = [s for s, w in buckets.items() if w == wide_w]
        return [(narrow_w, narrow), (wide_w, wide)]

    _SPLIT_RATIO = 4

    def _decode_round(self) -> None:
        if self.paged:
            targets = {a.slot: int(self._index[a.slot])
                       for a in self.active.values()}
            self._ensure_decode_pages(self.pool, targets)
            groups = self._width_split()
        else:
            groups = [(0, None)]
        self.stats.decode_steps += 1
        if len(groups) == 1:
            # common path: one full-batch dispatch
            width = groups[0][0] if self.paged else None
            logits = self.session.step(self._next_token[:, None],
                                       self._index, width=width)
            rows = np.asarray(logits.astype(jnp.float32))
            self.stats.decode_slot_steps += self.pool.num_slots
            # sample per active slot; finishing frees the slot in-place
            for act in list(self.active.values()):
                tok = self._sample(rows[act.slot, 0], act.req, act.ntok)
                self._accept_token(act, tok)
            return
        # ragged split: one subset dispatch per width group (row counts
        # pow2-bucketed so the compile count stays logarithmic)
        null = self.pool.null_page
        for W, slots in groups:
            n = min(_next_pow2(len(slots)), self.pool.num_slots)
            tokens = np.zeros((n, 1), np.int32)
            index = np.full((n,), -1, np.int32)
            tables = np.full((n, W), null, np.int32)
            for i, s in enumerate(slots):
                tokens[i, 0] = self._next_token[s]
                index[i] = self._index[s]
                tables[i] = self.pool.tables[s, :W]
            logits = self.session.step(tokens, index, tables=tables)
            rows = np.asarray(logits.astype(jnp.float32))
            self.stats.decode_slot_steps += n
            self.stats.ragged_splits += 1
            for i, s in enumerate(slots):
                act = self._by_slot.get(s)
                if act is not None:
                    tok = self._sample(rows[i, 0], act.req, act.ntok)
                    self._accept_token(act, tok)

    # -- speculative decode --------------------------------------------------
    def _spec_round(self) -> None:
        """One population-speculative round.

        The drafter proposes up to ``spec_tokens`` tokens per row
        (``spec_adapt`` modulates the depth per row from its accept
        history); the target verifies the row's pending token plus all
        proposals in ONE (K+1)-token ``session.step``; the accepted
        prefix (matching proposals + one target token — correction or
        bonus) is kept, so every emitted token is a TARGET sample and
        the output stream is identical to target-only decoding.

        **Fused drafting** (``spec_fused``, the default): the whole
        draft block is ONE dispatch — ``session.draft_block`` unrolls
        K+1 single-token decodes on device, feeding each greedy argmax
        into the next — and the host then RESAMPLES the proposals from
        the returned logits with the request's real sampling function.
        A round is 2 dispatches (draft + verify) instead of K+2.  At
        temperature 0 host resample == device greedy, so the drafter's
        cache is exactly right; at temperature > 0 a resample that
        diverges from the device feed leaves wrong tokens in the
        drafter's history, which the rollback below repairs (token
        identity is untouched either way — emitted tokens only ever
        come from the target).

        Rollback: the TARGET restores its recurrent snapshot + replays
        the accepted prefix when it kept fewer tokens than it fed
        (attention KV needs none: stale tail positions are causally
        masked and overwritten).  The DRAFTER additionally repairs
        rows whose device-fed block diverged from the host-resampled
        block — a replay write for attention KV, restore + replay for
        recurrent state.
        """
        B = self.pool.num_slots
        acts = list(self.active.values())
        t_rec = self.pool.has_recurrent
        d_rec = self.draft.layout.has_recurrent
        base = self._index.copy()
        # per-row cap: writes at base..base+cap-1 must stay inside the
        # prompt+max_new reservation (a cap-truncated row finishes this
        # round anyway)
        cap = np.zeros((B,), np.int32)
        for act in acts:
            k_row = int(self._spec_k[act.slot]) if self.spec_adapt \
                else self.spec_tokens
            cap[act.slot] = min(k_row + 1, act.req.max_new - act.ntok + 1)
        Kv = int(cap.max())
        if self.paged:
            targets = {a.slot: int(base[a.slot]) + int(cap[a.slot]) - 1
                       for a in acts}
            self._ensure_decode_pages(self.pool, targets)
            self._ensure_decode_pages(self.draft.layout, targets)
            W = self._table_bucket(int((base + cap).max()))
        else:
            W = None
        block = np.zeros((B, Kv), np.int32)
        block[:, 0] = self._next_token
        ntok0 = {act.slot: act.ntok for act in acts}

        d_snap = self.draft.snapshot() if d_rec else ()
        t_draft = time.perf_counter()
        if self.spec_fused:
            # -- fused draft: ONE dispatch for the whole block
            dlogits, dev = self.draft.draft_block(
                self._next_token[:, None], base, Kv, valid=cap, width=W)
            drows = np.asarray(dlogits.astype(jnp.float32))  # (B, Kv, V)
            dev = np.asarray(dev)                            # (B, Kv)
            self.stats.spec_draft_steps += 1
            for act in acts:
                s = act.slot
                for t in range(int(cap[s]) - 1):
                    block[s, t + 1] = self._sample(drows[s, t], act.req,
                                                   ntok0[s] + t)
        else:
            # -- sequential draft: Kv single-token steps (the last
            # feeds the final proposal so drafter and target caches
            # stay aligned when everything is accepted)
            for t in range(Kv):
                valid_t = (cap > t).astype(np.int32)
                idx_t = np.where(self._index >= 0, base + t,
                                 self._idle_index).astype(np.int32)
                logits = self.draft.step(block[:, t:t + 1], idx_t,
                                         valid=valid_t, width=W)
                self.stats.spec_draft_steps += 1
                if t + 1 >= Kv:
                    break
                rows = np.asarray(logits.astype(jnp.float32))
                for act in acts:
                    s = act.slot
                    if t + 1 < cap[s]:
                        block[s, t + 1] = self._sample(rows[s, 0], act.req,
                                                       ntok0[s] + t)
            dev = block          # the drafter was fed the host block
        t_verify = time.perf_counter()
        self.telemetry.phase("draft", t_draft, t_verify, k=Kv - 1)

        # -- target: verify the whole block in one K-token step
        t_snap = self.session.snapshot() if t_rec else ()
        vlogits = self.session.step(block, base, valid=cap, width=W)
        rows = np.asarray(vlogits.astype(jnp.float32))   # (B, Kv, V)
        self.telemetry.phase("verify", t_verify, time.perf_counter(), k=Kv)
        self.stats.decode_steps += 1
        self.stats.spec_rounds += 1
        self.stats.decode_slot_steps += B

        # -- acceptance: longest matching prefix + one target token
        fed_valid = np.zeros((B,), np.int32)
        for act in acts:
            s = act.slot
            c = int(cap[s])
            n0 = ntok0[s]
            appended = 0
            for t in range(c):
                g = self._sample(rows[s, t], act.req, n0 + t)
                self._accept_token(act, g)               # may finish
                appended += 1
                if act.req.rid not in self.active:
                    break
                if t + 1 >= c or g != int(block[s, t + 1]):
                    break
            fed_valid[s] = appended
            offered = max(0, c - 1)
            accepted = max(0, appended - 1)
            self.stats.spec_draft_proposed += offered
            self.stats.spec_draft_accepted += accepted
            if self.arena is not None:
                self.arena.record_spec(offered, accepted)
            if offered:
                self.stats.spec_k_sum += offered
                self.stats.spec_k_rows += 1
                if self.spec_adapt:
                    self._adapt_depth(act, offered, accepted)

        # -- rollback
        rb_t = np.zeros((B,), bool)
        rep_t = np.zeros((B,), np.int32)
        rb_d = np.zeros((B,), bool)
        rep_d = np.zeros((B,), np.int32)
        for act in acts:
            s = act.slot
            if act.req.rid not in self.active:
                continue
            fed = int(fed_valid[s])
            if fed < cap[s]:
                # target kept fewer than it fed: recurrent state (if
                # any) rolls back to the accepted prefix
                rb_t[s] = True
                rep_t[s] = fed
            diverged = dev[s, 1:fed].tolist() != block[s, 1:fed].tolist()
            if diverged or (d_rec and fed < cap[s]):
                rb_d[s] = True
                rep_d[s] = fed
        if t_rec and rb_t.any():
            self.session.restore(t_snap, rb_t)
            self.session.step(block, base, valid=rep_t, width=W)
            self.stats.spec_replays += 1
        if rb_d.any():
            if d_rec:
                self.draft.restore(d_snap, rb_d)
            self.draft.step(block, base, valid=rep_d, width=W)
            self.stats.spec_replays += 1

    def _adapt_depth(self, act: _Active, offered: int,
                     accepted: int) -> None:
        """Per-row speculative depth policy (``--spec-adapt``):
        additive increase on a fully accepted block, halve on a
        complete rejection, otherwise settle at what the row just
        proved it can absorb — bounded to [1, spec_tokens]."""
        k = int(self._spec_k[act.slot])
        if accepted >= offered:
            k = min(self.spec_tokens, k + 1)
        elif accepted == 0:
            k = max(1, k // 2)
        else:
            k = max(1, min(k, accepted + 1))
        self._spec_k[act.slot] = k
        self.spec_k_by_rid[act.req.rid] = k

    def _table_bucket(self, max_tokens: int) -> int:
        """Gather width (block-table columns) for this step: pow2-
        bucketed so compile count stays logarithmic while short batches
        never pay max_seq-width attention."""
        w = self.pool.table_width_for(max_tokens)
        return min(_next_pow2(w), self.pool.max_blocks_per_seq)

    def run(self, max_steps: Optional[int] = None) -> Dict[Any, np.ndarray]:
        """Drive until the queue and the batch drain; returns results
        (rid -> generated token ids)."""
        steps = 0
        while self.queue or self.active or self.prefilling:
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        self.stats.stop()
        return self.results

    def full_sequence(self, req: Request) -> np.ndarray:
        """Prompt + generated tokens for a completed request."""
        return np.concatenate([np.asarray(req.prompt, np.int32),
                               self.results[req.rid]])
