"""Online LTFB arena: live traffic runs the tournament.

The source paper's LTFB tournament picks winners *offline* between
training rounds; this module makes the selection *online*, scored by
production traffic itself.  An :class:`Arena` keeps an N-member roster
of population checkpoints resident in one scheduler (the per-session
``draft_cfg`` machinery generalized: the champion owns the target
session, one challenger at a time owns the drafter session, both share
the page pool), and the speculative-decode accept rate of the active
challenger drafting for the champion — a quality signal the spec path
already computes for free — becomes the match metric.

**Match scoring.**  Every speculative round contributes one
``(offered, accepted)`` sample per active row to the drafting member's
sliding window (:class:`MemberStats`; rates are zero-guarded so an
empty window or a zero-proposal drafter never surfaces as NaN).  The
scheduler evaluates a *match* every ``check_every`` steps.

**Promotion rule** (deterministic — on a mesh host 0 decides and the
name rides the :class:`~repro.serve.mesh.StepPlan`):

* *min-samples*: a challenger qualifies once its window holds at least
  ``min_samples`` offered proposals;
* *margin*: the best qualifying challenger's window accept rate must
  reach ``baseline + margin``, where ``baseline`` is the accept rate
  the current champion achieved when *it* was promoted (0 for the
  initial champion);
* *hysteresis*: the same challenger must win ``hysteresis``
  consecutive match evaluations before the promotion fires.

**Promotion mechanics** reuse the PR-8 transactional hot-swap: host 0
archives the dethroned champion to the registry as a dated generation
(``<pop>/arena/gen_NNNN_<date>_retired_<name>.ckpt`` + sha256
sidecar), exports and checksum-verifies the winner the same way, and
only then journals the promotion and swaps weights — drain-aware
(``swap_mode="drain"`` lets in-flight requests finish on the old
champion via the scheduler's ``_pending_params`` machinery).  A
failed verification aborts the promotion and the old champion keeps
serving.

**Durability.**  Every match evaluation and promotion is journaled
(``match`` / ``promotion`` records carrying a full :meth:`Arena.snapshot`),
so :func:`repro.serve.journal.replay_arena` reconstructs arena state
after a crash: promotions are applied iff their record is durable (a
torn promotion record means the swap never happened and the resumed
run serves the pre-promotion champion — token-identically, because the
weight swap is ordered *after* the journal sync).

**Write-back.**  Finished request/response streams (prompt + generated
tokens) are written as datastore token shards (:class:`TokenWriteback`,
``tokens_NNNNN.npz`` per ``repro.data.tokens``) so the next
``launch/ltfb.py`` training round ingests production traffic — the
train→serve→train loop.  A JSON state sidecar dedupes request ids
across crash/resume boundaries.

Routing policies (``--arena-policy``) pick which challenger drafts:

* ``champion`` — champion serves; the *best* challenger (by window
  rate) drafts, re-evaluated at stint boundaries (pure exploit);
* ``epsilon`` — mostly the best challenger, but every ~``1/epsilon``-th
  stint rotates round-robin through the roster (explore/exploit);
* ``shadow`` — round-robin every stint, so every challenger
  accumulates samples evenly (pure explore).
"""
from __future__ import annotations

import json
import os
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.telemetry import log_event

POLICIES = ("champion", "epsilon", "shadow")


def safe_rate(accepted: int, offered: int) -> float:
    """Accept rate guarded against empty windows / zero proposals.

    A drafter that has produced zero proposals has an *unknown* rate;
    reporting it as 0.0 (never NaN) keeps every downstream consumer —
    promotion rule, Prometheus export, JSON snapshots — total-ordered
    and JSON-safe.
    """
    return accepted / offered if offered > 0 else 0.0


@dataclass
class ArenaConfig:
    """Tunables for the online tournament (see the module docstring
    for how each one enters the promotion rule)."""

    policy: str = "champion"      # champion | epsilon | shadow
    window: int = 128             # sliding window, in spec row-rounds
    min_samples: int = 32         # offered proposals needed to qualify
    margin: float = 0.02          # rate must reach baseline + margin
    hysteresis: int = 2           # consecutive winning matches needed
    check_every: int = 8          # scheduler steps between matches
    rotate_every: int = 16        # steps per drafter stint
    epsilon: float = 0.25         # explore share of stints (epsilon)
    seq_len: int = 64             # write-back row width is seq_len + 1
    samples_per_file: int = 8     # write-back rows per token shard

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"unknown arena policy {self.policy!r} "
                             f"(choose from {POLICIES})")
        self.window = max(1, int(self.window))
        self.min_samples = max(1, int(self.min_samples))
        self.hysteresis = max(1, int(self.hysteresis))
        self.check_every = max(1, int(self.check_every))
        self.rotate_every = max(1, int(self.rotate_every))


class MemberStats:
    """One roster member's live scorecard.

    ``window`` holds the last ``maxlen`` per-row ``(offered, accepted)``
    speculative samples (the match metric reads only the window);
    ``offered``/``accepted`` accumulate for the member's lifetime;
    ``served_tokens`` counts tokens emitted while the member was the
    serving champion; ``promotions`` counts how many times it won.
    """

    def __init__(self, window: int):
        self.window: deque = deque(maxlen=int(window))
        self.offered = 0
        self.accepted = 0
        self.served_tokens = 0
        self.promotions = 0

    def add(self, offered: int, accepted: int) -> None:
        """Record one spec row-round's proposal/accept counts."""
        self.window.append((int(offered), int(accepted)))
        self.offered += int(offered)
        self.accepted += int(accepted)

    @property
    def win_offered(self) -> int:
        """Proposals offered inside the sliding window."""
        return sum(o for o, _ in self.window)

    @property
    def win_accepted(self) -> int:
        """Proposals accepted inside the sliding window."""
        return sum(a for _, a in self.window)

    @property
    def rate(self) -> float:
        """Window accept rate, zero-guarded (0.0 for an empty window)."""
        return safe_rate(self.win_accepted, self.win_offered)

    def as_dict(self) -> dict:
        """JSON-safe scorecard (journaled in match records)."""
        return {"window": [[o, a] for o, a in self.window],
                "offered": self.offered, "accepted": self.accepted,
                "rate": self.rate, "win_offered": self.win_offered,
                "served_tokens": self.served_tokens,
                "promotions": self.promotions}

    def load(self, d: dict) -> None:
        """Restore the scorecard from :meth:`as_dict` output."""
        self.window.clear()
        self.window.extend((int(o), int(a))
                           for o, a in d.get("window", []))
        self.offered = int(d.get("offered", 0))
        self.accepted = int(d.get("accepted", 0))
        self.served_tokens = int(d.get("served_tokens", 0))
        self.promotions = int(d.get("promotions", 0))


class TokenWriteback:
    """Served-stream → datastore token-shard writer (train→serve→train).

    Buffers one ``(seq_len + 1)``-token row per finished request
    (prompt + generated tokens, truncated or zero-padded) and writes a
    ``tokens_NNNNN.npz`` shard (``repro.data.tokens`` naming) whenever
    ``samples_per_file`` rows accumulate — every shard holds exactly
    that many rows, so ``DataStore``'s uniform-bundle check passes and
    ``launch/ltfb.py`` can list the directory as a training manifest.

    Crash safety: a ``writeback_state.json`` sidecar (atomic
    tmp+rename) records written request ids, pending rows and the next
    shard index after every mutation, so a restarted generation never
    writes a duplicate request id and never loses a buffered row.
    """

    STATE = "writeback_state.json"

    def __init__(self, root: str, seq_len: int, vocab: int,
                 samples_per_file: int = 8):
        self.root = root
        self.seq_len = int(seq_len)
        self.vocab = int(vocab)
        self.samples_per_file = max(1, int(samples_per_file))
        os.makedirs(root, exist_ok=True)
        self.written: set = set()
        self.pending: List[List[int]] = []   # rows awaiting a full shard
        self._pending_rids: List[str] = []
        self.shards_written = 0
        self.rows_written = 0
        self._load_state()

    # -- persistence --------------------------------------------------------
    def _state_path(self) -> str:
        return os.path.join(self.root, self.STATE)

    def _load_state(self) -> None:
        try:
            with open(self._state_path()) as f:
                st = json.load(f)
        except (FileNotFoundError, ValueError):
            from repro.data.tokens import list_token_shards
            existing = [p for p in list_token_shards(self.root)]
            self._next_shard = len(existing)
            return
        self.written = set(st.get("written", []))
        self.pending = [list(map(int, r)) for r in st.get("pending", [])]
        self._pending_rids = list(st.get("pending_rids", []))
        self._next_shard = int(st.get("next_shard", 0))
        self.rows_written = int(st.get("rows_written", 0))

    def _save_state(self) -> None:
        st = {"written": sorted(self.written),
              "pending": self.pending,
              "pending_rids": self._pending_rids,
              "next_shard": self._next_shard,
              "rows_written": self.rows_written}
        tmp = self._state_path() + ".tmp"
        with open(tmp, "w") as f:
            json.dump(st, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._state_path())

    # -- ingestion ----------------------------------------------------------
    def add(self, rid: Any, stream) -> bool:
        """Buffer one finished request/response stream as a shard row.

        ``stream`` is the full prompt + generated token sequence; it is
        truncated (or zero-padded) to ``seq_len + 1`` ids.  Returns
        False without writing when ``rid`` was already written back by
        this or a previous generation (crash/resume dedup).
        """
        key = str(rid)
        if key in self.written or key in self._pending_rids:
            return False
        toks = np.asarray(stream, np.int32).reshape(-1)
        width = self.seq_len + 1
        row = np.zeros((width,), np.int32)
        n = min(width, toks.shape[0])
        row[:n] = toks[:n]
        if int(row.max(initial=0)) >= self.vocab:
            raise ValueError(
                f"write-back row for request {rid!r} holds token id "
                f"{int(row.max())} >= vocab {self.vocab}")
        self.pending.append([int(t) for t in row])
        self._pending_rids.append(key)
        self._flush_full()
        self._save_state()
        return True

    def _flush_full(self) -> None:
        """Write every complete ``samples_per_file`` batch of pending
        rows as one uniform token shard."""
        from repro.data.tokens import shard_path
        while len(self.pending) >= self.samples_per_file:
            rows = self.pending[:self.samples_per_file]
            rids = self._pending_rids[:self.samples_per_file]
            path = shard_path(self.root, self._next_shard)
            np.savez(path, tokens=np.asarray(rows, np.int32))
            self._next_shard += 1
            self.shards_written += 1
            self.rows_written += len(rows)
            self.pending = self.pending[self.samples_per_file:]
            self._pending_rids = self._pending_rids[
                self.samples_per_file:]
            self.written.update(rids)

    def close(self) -> None:
        """Persist the final state (pending rows stay buffered for the
        next generation — shards must stay uniform for ``DataStore``)."""
        self._save_state()

    def as_dict(self) -> dict:
        """Progress counters for reports and snapshots."""
        return {"root": self.root, "shards": self._next_shard,
                "rows_written": self.rows_written,
                "pending_rows": len(self.pending),
                "written_rids": len(self.written)
                + len(self._pending_rids)}


class Arena:
    """The online tournament: roster, routing, match scoring, promotion.

    The scheduler drives it: :meth:`drafter_for_step` (every host,
    deterministic in the step count) picks which challenger drafts,
    :meth:`record_spec` / :meth:`record_finished` accumulate the match
    metric and the write-back stream, :meth:`decide` (host 0) applies
    the promotion rule, :meth:`prepare_promotion` (host 0) runs the
    checksum-verified registry transaction, and :meth:`promote` (every
    host, replaying host 0's broadcast decision) mutates roster state
    and hands back the new champion's weights for the drain-aware swap.
    """

    def __init__(self, members: Dict[str, Any], champion: str,
                 cfg: Optional[ArenaConfig] = None,
                 ckpt_dir: Optional[str] = None,
                 writeback: Optional[TokenWriteback] = None,
                 rank: int = 0):
        if len(members) < 2:
            raise ValueError(
                f"an arena needs >= 2 resident members, got "
                f"{sorted(members)} — train a larger population or "
                "serve without --arena")
        if champion not in members:
            raise ValueError(f"champion {champion!r} is not in the "
                             f"roster {sorted(members)}")
        self.cfg = cfg or ArenaConfig()
        self.order: List[str] = list(members)        # stable roster order
        self.members: Dict[str, MemberStats] = {
            n: MemberStats(self.cfg.window) for n in self.order}
        self.params: Dict[str, Any] = dict(members)
        self.champion = champion
        self.baseline = 0.0          # rate the champion was promoted at
        self.streak = 0
        self.streak_member: Optional[str] = None
        self.generation = 0
        self.matches = 0
        self.promotions = 0
        self.forced: Optional[str] = None   # POST /arena/promote override
        self.last_forced = False     # was the last decide() an override?
        self.last_promotion: Optional[dict] = None
        self.ckpt_dir = ckpt_dir
        self.writeback = writeback
        self.rank = int(rank)
        # training-lineage hookup: rank 0 of a from_population arena
        # appends promotion records to the population's genealogy log so
        # arena generations and LTFB rounds form one ancestry chain
        self.genealogy = None
        self.active_drafter = self.drafter_for_step(0)

    # -- construction --------------------------------------------------------
    @classmethod
    def from_population(cls, pop_dir: str, like_params,
                        cfg: Optional[ArenaConfig] = None,
                        step: Optional[int] = None,
                        writeback_dir: Optional[str] = None,
                        vocab: Optional[int] = None,
                        rank: int = 0) -> "Arena":
        """Build a roster from an LTFB population checkpoint dir.

        Loads every trainer of the newest population step (``step``
        overrides) as members ``trainer_<i>``; the initial champion is
        the trainer the offline tournament would export (most recorded
        wins).  Only rank 0 gets the registry dir (promotion archives)
        and the write-back writer — followers mirror state in memory.
        """
        from repro.serve.registry import (load_population_params,
                                          population_steps, select_winner)
        cfg = cfg or ArenaConfig()
        steps = population_steps(pop_dir)
        if not steps:
            raise FileNotFoundError(
                f"no population checkpoint in {pop_dir!r} — --arena "
                "needs a launch/ltfb.py checkpoint dir")
        s = step if step is not None else steps[-1]
        params, metas = load_population_params(pop_dir, s, like_params)
        idx, _ = select_winner(params, metas)
        members = {f"trainer_{i}": p for i, p in enumerate(params)}
        wb = None
        if writeback_dir and rank == 0:
            wb = TokenWriteback(writeback_dir, seq_len=cfg.seq_len,
                                vocab=int(vocab or 1 << 30),
                                samples_per_file=cfg.samples_per_file)
        arena = cls(members, f"trainer_{idx}", cfg,
                    ckpt_dir=pop_dir if rank == 0 else None,
                    writeback=wb, rank=rank)
        if rank == 0:
            from repro.train.telemetry import GenealogyLog
            arena.genealogy = GenealogyLog(
                os.path.join(pop_dir, "genealogy.jsonl"))
        return arena

    # -- routing -------------------------------------------------------------
    @property
    def challengers(self) -> List[str]:
        """Roster members other than the champion, in roster order."""
        return [n for n in self.order if n != self.champion]

    @property
    def champion_params(self):
        """The serving champion's weights (the scheduler's target)."""
        return self.params[self.champion]

    @property
    def drafter_params(self):
        """The active challenger's weights (the drafter session)."""
        return self.params[self.active_drafter]

    def best_challenger(self) -> str:
        """Highest window accept rate; roster order breaks ties (so
        every mesh host agrees without communicating)."""
        chs = self.challengers
        return max(chs, key=lambda n: (self.members[n].rate,
                                       -self.order.index(n)))

    def drafter_for_step(self, step: int) -> str:
        """The challenger that should draft at ``step`` — a pure
        function of (step, roster, windows), so every mesh host
        computes the same answer without a broadcast."""
        chs = self.challengers
        stint = step // self.cfg.rotate_every
        if self.cfg.policy == "shadow":
            return chs[stint % len(chs)]
        if self.cfg.policy == "epsilon":
            period = max(1, round(1.0 / max(self.cfg.epsilon, 1e-9)))
            if stint % period == 0:
                return chs[(stint // period) % len(chs)]
        return self.best_challenger()

    def set_drafter(self, name: str) -> None:
        """Record a drafter rotation (the scheduler swaps the session
        weights; this just tracks attribution)."""
        self.active_drafter = name

    # -- match metric --------------------------------------------------------
    def record_spec(self, offered: int, accepted: int) -> None:
        """Attribute one spec row-round to the active drafter."""
        self.members[self.active_drafter].add(offered, accepted)

    def record_finished(self, rid: Any, prompt, tokens) -> None:
        """Account a completed request: served tokens credit the
        champion; the full stream lands in the write-back buffer."""
        self.members[self.champion].served_tokens += len(tokens)
        if self.writeback is not None:
            stream = list(np.asarray(prompt, np.int32)) + list(tokens)
            self.writeback.add(rid, stream)

    # -- promotion rule ------------------------------------------------------
    def decide(self, step: int) -> Optional[str]:
        """One match evaluation; returns the member to promote or None.

        Deterministic in arena state (host 0 calls this; followers
        replay the result from the broadcast plan).  A pending admin
        override (:attr:`forced`) wins immediately — still subject to
        the transactional swap, but not to min-samples/margin.
        """
        self.matches += 1
        self.last_forced = False
        if self.forced is not None:
            forced, self.forced = self.forced, None
            if forced in self.members and forced != self.champion:
                self.last_forced = True
                return forced
        cand = self.best_challenger()
        m = self.members[cand]
        ok = (m.win_offered >= self.cfg.min_samples
              and m.rate >= self.baseline + self.cfg.margin)
        if ok and cand == self.streak_member:
            self.streak += 1
        else:
            self.streak = 1 if ok else 0
            self.streak_member = cand if ok else None
        if self.streak >= self.cfg.hysteresis:
            return cand
        return None

    def prepare_promotion(self, winner: str) -> Optional[str]:
        """Host-0 transactional half of a promotion (file I/O only).

        Archives the dethroned champion to the registry as a dated
        generation, exports the winner the same way, and verifies the
        winner's checksum sidecar — all *before* any state mutates.
        Returns ``winner`` on success, None when the export failed
        verification (the promotion is aborted; the old champion keeps
        serving — same contract as the corrupt-winner quarantine).
        """
        if self.ckpt_dir is None or self.rank != 0:
            return winner
        from repro.serve import registry as reg
        gen = self.generation + 1
        try:
            reg.archive_member(self.ckpt_dir, self.champion,
                               self.params[self.champion], gen,
                               tag="retired")
            path = reg.archive_member(self.ckpt_dir, winner,
                                      self.params[winner], gen,
                                      tag="champion")
            reg.verify_checkpoint(path)
        except (OSError, ValueError) as e:
            print(f"[arena] promotion of {winner!r} ABORTED: "
                  f"{type(e).__name__}: {e} — champion "
                  f"{self.champion!r} keeps serving", flush=True)
            log_event("arena_promotion_aborted", winner=winner,
                      error=str(e))
            return None
        return winner

    def promote(self, winner: str, step: int) -> Any:
        """Apply a promotion (every host, deterministically).

        The winner becomes champion, its window rate becomes the new
        ``baseline``, every window and the hysteresis streak reset
        (accept rates against the new champion are a fresh
        measurement), and the drafter rotation is recomputed.  Returns
        the new champion's weights for the scheduler's drain-aware
        swap.
        """
        record = {"winner": winner, "loser": self.champion,
                  "rate": self.members[winner].rate, "step": int(step)}
        self.baseline = record["rate"]
        self.members[winner].promotions += 1
        self.champion = winner
        self.generation += 1
        self.promotions += 1
        self.streak = 0
        self.streak_member = None
        for m in self.members.values():
            m.window.clear()
        self.active_drafter = self.drafter_for_step(step)
        self.last_promotion = record
        if self.genealogy is not None:
            self.genealogy.append(
                "promotion", winner=winner, loser=record["loser"],
                rate=record["rate"], step=record["step"],
                generation=self.generation)
            self.genealogy.sync()
        log_event("arena_promotion", winner=winner,
                  loser=record["loser"], rate=record["rate"],
                  step=record["step"], generation=self.generation)
        return self.params[winner]

    # -- durability ----------------------------------------------------------
    def snapshot(self) -> dict:
        """Full JSON-safe arena state: journaled with every match and
        promotion record, served at ``GET /population``, restored by
        :meth:`restore` after a crash."""
        return {"policy": self.cfg.policy,
                "champion": self.champion,
                "drafter": self.active_drafter,
                "baseline": self.baseline,
                "streak": self.streak,
                "streak_member": self.streak_member,
                "generation": self.generation,
                "matches": self.matches,
                "promotions": self.promotions,
                "order": list(self.order),
                "members": {n: self.members[n].as_dict()
                            for n in self.order},
                "writeback": (self.writeback.as_dict()
                              if self.writeback is not None else None)}

    def restore(self, state: Optional[dict]) -> None:
        """Rebuild arena state from a journaled snapshot (see
        :func:`repro.serve.journal.replay_arena`).  Weights are NOT in
        the journal — the roster must already hold every member named
        by the snapshot; promotions are reconstructed by pointing
        ``champion`` back at the journaled name."""
        if not state:
            return
        missing = [n for n in state.get("order", [])
                   if n not in self.members]
        if missing:
            raise ValueError(
                f"journal names arena member(s) {missing} that the "
                f"roster {sorted(self.members)} does not hold — resume "
                "with the same population dir the journal was written "
                "against")
        self.champion = state["champion"]
        self.baseline = float(state.get("baseline", 0.0))
        self.streak = int(state.get("streak", 0))
        self.streak_member = state.get("streak_member")
        self.generation = int(state.get("generation", 0))
        self.matches = int(state.get("matches", 0))
        self.promotions = int(state.get("promotions", 0))
        for n, d in state.get("members", {}).items():
            self.members[n].load(d)
        self.active_drafter = state.get("drafter")
        if self.active_drafter not in self.challengers:
            self.active_drafter = self.drafter_for_step(0)

    # -- export --------------------------------------------------------------
    def counters(self) -> dict:
        """Compact per-member counters for telemetry snapshots and the
        Prometheus exporter (rates zero-guarded, never NaN)."""
        return {"champion": self.champion,
                "drafter": self.active_drafter,
                "promotions": self.promotions,
                "matches": self.matches,
                "members": {n: {"accept_rate": self.members[n].rate,
                                "served_tokens":
                                    self.members[n].served_tokens,
                                "offered": self.members[n].offered,
                                "accepted": self.members[n].accepted}
                            for n in self.order}}

    def close(self) -> None:
        """Flush the write-back state sidecar and the genealogy log
        (idempotent)."""
        if self.writeback is not None:
            self.writeback.close()
        if self.genealogy is not None:
            self.genealogy.close()

    def report(self, log=print, prefix: str = "[arena]") -> None:
        """Print the human-readable arena summary lines."""
        log(f"{prefix} policy={self.cfg.policy} champion={self.champion} "
            f"generation={self.generation} matches={self.matches} "
            f"promotions={self.promotions} baseline={self.baseline:.2f}")
        for n in self.order:
            m = self.members[n]
            tag = "champion" if n == self.champion else (
                "drafting" if n == self.active_drafter else "idle")
            log(f"{prefix}   {n}: rate={m.rate:.2f} "
                f"accepted={m.accepted}/{m.offered} "
                f"served_tokens={m.served_tokens} "
                f"promotions={m.promotions} [{tag}]")
        if self.writeback is not None:
            w = self.writeback.as_dict()
            log(f"{prefix} write-back: {w['shards']} shard(s), "
                f"{w['rows_written']} row(s) in {w['root']} "
                f"(+{w['pending_rows']} pending)")
