"""Batched serving engine: prefill + KV-cache decode.

``decode_shapes``/``long_*`` dry-run cells lower exactly the
``engine.decode_step`` function.  ``generate`` is a host-driven loop
over ONE uniform-length batch (greedy or temperature sampling); for
request-level scheduling — queueing, continuous batching, slot reuse,
hot-swap — use :class:`repro.serve.scheduler.Scheduler`.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm


class Engine:
    def __init__(self, cfg: ModelConfig, params, max_len: int = 1024):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, b: lm.lm_prefill(p, cfg, b))
        self._decode = jax.jit(
            lambda p, t, c, i: lm.lm_decode(p, cfg, t, c, i),
            donate_argnums=(2,))
        # full-length cache templates, allocated ONCE per batch size and
        # reused across generate() calls (never donated); continuous
        # batching across requests lives in repro.serve.scheduler
        self._cache_templates: dict = {}
        self._fit = jax.jit(
            lambda full, cache: jax.tree.map(_fit_leaf, full, cache))

    def _pad_cache(self, cache, batch: int):
        if batch not in self._cache_templates:
            self._cache_templates[batch] = \
                lm.init_cache(self.cfg, batch, self.max_len)[0]
        return self._fit(self._cache_templates[batch], cache)

    def generate(self, tokens: jax.Array, steps: int,
                 temperature: float = 0.0,
                 key: Optional[jax.Array] = None) -> jax.Array:
        """tokens: (B, S_prompt) int32 -> (B, S_prompt + steps)."""
        B, S = tokens.shape
        assert S + steps <= self.max_len
        logits, cache = self._prefill(self.params, {"tokens": tokens})
        cache = self._pad_cache(cache, B)
        out = [tokens]
        cur = self._sample(logits[:, -1], temperature, key, 0)
        for i in range(steps):
            out.append(cur)
            if i == steps - 1:
                break
            logits, cache = self._decode(self.params, cur, cache,
                                         jnp.int32(S + i))
            cur = self._sample(logits[:, -1], temperature, key, i + 1)
        return jnp.concatenate(out, axis=1)

    def _sample(self, logits, temperature, key, i):
        if temperature > 0.0 and key is None:
            raise ValueError(
                "temperature > 0 requires a PRNG key (refusing to "
                "silently fall back to greedy)")
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        k = jax.random.fold_in(key, i)
        return jax.random.categorical(
            k, logits / temperature, axis=-1)[:, None].astype(jnp.int32)


def _fit_leaf(dst, src):
    """Write `src` into the start of `dst` (zero template row)."""
    if dst.shape == src.shape:
        return src
    return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype),
                                        (0,) * dst.ndim)
