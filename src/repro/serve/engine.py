"""Batched serving engine: prefill + KV-cache decode.

``decode_shapes``/``long_*`` dry-run cells lower exactly the
``engine.decode_step`` function.  ``generate`` is a host-driven loop
over ONE uniform-length batch (greedy or temperature sampling), built
on the same :class:`repro.serve.session.DecodeSession` +
:class:`repro.serve.kv_cache.SlotLayout` surface the scheduler uses —
one decode API, no engine-private cache plumbing.  For request-level
scheduling — queueing, continuous batching, slot reuse, hot-swap,
speculative decoding — use :class:`repro.serve.scheduler.Scheduler`.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.serve.kv_cache import SlotLayout
from repro.serve.session import DecodeSession


class Engine:
    """Batched decode executor: prefill + single-token step dispatches
    over a (possibly sharded) model replica."""

    def __init__(self, cfg: ModelConfig, params, max_len: int = 1024,
                 mesh=None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        # with a ("data", "model") mesh the batch decodes sharded:
        # weights-stationary TP over `model`, cache rows over `data`
        # (see repro.serve.mesh; batch must divide the data axis)
        self.mesh = mesh
        # one DecodeSession per batch size, created lazily and reused
        # across generate() calls (the layout's pool is allocated once;
        # jitted executables are module-level and shared regardless)
        self._sessions: Dict[int, DecodeSession] = {}

    def _session(self, batch: int) -> DecodeSession:
        if batch not in self._sessions:
            if self.mesh is not None:
                from repro.serve.mesh import make_engine_session
                self._sessions[batch] = make_engine_session(
                    self.cfg, self.params, self.mesh, batch,
                    self.max_len)
            else:
                self._sessions[batch] = DecodeSession(
                    self.cfg, self.params,
                    SlotLayout(self.cfg, batch, self.max_len))
        sess = self._sessions[batch]
        sess.set_params(self.params)    # pick up any weight swap
        return sess

    def generate(self, tokens: jax.Array, steps: int,
                 temperature: float = 0.0,
                 key: Optional[jax.Array] = None) -> jax.Array:
        """tokens: (B, S_prompt) int32 -> (B, S_prompt + steps)."""
        B, S = tokens.shape
        assert S + steps <= self.max_len
        sess = self._session(B)
        logits = sess.prefill_batch(tokens)
        out = [tokens]
        cur = self._sample(logits[:, -1], temperature, key, 0)
        index = jnp.full((B,), S, jnp.int32)
        for i in range(steps):
            out.append(cur)
            if i == steps - 1:
                break
            logits = sess.step(cur, index + i)
            cur = self._sample(logits[:, -1], temperature, key, i + 1)
        return jnp.concatenate(out, axis=1)

    def _sample(self, logits, temperature, key, i):
        if temperature > 0.0 and key is None:
            raise ValueError(
                "temperature > 0 requires a PRNG key (refusing to "
                "silently fall back to greedy)")
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        k = jax.random.fold_in(key, i)
        return jax.random.categorical(
            k, logits / temperature, axis=-1)[:, None].astype(jnp.int32)
