"""Write-ahead request journal: crash recovery for the serving stack.

Every admitted request and every emitted token is recorded in an
append-only JSONL file, flushed every scheduler step and fsync'd at a
bounded interval, so a new
scheduler/gateway generation can requeue unfinished work after a crash
(SIGKILL, dead mesh peer, OOM) or a graceful SIGTERM restart — and,
because host sampling is deterministic in ``(seed, ntok)``, resume
emission **token-identically** from the last journaled token.

Record types (one JSON object per line):

  * ``submit``   — the full request encoding at admission time
    (prompt ids, ``max_new``, ``eos_id``, ``temperature``, ``seed``,
    ``ntok_base``, optional gateway ``Idempotency-Key``).
  * ``tokens``   — one batched record per scheduler step mapping
    ``rid -> [tokens appended this step]``.
  * ``finish``   — rids completed this step (written AFTER their
    tokens, same flush).
  * ``cancel``   — a request cancelled/shed before completion.
  * ``note``     — free-form operational marker (``peer_death``,
    ``shutdown``) so a replay can tell a clean drain from a crash.
  * ``match``    — one online-LTFB arena match evaluation
    (``serve/arena.py``), carrying the full arena snapshot.
  * ``promotion`` — an arena champion promotion: winner/loser/rate plus
    the post-promotion arena snapshot.  Synced IMMEDIATELY and written
    BEFORE the weight swap, so a resumed generation serves the new
    champion iff the record is durable (see :func:`replay_arena`).

Replay ignores record types it does not know, so journals written by a
newer arena-enabled server still replay on older readers.

Durability contract: :meth:`RequestJournal.step_commit` performs ONE
``write + flush`` per scheduler step (submits and cancels fsync
immediately — they happen between steps and must never be lost once
acknowledged).  The flush lands the step's records in the OS page
cache, which survives a *process* death (SIGKILL, OOM-kill, segfault)
— the kill-recovery tests and CI lane rely on exactly this.  The
``fsync`` that additionally survives a *machine* death (power loss,
kernel panic) is issued at a bounded wall-clock interval
(``fsync_interval_s``, default 250 ms, 0 = every step): on a real
accelerator a decode step outlasts the interval and every step syncs,
while on fast-step CPU runs the disk barrier amortizes across a few
steps — which is what keeps the fig14 ``paged_journal`` arm inside the
<= 5% tokens/s budget.  A machine loss therefore costs at most the
last interval's tokens, a process crash at most the in-progress step,
and :func:`replay` tolerates a torn final line.  Losing steps is
harmless for token identity either way: the resumed request re-derives
the lost tokens deterministically.

Resume model — *a resumed request is just a longer prompt*.  For an
unfinished journal entry with ``k`` emitted tokens, :func:`resume_request`
rebuilds the request as ``prompt = original_prompt + emitted``,
``max_new = original_max_new - k`` and ``ntok_base = k``.  The
scheduler's sampler seeds ``rng([seed, ntok_base + ntok])``, so decode
step ``j`` of the resumed run conditions on exactly the tokens and rng
stream the uninterrupted run used at step ``k + j`` — pool budget,
write positions, EOS and speculative decoding all hold automatically.
The new generation's ``results[rid]`` holds only the NEW tokens;
:func:`stitched_results` prepends the journaled prefix to recover the
full stream.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np


def _encode_req(req) -> dict:
    """Journal encoding of a Request (wire-stable, JSON-only types)."""
    return {
        "rid": req.rid,
        "prompt": np.asarray(req.prompt, np.int32).tolist(),
        "max_new": int(req.max_new),
        "eos_id": None if req.eos_id is None else int(req.eos_id),
        "temperature": float(req.temperature),
        "seed": None if req.seed is None else int(req.seed),
        "ntok_base": int(getattr(req, "ntok_base", 0)),
        "idem_key": getattr(req, "idem_key", None),
    }


class RequestJournal:
    """Append-only fsync'd WAL attached to ONE scheduler generation.

    The scheduler calls :meth:`record_submit` / :meth:`record_cancel`
    as they happen (each fsyncs immediately) and batches per-step token
    emission + completions into one :meth:`step_commit` — flushed every
    step, fsync'd at a bounded wall-clock interval, which is what keeps
    the fig14 journal arm inside the 5% tokens/s budget.
    """

    def __init__(self, path: str, fsync: bool = True,
                 fsync_interval_s: float = 0.25):
        self.path = path
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        self._f = open(path, "ab")
        self._fsync = bool(fsync)
        self._interval = max(0.0, float(fsync_interval_s))
        self._last_fsync = time.monotonic()
        self.records = 0

    def _append(self, rec: dict) -> None:
        self._f.write(json.dumps(rec, separators=(",", ":")).encode()
                      + b"\n")
        self.records += 1

    def _sync(self) -> None:
        """Full durability barrier: returns with all records on disk."""
        self._f.flush()
        if self._fsync:
            os.fsync(self._f.fileno())
            self._last_fsync = time.monotonic()

    def _sync_step(self) -> None:
        """Per-step barrier: flush always (survives process death via
        the page cache), fsync only when the interval elapsed (bounds
        the machine-death loss window without putting a disk barrier on
        every decode step)."""
        self._f.flush()
        if self._fsync and \
                time.monotonic() - self._last_fsync >= self._interval:
            os.fsync(self._f.fileno())
            self._last_fsync = time.monotonic()

    def record_submit(self, req) -> None:
        """Journal an accepted submit (synced immediately: admission
        happens between steps, outside the per-step batch)."""
        self._append({"t": "submit", "req": _encode_req(req)})
        self._sync()

    def record_cancel(self, rid, reason: str) -> None:
        """Journal a cancellation/shed; the rid will not be resumed."""
        self._append({"t": "cancel", "rid": rid, "reason": reason})
        self._sync()

    def record_note(self, kind: str, **fields) -> None:
        """Journal an operational marker (``peer_death``, ``shutdown``)."""
        rec = {"t": "note", "kind": kind}
        rec.update(fields)
        self._append(rec)
        self._sync()

    def record_match(self, step: int, arena: dict) -> None:
        """Journal one arena match evaluation with the full arena
        snapshot — replayed by :func:`replay_arena` so sliding windows
        and hysteresis streaks survive a crash."""
        self._append({"t": "match", "step": int(step), "arena": arena})
        self._sync()

    def record_promotion(self, step: int, winner: str, loser: str,
                         rate: float, forced: bool,
                         arena: dict) -> None:
        """Journal an arena promotion (synced immediately, BEFORE the
        weight swap): ``arena`` is the post-promotion snapshot, so a
        torn record means the swap never happened and replay lands on
        the pre-promotion state — either way consistent."""
        self._append({"t": "promotion", "step": int(step),
                      "winner": winner, "loser": loser,
                      "rate": float(rate), "forced": bool(forced),
                      "arena": arena})
        self._sync()

    def step_commit(self, tokens: Dict[Any, List[int]],
                    finished: List[Any]) -> None:
        """Commit one scheduler step: tokens appended per rid, then the
        rids that completed — ONE write + flush for the whole step,
        fsync'd when the interval elapsed."""
        if not tokens and not finished:
            return
        if tokens:
            self._append({"t": "tokens",
                          "toks": {str(r): t for r, t in tokens.items()}})
        if finished:
            self._append({"t": "finish", "rids": list(finished)})
        self._sync_step()

    def close(self) -> None:
        """Flush, fsync and close the journal file (idempotent)."""
        if not self._f.closed:
            self._sync()
            self._f.close()


@dataclass
class JournalEntry:
    """Replayed per-request state: the original request encoding, the
    tokens emitted before the cut, and whether it completed."""

    req: dict
    tokens: List[int] = field(default_factory=list)
    done: bool = False
    cancelled: bool = False


def replay(path: str) -> Dict[Any, JournalEntry]:
    """Rebuild per-request state from a journal file.

    Tolerates a torn final line (the generation died mid-write): replay
    stops at the first undecodable record.  Returns ``rid ->``
    :class:`JournalEntry`; rids are the journal's JSON representation
    (``tokens`` records key by ``str(rid)``, matched back to the submit
    record's rid).
    """
    entries: Dict[Any, JournalEntry] = {}
    by_str: Dict[str, Any] = {}
    try:
        raw = open(path, "rb").read()
    except FileNotFoundError:
        return entries
    for line in raw.split(b"\n"):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except ValueError:
            break                       # torn tail — stop replay here
        t = rec.get("t")
        if t == "submit":
            rid = rec["req"]["rid"]
            entries[rid] = JournalEntry(req=rec["req"])
            by_str[str(rid)] = rid
        elif t == "tokens":
            for srid, toks in rec.get("toks", {}).items():
                rid = by_str.get(srid)
                if rid in entries:
                    entries[rid].tokens.extend(int(x) for x in toks)
        elif t == "finish":
            for rid in rec.get("rids", []):
                rid = by_str.get(str(rid), rid)
                if rid in entries:
                    entries[rid].done = True
        elif t == "cancel":
            rid = rec.get("rid")
            rid = by_str.get(str(rid), rid)
            if rid in entries:
                entries[rid].cancelled = True
        # "note" records carry no per-request state
    return entries


def replay_arena(path: str) -> Optional[dict]:
    """Reconstruct arena state from a journal: the LAST durable
    ``match``/``promotion`` record's snapshot (None when the journal
    holds neither).

    Stops at the first undecodable line, exactly like :func:`replay`:
    a promotion record torn mid-write is NOT durable, and because the
    journal sync is ordered before the weight swap, the crashed
    generation never served the new champion — so resuming from the
    preceding snapshot is token-identical.
    """
    state: Optional[dict] = None
    try:
        raw = open(path, "rb").read()
    except FileNotFoundError:
        return None
    for line in raw.split(b"\n"):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except ValueError:
            break                       # torn tail — stop replay here
        if rec.get("t") in ("match", "promotion"):
            arena = rec.get("arena")
            if isinstance(arena, dict):
                state = arena
    return state


def resume_request(entry: JournalEntry):
    """Build the resume request for one unfinished entry.

    Returns ``(Request, prefix)`` where ``prefix`` is the already-
    emitted token list.  The request's prompt is the original prompt
    plus the prefix, ``max_new`` is the remaining budget and
    ``ntok_base`` offsets the sampler's rng stream — see the module
    docstring for why this is token-identical to the uninterrupted run.
    """
    from repro.serve.scheduler import Request
    r = entry.req
    prefix = list(entry.tokens)
    k = len(prefix)
    base = int(r.get("ntok_base", 0))
    prompt = np.asarray(list(r["prompt"]) + prefix, np.int32)
    req = Request(rid=r["rid"], prompt=prompt,
                  max_new=int(r["max_new"]) - k,
                  eos_id=r.get("eos_id"),
                  temperature=float(r.get("temperature", 0.0)),
                  seed=r.get("seed"),
                  ntok_base=base + k,
                  idem_key=r.get("idem_key"))
    return req, prefix


def resume_scheduler(sched, entries: Dict[Any, JournalEntry]
                     ) -> Dict[Any, List[int]]:
    """Requeue unfinished journal entries into a fresh scheduler.

    Finished entries preload ``sched.results`` directly (so client
    retries and out-json see them); cancelled entries are skipped;
    unfinished entries are re-submitted as resume requests.  Returns
    ``rid -> journaled prefix`` for the resumed rids (feed it to
    :func:`stitched_results` once the run completes) and sets
    ``stats.journal_replayed`` to the resumed count.
    """
    prefixes: Dict[Any, List[int]] = {}
    for rid, e in entries.items():
        if e.cancelled:
            continue
        hit_eos = e.req.get("eos_id") is not None and e.tokens \
            and e.tokens[-1] == e.req["eos_id"]
        if e.done or len(e.tokens) >= int(e.req["max_new"]) or hit_eos:
            sched.results[rid] = np.asarray(e.tokens, np.int32)
            continue
        req, prefix = resume_request(e)
        sched.submit(req)
        prefixes[rid] = prefix
    sched.stats.journal_replayed += len(prefixes)
    return prefixes


def stitched_results(results: Dict[Any, np.ndarray],
                     prefixes: Dict[Any, List[int]]
                     ) -> Dict[Any, np.ndarray]:
    """Full token streams: journaled prefix + this generation's tokens
    for resumed rids, pass-through for everything else."""
    out: Dict[Any, np.ndarray] = {}
    for rid, toks in results.items():
        pre = prefixes.get(rid)
        if pre:
            out[rid] = np.concatenate(
                [np.asarray(pre, np.int32), np.asarray(toks, np.int32)])
        else:
            out[rid] = np.asarray(toks, np.int32)
    return out


def idempotency_map(entries: Dict[Any, JournalEntry]
                    ) -> Dict[str, Tuple[Any, bool]]:
    """``Idempotency-Key -> (rid, done)`` for journaled requests that
    carried a key — seeds the gateway's dedup map across a restart so
    a client retry does not double-admit."""
    out: Dict[str, Tuple[Any, bool]] = {}
    for rid, e in entries.items():
        key = e.req.get("idem_key")
        if key:
            out[key] = (rid, e.done)
    return out


def unfinished(entries: Dict[Any, JournalEntry]) -> List[Any]:
    """The rids a resume will requeue (not done, not cancelled,
    budget remaining)."""
    out = []
    for rid, e in entries.items():
        if e.cancelled or e.done:
            continue
        if len(e.tokens) >= int(e.req["max_new"]):
            continue
        out.append(rid)
    return out


def last_note(path: str) -> Optional[dict]:
    """The final ``note`` record in a journal (None when absent) —
    distinguishes a clean ``shutdown`` from a crash cut."""
    note = None
    try:
        raw = open(path, "rb").read()
    except FileNotFoundError:
        return None
    for line in raw.split(b"\n"):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except ValueError:
            break
        if rec.get("t") == "note":
            note = rec
    return note
