"""Async serving gateway: the HTTP front door over a scheduler.

Stdlib-only (``asyncio`` + hand-rolled HTTP/1.1 — no framework
dependency in the serving image).  One :class:`Gateway` owns one
:class:`repro.serve.scheduler.Scheduler` (or a host-0
:class:`repro.serve.mesh.MeshScheduler`) and splits the work across
two execution domains:

* **event loop** (asyncio): accepts connections, parses requests,
  streams tokens out as NDJSON chunks;
* **driver thread**: the only thread that touches the scheduler.  It
  drains the ingress queue into :meth:`Scheduler.submit`, sheds
  expired requests, runs :meth:`Scheduler.step`, and publishes each
  request's newly decoded tokens back into the loop via
  ``call_soon_threadsafe``.

SLO-aware admission lives at this boundary:

* ``max_queue`` (configured on the scheduler) bounds the request
  queue — an over-bound submit raises
  :class:`repro.serve.scheduler.Overloaded` which the gateway maps to
  **HTTP 429** with a ``Retry-After`` hint;
* requests may declare ``ttft_deadline_ms`` / ``tpot_deadline_ms``;
  queued requests whose TTFT deadline already passed are shed (429)
  instead of admitted late, and completed requests that missed a
  deadline increment the ``[serve]`` miss counters;
* each streaming response has a bounded token buffer
  (``stream_buffer``); a consumer too slow to drain it gets its
  request **cancelled** (backpressure) rather than buffering without
  bound.

Fault tolerance rides the same boundary.  :meth:`Gateway.begin_drain`
flips the gateway into a **draining** state (rolling restart step 1):
new ``/v1/generate`` submits get **503** with a ``Retry-After`` hint,
``/readyz`` answers 503 so load balancers stop routing here, and
in-flight requests run to completion (or get journaled for the next
generation — see :mod:`repro.serve.journal`).  Clients may send an
``Idempotency-Key`` header: a retry after a gateway restart with the
same key replays the finished result (``idempotent_replay``) or gets
**409** while the original is still in flight, instead of
double-admitting.  :meth:`Gateway.seed_idempotency` preloads the
key→rid map from a replayed journal.

Endpoints: ``POST /v1/generate`` (streaming NDJSON by default,
``"stream": false`` for a single JSON body), ``GET /healthz``
(liveness), ``GET /readyz`` (readiness: 503 until the warmup step ran
and the driver is up), ``GET /metrics`` (Prometheus text by default,
the :meth:`ServeStats.as_dict` JSON summary under
``Accept: application/json``), ``GET /debug/trace`` (the Chrome-trace
ring buffer), ``POST /debug/profile`` (arm ``jax.profiler`` around the
next N scheduler steps).  The debug endpoints route through a control
queue the driver drains, preserving the single-scheduler-caller
invariant.
"""
from __future__ import annotations

import asyncio
import collections
import json
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serve import telemetry as telemetry_mod
from repro.serve.scheduler import Overloaded, Request, Scheduler
from repro.serve.telemetry import log_event


@dataclass
class _Stream:
    """Loop-side state of one in-flight request."""

    rid: Any
    q: asyncio.Queue                 # ("tok", t) / ("end",) / ("err", msg)
    sent: int = 0                    # tokens published so far (driver side)
    inflight: int = 0                # published - consumed (the bound;
    #                                  incremented by the driver BEFORE the
    #                                  loop callback runs, so it can't lag
    #                                  behind qsize the way qsize does)
    error: Optional[str] = None      # set on overflow/shed/cancel
    done: bool = False


@dataclass
class _Ingress:
    """One submit waiting to cross into the driver thread."""

    req: Request
    fut: asyncio.Future               # -> ("ok"|"overloaded"|"invalid", msg)
    stream: Optional[_Stream] = None


class Gateway:
    """Asyncio HTTP/1.1 front door around one scheduler.

    ``stream_buffer`` bounds each response's unconsumed-token queue —
    overflow cancels the request (backpressure) instead of growing the
    buffer.  ``port=0`` binds an ephemeral port (read :attr:`port`
    after :meth:`start`).  The scheduler must be constructed by the
    caller (with ``max_queue`` for bounded admission); the gateway
    never touches it outside the driver thread.
    """

    def __init__(self, sched: Scheduler, host: str = "127.0.0.1",
                 port: int = 0, stream_buffer: int = 64,
                 idle_sleep_s: float = 0.002,
                 warmup: Optional[Callable[[], None]] = None):
        self.sched = sched
        self.host = host
        self.port = port
        self.stream_buffer = int(stream_buffer)
        self.idle_sleep_s = float(idle_sleep_s)
        self.loop: Optional[asyncio.AbstractEventLoop] = None
        self._server: Optional[asyncio.AbstractServer] = None
        self._driver: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._lock = threading.Lock()
        self._ingress: collections.deque = collections.deque()
        self._cancels: collections.deque = collections.deque()
        # control ops from debug endpoints, drained by the driver (the
        # only scheduler caller): ("profile", steps, outdir)
        self._control: collections.deque = collections.deque()
        self._streams: Dict[Any, _Stream] = {}   # driver-owned tracking
        self._next_rid = 0
        # readiness: set by the driver AFTER the optional warmup
        # callable (weight load / first compile) completes — /readyz
        # answers 503 until then, so load balancers wait out cold start
        self._warmup = warmup
        self._ready = threading.Event()
        # rolling-restart drain: set by begin_drain(); new submits are
        # refused (503 + Retry-After) while in-flight work finishes
        self._draining = threading.Event()
        # Idempotency-Key -> rid of the admitted request (loop-owned;
        # seeded from a replayed journal across restarts)
        self._idem: Dict[str, Any] = {}

    # -- lifecycle (event loop side) ----------------------------------------
    async def start(self) -> None:
        """Bind the listener and start the scheduler driver thread."""
        self.loop = asyncio.get_running_loop()
        self._server = await asyncio.start_server(
            self._handle_conn, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._driver = threading.Thread(target=self._drive,
                                        name="gateway-driver", daemon=True)
        self._driver.start()

    async def stop(self) -> None:
        """Stop accepting, stop the driver thread, close the listener."""
        self._stop.set()
        if self._driver is not None:
            await asyncio.get_running_loop().run_in_executor(
                None, self._driver.join)
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()

    async def serve_forever(self) -> None:
        """:meth:`start` then block until the server is closed."""
        await self.start()
        assert self._server is not None
        async with self._server:
            await self._server.serve_forever()

    # -- rolling restart ----------------------------------------------------
    def begin_drain(self) -> None:
        """Stop admitting new work (rolling-restart step 1).

        After this call new ``/v1/generate`` submits answer 503 with a
        ``Retry-After`` hint and ``/readyz`` flips to 503; in-flight
        requests keep streaming.  Thread-safe (signal handlers call it
        from the event loop, tests from anywhere)."""
        if not self._draining.is_set():
            self._draining.set()
            log_event("gateway_drain")

    @property
    def draining(self) -> bool:
        """Whether :meth:`begin_drain` has been called."""
        return self._draining.is_set()

    def drained(self) -> bool:
        """True once no queued/in-flight work remains (the drain is
        complete and the process can exit or hand off its journal)."""
        sched = self.sched
        return not (sched.queue or sched.active or sched.prefilling
                    or self._streams)

    def seed_idempotency(self, mapping: Dict[str, Any]) -> None:
        """Preload the Idempotency-Key map from a replayed journal.

        ``mapping`` is ``{key: (rid, done)}`` as produced by
        :func:`repro.serve.journal.idempotency_map`; only the rid is
        kept — completion is re-checked against ``sched.results`` at
        lookup time.  Call before :meth:`start`."""
        for key, (rid, _done) in mapping.items():
            self._idem[key] = rid

    def _retry_after(self) -> str:
        """Load-aware Retry-After hint: roughly one second per queued
        batch the scheduler has to chew through first."""
        sched = self.sched
        return str(max(1, round(len(sched.queue)
                                / max(sched.stats.slots, 1))))

    # -- driver thread: the ONLY scheduler caller ---------------------------
    def _drive(self) -> None:
        """Scheduler loop: drain ingress/cancels/control, shed, step,
        publish.  Runs the warmup callable first, then flips
        readiness."""
        sched = self.sched
        if self._warmup is not None:
            self._warmup()
        self._ready.set()
        log_event("gateway_ready", host=self.host, port=self.port)
        while not self._stop.is_set():
            busy = self._drain_ingress()
            while self._cancels:
                rid = self._cancels.popleft()
                sched.cancel(rid)
                busy = True
            while self._control:
                op = self._control.popleft()
                if op[0] == "profile":
                    sched.profile_steps(op[1], op[2])
                elif op[0] == "promote":
                    sched.arena_force(op[1])
                busy = True
            for rid in sched.shed_expired():
                self._post_error(rid, "shed: TTFT deadline expired "
                                      "before admission")
            if sched.queue or sched.active or sched.prefilling:
                sched.step()
                self._publish_progress()
                busy = True
            if not busy:
                time.sleep(self.idle_sleep_s)
        sched.stats.stop()

    def _drain_ingress(self) -> bool:
        """Submit queued ingress entries; resolve their futures."""
        busy = False
        while True:
            with self._lock:
                if not self._ingress:
                    return busy
                entry = self._ingress.popleft()
            busy = True
            try:
                self.sched.submit(entry.req)
            except Overloaded as e:
                self._resolve(entry.fut, ("overloaded", str(e)))
                continue
            except ValueError as e:
                self._resolve(entry.fut, ("invalid", str(e)))
                continue
            if entry.stream is not None:
                self._streams[entry.req.rid] = entry.stream
            self._resolve(entry.fut, ("ok", ""))

    def _publish_progress(self) -> None:
        """Diff scheduler state against each stream's published count
        and push the new tokens (then completion) into the loop."""
        sched = self.sched
        for rid, st in list(self._streams.items()):
            if rid in sched.results:
                toks = sched.results[rid]
                for t in toks[st.sent:]:
                    self._post(st, ("tok", int(t)))
                st.sent = len(toks)
                self._post(st, ("end",))
                del self._streams[rid]
                continue
            act = sched.active.get(rid) or sched.prefilling.get(rid)
            if act is not None:
                for t in act.tokens[st.sent:]:
                    self._post(st, ("tok", int(t)))
                st.sent = len(act.tokens)
            elif not any(q.rid == rid for q in sched.queue) \
                    and not any(a.req.rid == rid
                                for a in sched._pending_onepass):
                # vanished without a result: cancelled or shed
                self._post_error(rid, "request cancelled")

    def _post(self, st: _Stream, item: Tuple) -> None:
        """Publish one stream item into the event loop, enforcing the
        bounded buffer: overflow cancels the request (backpressure)."""
        if st.error is not None:
            return
        if st.inflight >= self.stream_buffer:
            st.error = (f"backpressure: consumer fell more than "
                        f"{self.stream_buffer} tokens behind; "
                        "request cancelled")
            log_event("backpressure", rid=st.rid,
                      buffer=self.stream_buffer)
            self._cancels.append(st.rid)
            self._streams.pop(st.rid, None)
            return
        st.inflight += 1
        assert self.loop is not None
        self.loop.call_soon_threadsafe(st.q.put_nowait, item)

    def _post_error(self, rid: Any, msg: str) -> None:
        """Terminate a stream with an error item (driver side)."""
        st = self._streams.pop(rid, None)
        if st is None or st.error is not None:
            return
        st.error = msg
        assert self.loop is not None
        self.loop.call_soon_threadsafe(st.q.put_nowait, ("err", msg))

    def _resolve(self, fut: asyncio.Future, value: Tuple[str, str]) -> None:
        """Resolve an ingress future from the driver thread."""
        assert self.loop is not None
        self.loop.call_soon_threadsafe(
            lambda: fut.done() or fut.set_result(value))

    # -- HTTP layer ---------------------------------------------------------
    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        """Parse one HTTP/1.1 request and dispatch it (no keep-alive)."""
        try:
            line = await reader.readline()
            parts = line.decode("latin1").split()
            if len(parts) < 2:
                return
            method, path = parts[0].upper(), parts[1]
            clen = 0
            accept = ""
            idem_key: Optional[str] = None
            while True:
                h = await reader.readline()
                if h in (b"\r\n", b"\n", b""):
                    break
                name, _, val = h.decode("latin1").partition(":")
                hname = name.strip().lower()
                if hname == "content-length":
                    clen = int(val.strip())
                elif hname == "accept":
                    accept = val.strip().lower()
                elif hname == "idempotency-key":
                    idem_key = val.strip()
            body = await reader.readexactly(clen) if clen else b""
            await self._route(method, path, body, accept, writer,
                              idem_key=idem_key)
        except (asyncio.IncompleteReadError, ConnectionResetError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionResetError, OSError):
                pass

    async def _route(self, method: str, path: str, body: bytes,
                     accept: str, writer: asyncio.StreamWriter,
                     idem_key: Optional[str] = None) -> None:
        """Dispatch to an endpoint handler."""
        sched = self.sched
        busy = len(sched.active) + len(sched.prefilling)
        if method == "GET" and path == "/healthz":
            # liveness: the process is up and parsing HTTP — readiness
            # is reported but does NOT change the status code
            await _respond(writer, 200, {
                "ok": True, "live": True,
                "ready": self._ready.is_set(),
                "slots": sched.stats.slots,
                "queued": len(sched.queue), "active": busy})
        elif method == "GET" and path == "/readyz":
            # readiness: 503 until weights are loaded / mesh is up
            # (the driver's warmup), and again once draining — load
            # balancers gate on this to stop routing during a rolling
            # restart
            ready = self._ready.is_set() and not self._draining.is_set()
            await _respond(
                writer, 200 if ready else 503, {
                    "ready": ready, "draining": self._draining.is_set(),
                    "slots": sched.stats.slots,
                    "queued": len(sched.queue), "slots_busy": busy},
                extra_headers=None if ready
                else [("Retry-After", self._retry_after())])
        elif method == "GET" and path == "/metrics":
            if "application/json" in accept:
                d = dict(sched.stats.as_dict())
                d["phase_seconds"] = dict(sched.telemetry.phase_seconds)
                await _respond(writer, 200, d)
            else:
                await _respond_text(
                    writer, 200, telemetry_mod.scheduler_prometheus(sched),
                    content_type="text/plain; version=0.0.4; "
                                 "charset=utf-8")
        elif method == "GET" and path == "/population":
            arena = getattr(sched, "arena", None)
            if arena is None:
                await _respond(writer, 404,
                               {"error": "no arena attached "
                                         "(serve with --arena)"})
            else:
                await _respond(writer, 200, arena.snapshot())
        elif method == "POST" and path == "/arena/promote":
            await self._arena_promote(body, writer)
        elif method == "GET" and path == "/debug/trace":
            await _respond(writer, 200, sched.telemetry.tracer.export())
        elif method == "POST" and path == "/debug/profile":
            await self._profile(body, writer)
        elif method == "POST" and path == "/v1/generate":
            await self._generate(body, writer, idem_key=idem_key)
        else:
            await _respond(writer, 404, {"error": f"no route "
                                                  f"{method} {path}"})

    async def _profile(self, body: bytes,
                       writer: asyncio.StreamWriter) -> None:
        """``POST /debug/profile``: arm the jax profiler around the
        next ``steps`` scheduler steps, artifacts under ``dir``.  The
        arm rides the control queue — the driver applies it, keeping
        the scheduler single-callered."""
        try:
            d = json.loads(body.decode() or "{}")
            steps = int(d.get("steps", 8))
            outdir = str(d.get("dir", "/tmp/repro_profile"))
            if steps < 1:
                raise ValueError("steps must be >= 1")
        except (ValueError, TypeError, json.JSONDecodeError) as e:
            await _respond(writer, 400, {"error": f"bad request: {e}"})
            return
        self._control.append(("profile", steps, outdir))
        await _respond(writer, 200,
                       {"armed": True, "steps": steps, "dir": outdir})

    async def _arena_promote(self, body: bytes,
                             writer: asyncio.StreamWriter) -> None:
        """``POST /arena/promote``: admin override — force the named
        challenger to win the next match evaluation (still through the
        transactional archive + drain-aware swap).  The override rides
        the control queue so the driver stays the scheduler's only
        caller."""
        arena = getattr(self.sched, "arena", None)
        if arena is None:
            await _respond(writer, 404,
                           {"error": "no arena attached "
                                     "(serve with --arena)"})
            return
        try:
            d = json.loads(body.decode() or "{}")
            member = d.get("member")
            if not isinstance(member, str) or member not in arena.members:
                raise ValueError(
                    f"unknown arena member {member!r}; roster is "
                    f"{sorted(arena.members)}")
            if member == arena.champion:
                raise ValueError(
                    f"{member!r} is already the champion")
        except (ValueError, TypeError, json.JSONDecodeError) as e:
            await _respond(writer, 400, {"error": f"bad request: {e}"})
            return
        self._control.append(("promote", member))
        await _respond(writer, 200,
                       {"queued": True, "member": member,
                        "champion": arena.champion})

    async def _generate(self, body: bytes,
                        writer: asyncio.StreamWriter,
                        idem_key: Optional[str] = None) -> None:
        """``POST /v1/generate``: admit, then stream tokens (NDJSON
        chunks) or collect the full completion (``"stream": false``).

        While draining, answers 503 + ``Retry-After`` without
        admitting.  A repeated ``Idempotency-Key`` replays the finished
        result (200, ``idempotent_replay``) or answers 409 while the
        original request is still in flight."""
        if self._draining.is_set():
            await _respond(
                writer, 503,
                {"error": "gateway is draining for restart; retry "
                          "against the next generation"},
                extra_headers=[("Retry-After", self._retry_after())])
            return
        try:
            d = json.loads(body.decode() or "{}")
            idem = idem_key or d.get("idempotency_key")
            known = self._idem.get(idem) if idem else None
            if known is not None:
                res = self.sched.results.get(known)
                if res is not None:
                    await _respond(writer, 200, {
                        "rid": known,
                        "tokens": [int(t) for t in res],
                        "idempotent_replay": True})
                else:
                    await _respond(
                        writer, 409,
                        {"error": "a request with this "
                                  "Idempotency-Key is still in flight",
                         "rid": known},
                        extra_headers=[("Retry-After",
                                        self._retry_after())])
                return
            prompt = np.asarray(d["prompt"], np.int32)
            req = Request(
                rid=d.get("rid", self._make_rid()), prompt=prompt,
                max_new=int(d.get("max_new", 16)),
                eos_id=d.get("eos_id"),
                temperature=float(d.get("temperature", 0.0)),
                seed=d.get("seed"),
                ttft_deadline_ms=d.get("ttft_deadline_ms"),
                tpot_deadline_ms=d.get("tpot_deadline_ms"),
                idem_key=idem)
        except (KeyError, ValueError, TypeError,
                json.JSONDecodeError) as e:
            await _respond(writer, 400, {"error": f"bad request: {e}"})
            return
        streaming = bool(d.get("stream", True))
        assert self.loop is not None
        st = _Stream(rid=req.rid,
                     q=asyncio.Queue(maxsize=self.stream_buffer + 2))
        entry = _Ingress(req=req, fut=self.loop.create_future(), stream=st)
        with self._lock:
            self._ingress.append(entry)
        status, msg = await entry.fut
        if status == "overloaded":
            await _respond(writer, 429, {"error": msg, "rid": req.rid},
                           extra_headers=[("Retry-After", "1")])
            return
        if status == "invalid":
            await _respond(writer, 400, {"error": msg, "rid": req.rid})
            return
        if idem:
            self._idem[idem] = req.rid
        if streaming:
            await self._stream_out(req.rid, st, writer)
        else:
            await self._collect_out(req.rid, st, writer)

    async def _stream_out(self, rid: Any, st: _Stream,
                          writer: asyncio.StreamWriter) -> None:
        """Send tokens as they decode: chunked NDJSON, one object per
        token, a final ``done`` record, or an ``error`` record when the
        request was shed/cancelled after headers went out."""
        # headers wait for the FIRST item so a pre-admission shed can
        # still become a clean 429 instead of a broken 200
        item = await st.q.get()
        st.inflight -= 1
        if item[0] == "err" and st.sent == 0:
            await _respond(writer, 429, {"error": item[1], "rid": rid},
                           extra_headers=[("Retry-After", "1")])
            return
        writer.write(_stream_head(200))
        ntok = 0
        try:
            while True:
                kind = item[0]
                if kind == "tok":
                    ntok += 1
                    _chunk(writer, {"rid": rid, "token": item[1]})
                elif kind == "end":
                    _chunk(writer, {"rid": rid, "done": True,
                                    "ntok": ntok})
                    break
                else:
                    _chunk(writer, {"rid": rid, "error": item[1]})
                    break
                await writer.drain()
                item = await st.q.get()
                st.inflight -= 1
            writer.write(b"0\r\n\r\n")
            await writer.drain()
        except (ConnectionResetError, BrokenPipeError):
            # client went away mid-stream: cancel to free the slot
            self._cancels.append(rid)

    async def _collect_out(self, rid: Any, st: _Stream,
                           writer: asyncio.StreamWriter) -> None:
        """Non-streaming mode: wait for completion, answer once."""
        tokens: List[int] = []
        while True:
            item = await st.q.get()
            st.inflight -= 1
            if item[0] == "tok":
                tokens.append(item[1])
            elif item[0] == "end":
                await _respond(writer, 200, {"rid": rid,
                                             "tokens": tokens})
                return
            else:
                await _respond(writer, 429,
                               {"error": item[1], "rid": rid,
                                "tokens": tokens},
                               extra_headers=[("Retry-After", "1")])
                return

    def _make_rid(self) -> str:
        """Allocate a gateway-unique request id."""
        self._next_rid += 1
        return f"g{self._next_rid}"


# -- wire helpers -----------------------------------------------------------

_REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
            409: "Conflict", 429: "Too Many Requests",
            500: "Internal Server Error", 503: "Service Unavailable"}


async def _respond(writer: asyncio.StreamWriter, code: int, obj: Dict,
                   extra_headers: Optional[List[Tuple[str, str]]] = None
                   ) -> None:
    """Write one complete JSON response and flush it."""
    payload = json.dumps(obj).encode()
    head = [f"HTTP/1.1 {code} {_REASONS.get(code, '')}",
            "Content-Type: application/json",
            f"Content-Length: {len(payload)}",
            "Connection: close"]
    head += [f"{k}: {v}" for k, v in (extra_headers or [])]
    writer.write(("\r\n".join(head) + "\r\n\r\n").encode() + payload)
    await writer.drain()


async def _respond_text(writer: asyncio.StreamWriter, code: int,
                        text: str,
                        content_type: str = "text/plain; charset=utf-8"
                        ) -> None:
    """Write one complete plain-text response (Prometheus scrapes)."""
    payload = text.encode()
    head = [f"HTTP/1.1 {code} {_REASONS.get(code, '')}",
            f"Content-Type: {content_type}",
            f"Content-Length: {len(payload)}",
            "Connection: close"]
    writer.write(("\r\n".join(head) + "\r\n\r\n").encode() + payload)
    await writer.drain()


def _stream_head(code: int) -> bytes:
    """Response head for a chunked NDJSON token stream."""
    return (f"HTTP/1.1 {code} {_REASONS.get(code, '')}\r\n"
            "Content-Type: application/x-ndjson\r\n"
            "Transfer-Encoding: chunked\r\n"
            "Connection: close\r\n\r\n").encode()


def _chunk(writer: asyncio.StreamWriter, obj: Dict) -> None:
    """Write one NDJSON record as an HTTP chunk (no flush)."""
    b = json.dumps(obj).encode() + b"\n"
    writer.write(f"{len(b):x}\r\n".encode() + b + b"\r\n")
