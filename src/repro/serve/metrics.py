"""Serving metrics: throughput / latency / queue accounting.

Mirrors the exchange-byte accounting style of ``core/tournament.py``:
counters accumulate while the scheduler runs, ``as_dict`` produces the
unified summary, and ``report`` prints the ``[serve]`` lines the CLI
and the fig14 benchmark consume.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Union


def percentile(xs: Union[Sequence[float], "BoundedSeries"],
               q: float) -> float:
    """Nearest-rank percentile (no numpy dependency on the hot path).

    Accepts a plain sequence or a :class:`BoundedSeries` (which answers
    from its exact list or its reservoir, whichever it currently holds).
    """
    if isinstance(xs, BoundedSeries):
        return xs.percentile(q)
    if not xs:
        return float("nan")
    ys = sorted(xs)
    k = min(len(ys) - 1, max(0, int(round(q / 100.0 * (len(ys) - 1)))))
    return ys[k]


# Fixed latency bucket upper bounds (seconds), ~1ms .. 2min exponential:
# bounded memory regardless of how long the gateway runs.
LATENCY_BUCKETS = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
    0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0, 120.0,
)


class Histogram:
    """Fixed-bucket histogram: O(len(bounds)) memory forever.

    ``bounds`` are inclusive upper edges; values above the last bound
    land in the implicit ``+Inf`` bucket.  ``bucket_counts`` yields
    per-bucket (non-cumulative) counts for the finite bounds — the
    Prometheus exporter accumulates them into cumulative ``le`` series.
    """

    __slots__ = ("bounds", "counts", "total", "sum")

    def __init__(self, bounds: Sequence[float] = LATENCY_BUCKETS):
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)  # +1: the +Inf bucket
        self.total = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        """Count one sample."""
        i = 0
        for b in self.bounds:
            if v <= b:
                break
            i += 1
        self.counts[i] += 1
        self.total += 1
        self.sum += v

    def bucket_counts(self) -> List[tuple]:
        """Per-bucket ``(upper_bound, count)`` pairs for finite bounds."""
        return list(zip(self.bounds, self.counts[:-1]))


class BoundedSeries:
    """Latency series with bounded memory.

    Short runs (benchmarks, tests) keep every sample exactly; past
    ``exact_cap`` samples the storage degrades to a deterministic
    Algorithm-R reservoir of ``reservoir`` samples, while a fixed-bucket
    :class:`Histogram` keeps exact counts/sum forever.  ``percentile``
    answers from whichever representation is live; ``mean`` and ``sum``
    are always exact (from the histogram accumulators).

    Duck-types the old ``List[float]`` usage: ``append``, ``len()``,
    truthiness, and iteration (over the stored sample) keep working.
    """

    __slots__ = ("exact_cap", "reservoir", "hist", "_sample", "_rng")

    def __init__(self, exact_cap: int = 4096, reservoir: int = 1024,
                 bounds: Sequence[float] = LATENCY_BUCKETS):
        self.exact_cap = int(exact_cap)
        self.reservoir = min(int(reservoir), self.exact_cap)
        self.hist = Histogram(bounds)
        self._sample: List[float] = []
        self._rng = random.Random(0x5EED)  # deterministic across runs

    @property
    def count(self) -> int:
        """Total samples observed (exact, never truncated)."""
        return self.hist.total

    @property
    def sum(self) -> float:
        """Exact sum of all samples observed."""
        return self.hist.sum

    @property
    def mean(self) -> float:
        """Exact mean of all samples observed (NaN when empty)."""
        n = self.hist.total
        return self.hist.sum / n if n else float("nan")

    @property
    def exact(self) -> bool:
        """Whether the stored sample still holds every observation."""
        return self.hist.total <= self.exact_cap

    def append(self, v: float) -> None:
        """Observe one sample (list-compatible name)."""
        v = float(v)
        self.hist.observe(v)
        n = self.hist.total
        if n <= self.exact_cap:
            self._sample.append(v)
            return
        if n == self.exact_cap + 1:
            # first overflow: collapse the exact list to a seeded
            # uniform subsample, then run standard Algorithm R
            self._sample = self._rng.sample(self._sample, self.reservoir)
        j = self._rng.randrange(n)
        if j < self.reservoir:
            self._sample[j] = v

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile from the exact list or the reservoir."""
        if not self._sample:
            return float("nan")
        ys = sorted(self._sample)
        k = min(len(ys) - 1, max(0, int(round(q / 100.0 * (len(ys) - 1)))))
        return ys[k]

    def __len__(self) -> int:
        return self.hist.total

    def __iter__(self) -> Iterator[float]:
        return iter(self._sample)


@dataclass
class ServeStats:
    """Counter bundle for one scheduler (or gateway) lifetime.

    All counters are plain ints/lists mutated on the host control path
    (never inside jit); times are ``time.perf_counter`` seconds.
    ``as_dict`` derives the rates/percentiles, ``report`` prints the
    ``[serve]`` summary lines.
    """

    slots: int = 0
    submitted: int = 0
    completed: int = 0
    rejected: int = 0
    # SLO-aware admission (gateway front door)
    shed_overload: int = 0         # submits refused: queue at --max-queue
    shed_deadline: int = 0         # queued requests dropped: TTFT deadline
    cancelled: int = 0             # in-flight requests cancelled by caller
    ttft_deadline_misses: int = 0  # completed, but first token was late
    tpot_deadline_misses: int = 0  # completed, but mean TPOT was over
    prefills: int = 0
    prefill_chunks: int = 0        # chunked-prefill slices processed
    prefill_tokens: int = 0        # true prompt tokens processed
    padded_prefill_tokens: int = 0  # incl. bucket padding (waste measure)
    decode_steps: int = 0
    decode_tokens: int = 0         # useful generated tokens
    decode_slot_steps: int = 0     # slots * steps actually computed
    # speculative decoding (population drafter)
    spec_rounds: int = 0           # target verify steps
    spec_draft_steps: int = 0      # drafter decode dispatches
    spec_draft_proposed: int = 0   # draft tokens offered for verify
    spec_draft_accepted: int = 0   # draft tokens the target kept
    spec_replays: int = 0          # rollback replay steps (recurrent)
    spec_k_sum: int = 0            # proposals offered, summed per row-round
    spec_k_rows: int = 0           # row-rounds that offered proposals
    ragged_splits: int = 0         # width-split subset decode dispatches
    hot_swaps: int = 0
    # fault tolerance (serve/journal.py, serve/faults.py, registry)
    fault_injected: int = 0        # harness faults fired (--fault-spec)
    swap_rejected_corrupt: int = 0  # hot-swaps refused: corrupt checkpoint
    plan_retries: int = 0          # mesh plan-channel fetch retries
    journal_replayed: int = 0      # requests requeued from a WAL journal
    # online LTFB arena (serve/arena.py)
    arena_matches: int = 0         # match evaluations run
    arena_promotions: int = 0      # champion promotions applied
    steps: int = 0
    queue_depth_sum: int = 0
    queue_depth_max: int = 0
    slot_busy_sum: int = 0
    ttft: BoundedSeries = field(default_factory=BoundedSeries)
    tpot: BoundedSeries = field(default_factory=BoundedSeries)
    latency: BoundedSeries = field(default_factory=BoundedSeries)
    started: Optional[float] = None
    finished: Optional[float] = None

    # -- lifecycle ---------------------------------------------------------
    def start(self):
        """Arm the wall clock on the first scheduler step (idempotent)."""
        if self.started is None:
            self.started = time.perf_counter()

    def stop(self):
        """Freeze the wall clock; rates in :meth:`as_dict` stop growing."""
        self.finished = time.perf_counter()

    @property
    def wall(self) -> float:
        """Elapsed serving seconds (live until :meth:`stop` is called)."""
        if self.started is None:
            return 0.0
        end = self.finished if self.finished is not None \
            else time.perf_counter()
        return max(end - self.started, 1e-9)

    # -- per-step sampling -------------------------------------------------
    def sample_step(self, queue_depth: int, busy_slots: int):
        """Record one scheduler step's queue depth and busy-slot count."""
        self.steps += 1
        self.queue_depth_sum += queue_depth
        self.queue_depth_max = max(self.queue_depth_max, queue_depth)
        self.slot_busy_sum += busy_slots

    # -- summary -----------------------------------------------------------
    def as_dict(self) -> Dict[str, float]:
        """One flat summary dict: raw counters plus derived rates
        (req/s, tok/s), latency stats (TTFT / TPOT / e2e, mean + p95
        seconds), and occupancy.  NaN where no samples exist."""
        wall = self.wall
        occ = self.slot_busy_sum / max(self.steps * max(self.slots, 1), 1)
        return {
            "slots": self.slots,
            "submitted": self.submitted,
            "completed": self.completed,
            "rejected": self.rejected,
            "shed_overload": self.shed_overload,
            "shed_deadline": self.shed_deadline,
            "cancelled": self.cancelled,
            "ttft_deadline_misses": self.ttft_deadline_misses,
            "tpot_deadline_misses": self.tpot_deadline_misses,
            "prefills": self.prefills,
            "prefill_chunks": self.prefill_chunks,
            "prefill_tokens": self.prefill_tokens,
            "padded_prefill_tokens": self.padded_prefill_tokens,
            "decode_steps": self.decode_steps,
            "decode_tokens": self.decode_tokens,
            "decode_slot_steps": self.decode_slot_steps,
            "spec_rounds": self.spec_rounds,
            "spec_draft_steps": self.spec_draft_steps,
            "spec_draft_proposed": self.spec_draft_proposed,
            "spec_draft_accepted": self.spec_draft_accepted,
            "spec_replays": self.spec_replays,
            "spec_accept_rate": self.spec_draft_accepted
            / max(self.spec_draft_proposed, 1),
            "spec_k_mean": self.spec_k_sum / max(self.spec_k_rows, 1),
            "ragged_splits": self.ragged_splits,
            "hot_swaps": self.hot_swaps,
            "fault_injected": self.fault_injected,
            "swap_rejected_corrupt": self.swap_rejected_corrupt,
            "plan_retries": self.plan_retries,
            "journal_replayed": self.journal_replayed,
            "arena_matches": self.arena_matches,
            "arena_promotions": self.arena_promotions,
            "wall_s": wall,
            # wall is 0.0 before the first step: a /metrics scrape of an
            # idle gateway must not divide by zero
            "requests_per_s": self.completed / max(wall, 1e-9),
            "tokens_per_s": self.decode_tokens / max(wall, 1e-9),
            "ttft_mean_s": self.ttft.mean,
            "ttft_p95_s": self.ttft.percentile(95),
            "tpot_mean_s": self.tpot.mean,
            "tpot_p95_s": self.tpot.percentile(95),
            "latency_mean_s": self.latency.mean,
            "latency_p95_s": self.latency.percentile(95),
            "queue_depth_mean": self.queue_depth_sum / max(self.steps, 1),
            "queue_depth_max": self.queue_depth_max,
            "slot_occupancy": occ,
        }

    def report(self, log: Callable[[str], None] = print,
               prefix: str = "[serve]"):
        """Print the human-readable ``[serve]`` summary via ``log``.

        When ``--log-json`` is active (``telemetry.enable_json_logs``),
        the same summary also goes out as one machine-parseable JSON
        record.
        """
        d = self.as_dict()
        from repro.serve import telemetry  # local import: no cycle

        if telemetry.json_logs_enabled():
            telemetry.log_event("serve_report", **d)
        log(f"{prefix} requests: submitted={d['submitted']} "
            f"completed={d['completed']} rejected={d['rejected']} "
            f"hot_swaps={d['hot_swaps']}")
        if self.shed_overload or self.shed_deadline or self.cancelled \
                or self.ttft_deadline_misses or self.tpot_deadline_misses:
            log(f"{prefix} admission: shed_overload={d['shed_overload']} "
                f"shed_deadline={d['shed_deadline']} "
                f"cancelled={d['cancelled']} "
                f"ttft_misses={d['ttft_deadline_misses']} "
                f"tpot_misses={d['tpot_deadline_misses']}")
        log(f"{prefix} throughput: {d['requests_per_s']:.2f} req/s "
            f"{d['tokens_per_s']:.1f} tok/s "
            f"(decode_steps={d['decode_steps']} "
            f"useful/slot-step="
            f"{d['decode_tokens'] / max(d['decode_slot_steps'], 1):.2f})")
        log(f"{prefix} latency: ttft_mean={d['ttft_mean_s'] * 1e3:.1f}ms "
            f"ttft_p95={d['ttft_p95_s'] * 1e3:.1f}ms "
            f"tpot_mean={d['tpot_mean_s'] * 1e3:.1f}ms "
            f"e2e_mean={d['latency_mean_s'] * 1e3:.1f}ms "
            f"e2e_p95={d['latency_p95_s'] * 1e3:.1f}ms")
        log(f"{prefix} occupancy: slots={d['slots']} "
            f"busy={d['slot_occupancy'] * 100:.0f}% "
            f"queue_mean={d['queue_depth_mean']:.1f} "
            f"queue_max={d['queue_depth_max']}")
        if self.fault_injected or self.swap_rejected_corrupt \
                or self.plan_retries or self.journal_replayed:
            log(f"{prefix} robustness: fault_injected={d['fault_injected']} "
                f"swap_rejected_corrupt={d['swap_rejected_corrupt']} "
                f"plan_retries={d['plan_retries']} "
                f"journal_replayed={d['journal_replayed']}")
        if self.arena_matches or self.arena_promotions:
            log(f"{prefix} arena: matches={d['arena_matches']} "
                f"promotions={d['arena_promotions']}")
        if self.spec_rounds:
            log(f"{prefix} speculative: rounds={d['spec_rounds']} "
                f"accept_rate={d['spec_accept_rate'] * 100:.0f}% "
                f"accepted={d['spec_draft_accepted']}"
                f"/{d['spec_draft_proposed']} "
                f"draft_steps={d['spec_draft_steps']} "
                f"replays={d['spec_replays']} "
                f"k_mean={d['spec_k_mean']:.2f}")
