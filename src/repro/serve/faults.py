"""Deterministic fault-injection harness for the serving stack.

Faults are scheduled at EXACT scheduler step numbers from a compact
spec string (``--fault-spec``), so every failure-handling path in this
repo — journal crash recovery, transactional hot-swap, mesh
degradation, pool exhaustion, client disconnects — is tested by
*reproducible* runs instead of flaky sleeps and signals-by-hand.

Spec syntax: comma-separated events, each ``kind@step[:key=val...]``::

    kill@12                       SIGKILL this process at step 12
    crash@12                      raise InjectedFault (in-process tests)
    stall@5:secs=0.2              sleep 0.2s inside step 5
    corrupt@8                     truncate the newest winner checkpoint
    oom@7:hold=3                  block admission for steps 7..9
    disconnect@6                  cancel the oldest in-flight request
    kill@12:rank=1                same, but only on mesh rank 1

Each event fires on exactly ONE process: ``rank`` defaults to 0 (the
host-0 scheduler).  The injector is invoked at the top of every
scheduler step (before the hot-swap poll, so ``corrupt@N`` lands
before step N's registry refresh) and counts each firing into
``stats.fault_injected`` — the telemetry signature operators grep for
(see ``docs/failure_modes.md``).
"""
from __future__ import annotations

import os
import signal
import time
from dataclasses import dataclass, field
from typing import Dict, List

from repro.serve.telemetry import log_event


class InjectedFault(RuntimeError):
    """Raised by ``crash`` events — the in-process stand-in for a
    SIGKILL that unit tests can catch (the process state after the
    raise is exactly what a kill leaves behind: an un-flushed step)."""


KINDS = ("kill", "crash", "stall", "corrupt", "oom", "disconnect")


@dataclass
class FaultEvent:
    """One scheduled fault: ``kind`` at scheduler ``step`` with
    key=value ``args`` (``rank`` selects the target process)."""

    kind: str
    step: int
    args: Dict[str, str] = field(default_factory=dict)

    @property
    def rank(self) -> int:
        """The mesh rank this event targets (default 0)."""
        return int(self.args.get("rank", 0))


def parse_fault_spec(spec: str) -> List[FaultEvent]:
    """Parse a ``--fault-spec`` string into sorted fault events.

    Raises ``ValueError`` on unknown kinds or malformed events so a
    typo fails the launch instead of silently never firing.
    """
    events: List[FaultEvent] = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        fields = part.split(":")
        head = fields[0]
        if "@" not in head:
            raise ValueError(
                f"fault event {part!r}: expected kind@step[:key=val...]")
        kind, step_s = head.split("@", 1)
        if kind not in KINDS:
            raise ValueError(
                f"fault event {part!r}: unknown kind {kind!r} "
                f"(known: {', '.join(KINDS)})")
        args: Dict[str, str] = {}
        for kv in fields[1:]:
            if "=" not in kv:
                raise ValueError(
                    f"fault event {part!r}: bad arg {kv!r} (want key=val)")
            k, v = kv.split("=", 1)
            args[k] = v
        events.append(FaultEvent(kind=kind, step=int(step_s), args=args))
    events.sort(key=lambda e: e.step)
    return events


class FaultInjector:
    """Fires scheduled faults at exact scheduler steps.

    Attach via ``Scheduler(..., faults=FaultInjector(spec, rank=r))``;
    the scheduler calls :meth:`on_step` at the top of each step and
    :meth:`admission_blocked` inside the admission phase (the ``oom``
    kind simulates pool exhaustion by refusing admission for ``hold``
    steps — layout-agnostic and identical on every mesh host).
    """

    def __init__(self, spec, rank: int = 0):
        self.events = parse_fault_spec(spec) if isinstance(spec, str) \
            else list(spec)
        self.rank = int(rank)
        self.injected = 0
        self._oom_until = 0
        self._fired: List[FaultEvent] = []

    def admission_blocked(self, step: int) -> bool:
        """True while an ``oom`` event holds admission shut."""
        return step < self._oom_until

    def on_step(self, sched, step: int) -> None:
        """Fire every event scheduled for ``step`` on this rank."""
        for ev in self.events:
            if ev.step == step and ev.rank == self.rank \
                    and ev not in self._fired:
                self._fired.append(ev)
                self._fire(ev, sched, step)

    def _count(self, sched, ev: FaultEvent, step: int) -> None:
        self.injected += 1
        sched.stats.fault_injected += 1
        log_event("fault_injected", kind=ev.kind, step=step,
                  rank=self.rank)

    def _fire(self, ev: FaultEvent, sched, step: int) -> None:
        if ev.kind == "kill":
            # the real thing: no cleanup, no flush — exactly what the
            # journal's torn-tail tolerance is specified against.
            # counted BEFORE the kill lands only in the journal's favor
            self._count(sched, ev, step)
            print(f"[faults] SIGKILL self at step {step} (rank "
                  f"{self.rank})", flush=True)
            os.kill(os.getpid(), signal.SIGKILL)
        elif ev.kind == "crash":
            self._count(sched, ev, step)
            raise InjectedFault(f"injected crash at step {step}")
        elif ev.kind == "stall":
            self._count(sched, ev, step)
            time.sleep(float(ev.args.get("secs", 0.05)))
        elif ev.kind == "corrupt":
            self._count(sched, ev, step)
            self._corrupt_winner(sched, ev)
        elif ev.kind == "oom":
            self._count(sched, ev, step)
            self._oom_until = step + int(ev.args.get("hold", 1))
        elif ev.kind == "disconnect":
            rid = self._disconnect_victim(sched, ev)
            if rid is not None:
                self._count(sched, ev, step)
                sched.cancel(rid)

    def _corrupt_winner(self, sched, ev: FaultEvent) -> None:
        """Truncate the newest winner checkpoint in the registry's
        directory to half its size — a torn file exactly like a writer
        that died mid-copy."""
        from repro.serve.registry import latest_winner_step, winner_path
        d = ev.args.get("dir") or getattr(
            getattr(sched, "registry", None), "ckpt_dir", None)
        if d is None:
            return
        step = latest_winner_step(d)
        if step is None:
            return
        path = winner_path(d, step)
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(max(1, size // 2))
        print(f"[faults] truncated {path} ({size} -> {size // 2} bytes)",
              flush=True)

    def _disconnect_victim(self, sched, ev: FaultEvent):
        """Pick the cancellation victim deterministically: an explicit
        ``rid=`` arg, else the oldest in-flight request, else the queue
        head."""
        if "rid" in ev.args:
            rid = ev.args["rid"]
            return int(rid) if rid.lstrip("-").isdigit() else rid
        for pool in (sched.active, sched.prefilling):
            for rid in pool:
                return rid
        for q in sched.queue:
            return q.rid
        return None
