"""DecodeSession: ONE decode surface for every cache layout and family.

Before this module the serving stack picked model entry points by hand
— a dense decode vs a paged one, a full prefill vs a chunked slice —
once per cache layout, and every caller (scheduler, engine, launcher,
examples, benchmarks) re-encoded the choice.  A
:class:`DecodeSession` pairs model weights with a
:class:`repro.serve.kv_cache.CacheLayout` and exposes the whole decode
lifecycle as four calls:

  ``prefill(rid, prompt)``        full-prompt prefill into the layout
  ``prefill_chunk(rid, ...)``     one chunked-prefill slice (paged)
  ``step(tokens, index, ...)``    K >= 1 tokens per row, any layout
  ``snapshot() / restore(...)``   recurrent-state rollback

``step`` is the single write primitive: ``tokens`` is (B, K) with
K >= 1, so a speculative verify (K tokens at once) and a classic decode
(K = 1) are the same call, on dense rows, paged pools, and hybrid
stacks alike.  ``snapshot``/``restore`` bound what speculation can
break: attention KV never needs rollback (stale positions are causally
masked and overwritten), so a snapshot is exactly the recurrent leaves
— empty, and free, for attention-only models.

The jitted executables are module-level and keyed by the (hashable)
config, so scheduler, drafter, engine, and benchmark sessions of the
same model share every compile.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.serve.kv_cache import CacheLayout, PagedLayout

# module-level jits (config is a hashable frozen dataclass): compiled
# executables are shared across DecodeSession instances, so spinning up
# a server — or a target + drafter pair — never re-pays compilation


@partial(jax.jit, static_argnums=(1,))
def _prefill_fn(params, cfg, toks, last_pos):
    return lm.lm_prefill(params, cfg, {"tokens": toks}, last_pos=last_pos)


@partial(jax.jit, static_argnums=(1,), donate_argnums=(3,))
def _chunk_fn(params, cfg, toks, cache, tables, hist, plen, last_pos):
    return lm.lm_prefill(params, cfg, {"tokens": toks}, last_pos=last_pos,
                         cache=cache, tables=tables, hist_len=hist,
                         prompt_len=plen)


@partial(jax.jit, static_argnums=(1,), donate_argnums=(3,))
def _step_fn(params, cfg, tokens, cache, index, valid):
    return lm.lm_decode(params, cfg, tokens, cache, index, valid=valid)


@partial(jax.jit, static_argnums=(1,), donate_argnums=(3,))
def _step_tables_fn(params, cfg, tokens, cache, index, tables, valid):
    return lm.lm_decode(params, cfg, tokens, cache, index, tables=tables,
                        valid=valid)


def _draft_unroll(params, cfg, tok0, cache, index, valid, steps, tables):
    """`steps` single-token decodes in ONE jitted dispatch, each feeding
    the next token by on-device greedy argmax — the fused drafter round.
    Step t is real for row b iff ``t < valid[b]`` (null-routed paged
    writes + frozen recurrent state beyond, exactly like a masked
    multi-token step).  Returns (logits (B, steps, V), the tokens
    actually fed (B, steps), new cache)."""
    tok = tok0
    fed, logits_all = [], []
    for t in range(steps):
        valid_t = jnp.minimum(jnp.maximum(valid - t, 0), 1)
        if tables is None:
            idx_t = index + t
            logits, cache = lm.lm_decode(params, cfg, tok, cache, idx_t,
                                         valid=valid_t)
        else:
            idx_t = jnp.where(index >= 0, index + t, -1)
            logits, cache = lm.lm_decode(params, cfg, tok, cache, idx_t,
                                         tables=tables, valid=valid_t)
        fed.append(tok[:, 0])
        logits_all.append(logits[:, 0])
        # greedy device feed; the host resamples from the returned
        # logits with the request's true sampling function afterwards
        tok = jnp.argmax(logits[:, 0].astype(jnp.float32),
                         axis=-1).astype(jnp.int32)[:, None]
    return (jnp.stack(logits_all, axis=1), jnp.stack(fed, axis=1), cache)


@partial(jax.jit, static_argnums=(1, 6), donate_argnums=(3,))
def _draft_fn(params, cfg, tok0, cache, index, valid, steps):
    return _draft_unroll(params, cfg, tok0, cache, index, valid, steps,
                         None)


@partial(jax.jit, static_argnums=(1, 6), donate_argnums=(3,))
def _draft_tables_fn(params, cfg, tok0, cache, index, valid, steps,
                     tables):
    return _draft_unroll(params, cfg, tok0, cache, index, valid, steps,
                         tables)


class DecodeSession:
    """Weights + a cache layout, driven through one decode API.

    The session owns the jit boundaries and the cache pytree rebinding
    (every step donates the layout's cache and rebinds the result);
    request/slot lifecycle stays on ``session.layout`` so schedulers
    keep their admission logic while never touching a model entry point
    directly.
    """

    def __init__(self, cfg: ModelConfig, params, layout: CacheLayout):
        self.cfg = cfg
        self.params = params
        self.layout = layout

    @property
    def paged(self) -> bool:
        """True when the KV cache is the paged (scattered-page) layout."""
        return isinstance(self.layout, PagedLayout)

    def set_params(self, params) -> None:
        """Hot-swap weights (cache layout depends only on the config)."""
        self.params = params

    # -- jit indirection ---------------------------------------------------
    # Every dispatch goes through one of these hooks so a subclass can
    # swap in DIFFERENT jitted executables (the serving mesh binds
    # mesh-dedicated jits with the Mesh as a static arg) while the
    # host-side marshalling above/below stays in exactly one place.
    def _call_prefill(self, *args):
        return _prefill_fn(*args)

    def _call_chunk(self, *args):
        return _chunk_fn(*args)

    def _call_step(self, *args):
        return _step_fn(*args)

    def _call_step_tables(self, *args):
        return _step_tables_fn(*args)

    def _call_draft(self, *args):
        return _draft_fn(*args)

    def _call_draft_tables(self, *args):
        return _draft_tables_fn(*args)

    # -- prefill -----------------------------------------------------------
    def prefill(self, rid, prompt: np.ndarray,
                bucket: Optional[int] = None) -> np.ndarray:
        """Full-prompt prefill for ONE request, written into its
        slot/pages.  ``bucket`` right-pads the prompt to a shape bucket
        (attention-only stacks; logits still read at the true last
        token); None prefills at exact length (recurrent families —
        padding would poison their state).  Returns the last-token
        logits row (V,) as float32 on host.
        """
        P = int(len(prompt))
        L = bucket or P
        toks = np.zeros((1, L), np.int32)
        toks[0, :P] = prompt
        logits, cache = self._call_prefill(
            self.params, self.cfg, jnp.asarray(toks),
            jnp.asarray([P - 1], jnp.int32))
        if self.paged:
            self.layout.insert_prefill(rid, cache, P)
        else:
            self.layout.insert(rid, cache)
        return np.asarray(logits[0, -1].astype(jnp.float32))

    def prefill_batch(self, tokens: jax.Array) -> jax.Array:
        """Uniform-length batch prefill filling EVERY slot row (the
        engine path; slot layouts only).  Returns logits (B, 1, V)."""
        logits, cache = self._call_prefill(self.params, self.cfg,
                                           tokens, None)
        self.layout.insert_batch(cache)
        return logits

    def prefill_chunk(self, rid, chunk: np.ndarray, hist_len: int,
                      prompt_len: int, chunk_bucket: int,
                      width: int) -> np.ndarray:
        """One chunked-prefill slice scattered into `rid`'s pages.

        chunk: the real tokens of this slice (right-padded to
        ``chunk_bucket`` here); hist_len: prompt tokens already
        prefilled; width: block-table columns to expose (pow2-bucketed
        by the caller).  Returns the slice's last-real-token logits row
        (V,) — only meaningful on the final slice.
        """
        n = int(len(chunk))
        toks = np.zeros((1, chunk_bucket), np.int32)
        toks[0, :n] = chunk
        slot = self.layout.slot_of(rid)
        logits, self.layout.cache = self._call_chunk(
            self.params, self.cfg, jnp.asarray(toks), self.layout.cache,
            jnp.asarray(self.layout.tables[slot:slot + 1, :width]),
            jnp.int32(hist_len), jnp.int32(prompt_len),
            jnp.asarray([n - 1], jnp.int32))
        return np.asarray(logits[0, -1].astype(jnp.float32))

    # -- decode ------------------------------------------------------------
    def step(self, tokens: np.ndarray, index: np.ndarray,
             valid: Optional[np.ndarray] = None,
             width: Optional[int] = None,
             rows: Optional[np.ndarray] = None,
             tables: Optional[np.ndarray] = None) -> jax.Array:
        """One decode/verify step: K >= 1 tokens per row.

        tokens: (B, K) int32; index: (B,) first-token write positions
        (-1 = idle row on paged layouts); valid: optional (B,) real
        token counts (speculative verify / rollback replay); width:
        block-table columns (paged; pow2-bucketed by the caller); rows:
        restrict a paged step to these slots (ragged grouping — only
        when ``layout.supports_row_subset``); tables: explicit
        block-table array overriding the layout's (padded group calls).
        Returns logits (B, K, V) still on device (callers cast/copy).
        """
        tok = jnp.asarray(tokens, jnp.int32)
        idx = jnp.asarray(index, jnp.int32)
        v = None if valid is None else jnp.asarray(valid, jnp.int32)
        if self.paged:
            if tables is None:
                tables = self.layout.step_kwargs(width=width,
                                                 rows=rows)["tables"]
            else:
                tables = jnp.asarray(tables)
            logits, self.layout.cache = self._call_step_tables(
                self.params, self.cfg, tok, self.layout.cache, idx,
                tables, v)
        else:
            logits, self.layout.cache = self._call_step(
                self.params, self.cfg, tok, self.layout.cache, idx, v)
        return logits

    def draft_block(self, tok0: np.ndarray, index: np.ndarray,
                    steps: int, valid: Optional[np.ndarray] = None,
                    width: Optional[int] = None
                    ) -> Tuple[jax.Array, jax.Array]:
        """Fused drafter round: ``steps`` unrolled single-token decodes
        in ONE dispatch, each feeding the next token by on-device
        greedy argmax.

        tok0: (B, 1) the pending token per row; index: (B,) its write
        position (-1 = idle row on paged layouts); valid: (B,) real
        steps per row (rows freeze beyond, like a masked multi-token
        step); width: block-table columns (paged).  Returns (logits
        (B, steps, V), fed tokens (B, steps)) still on device — the
        caller resamples proposals from the logits with the request's
        real sampling function and repairs the cache where its samples
        diverge from the greedy feed.
        """
        tok = jnp.asarray(tok0, jnp.int32)
        idx = jnp.asarray(index, jnp.int32)
        B = tok.shape[0]
        v = jnp.full((B,), steps, jnp.int32) if valid is None \
            else jnp.asarray(valid, jnp.int32)
        if self.paged:
            tables = self.layout.step_kwargs(width=width)["tables"]
            logits, fed, self.layout.cache = self._call_draft_tables(
                self.params, self.cfg, tok, self.layout.cache, idx, v,
                steps, tables)
        else:
            logits, fed, self.layout.cache = self._call_draft(
                self.params, self.cfg, tok, self.layout.cache, idx, v,
                steps)
        return logits, fed

    # -- rollback ----------------------------------------------------------
    def snapshot(self) -> Tuple[jax.Array, ...]:
        """Copy of the recurrent leaves (empty for attention-only
        stacks — their rollback is free)."""
        return self.layout.snapshot()

    def restore(self, snap: Tuple[jax.Array, ...], rows) -> None:
        """Roll slots with ``rows[b] == True`` back to ``snap``; pair
        with a ``valid``-masked replay :meth:`step` to rebuild the
        accepted prefix."""
        self.layout.restore(snap, rows)
