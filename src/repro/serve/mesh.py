"""Multi-host serving mesh: sharded decode with a host-0 scheduler.

Training already runs on a device mesh; this module puts the SERVING
stack on one.  The existing :class:`repro.serve.session.DecodeSession`
/ :class:`repro.serve.kv_cache.CacheLayout` machinery is reused
unchanged — the mesh runtime only decides *where things live* and *who
decides*:

**Axis layout** (the dry-run "serve" preset,
:data:`repro.parallel.sharding.SERVE_RULES`):

  * **weights** — stationary, tensor-parallel over ``model`` (vocab /
    head / mlp / expert dims); never gathered, per-token collectives
    are tiny activation all-reduces;
  * **decode batch** — the ``num_slots`` rows split over ``data``:
    tokens, write indices, block tables, logits;
  * **cache leaves** — every one over ``data``: dense KV rows and
    recurrent state on their batch dim, paged pools on the PAGE dim.
    The paged pool becomes ``data``-many private sub-pools, each with
    its own null page, each accounted by a host-local
    :class:`repro.serve.kv_cache.PageShard`; block tables hold global
    page ids and the shard_map gather dispatch
    (:func:`repro.kernels.ops.paged_attention`) rebases them
    per-shard, so decode NEVER moves a KV page across ``data``.

**Control plane**: scheduling state (queue, slot maps, block
managers, prefix caches) is replicated host-side and evolves
deterministically — with two exceptions, both decided by **host 0**
and broadcast as a :class:`StepPlan` each step:

  * *admission* — which queued requests enter the batch this step
    (and implicitly which pinned pages get reclaimed for them);
  * *hot swap* — whether a newer tournament winner was found on disk
    (filesystem reads race the trainer; followers load exactly the
    broadcast step).

After the plan lands, every host executes the SAME jitted prefill /
decode dispatches on the sharded arrays.  Three plan transports
(:func:`make_plan_channel` picks one):

  * single process — the plan round-trips its wire encoding
    (:class:`LoopbackChannel`), so CI exercises the format every step;
  * multi-process on a collective-capable backend (TPU/GPU) — two
    ``multihost_utils.broadcast_one_to_all`` rounds
    (:class:`CollectiveChannel`);
  * multi-process on CPU — XLA's CPU backend cannot run cross-process
    computations, so the plan rides the **jax coordination service**
    (the gRPC key-value store ``jax.distributed.initialize`` already
    stood up): host 0 publishes the plan bytes under a per-step key,
    followers block on it with a timeout, and a per-step barrier both
    confirms delivery and turns a dead peer into a clean
    ``DEADLINE_EXCEEDED`` error instead of a hang
    (:class:`CoordServiceChannel`).
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch import specs as specs_lib
from repro.models import lm
from repro.parallel.sharding import (serve_rules, tree_shardings,
                                     use_sharding)
from repro.serve.kv_cache import PagedLayout, SlotLayout, blocks_for
from repro.serve.scheduler import Request, Scheduler
from repro.serve.session import DecodeSession, _draft_unroll
from repro.serve.telemetry import stats_snapshot


# ---------------------------------------------------------------------------
# mesh construction
# ---------------------------------------------------------------------------


def parse_mesh(spec: str) -> Tuple[int, int]:
    """Parse ``--mesh`` values: "4,2" / "data=4,model=2" / "8" (pure
    data parallelism) -> (data, model)."""
    parts = [p.strip() for p in spec.split(",") if p.strip()]
    named = {}
    sizes = []
    for p in parts:
        if "=" in p:
            k, v = p.split("=", 1)
            named[k.strip()] = int(v)
        else:
            sizes.append(int(p))
    if named:
        return named.get("data", 1), named.get("model", 1)
    if len(sizes) == 1:
        return sizes[0], 1
    if len(sizes) == 2:
        return sizes[0], sizes[1]
    raise ValueError(f"cannot parse mesh spec {spec!r}")


def make_serve_mesh(data: int, model: int = 1, local: bool = False):
    """("data", "model") mesh over the first data*model visible devices.

    ``local=True`` restricts the mesh to THIS process's devices
    (``jax.local_devices()``): the replicated-deployment mode
    ``launch/distributed.py`` uses on backends whose cross-process
    computations XLA does not support (CPU) — every process holds a
    full model replica on a private mesh and stays in lockstep through
    the broadcast plan instead of through device collectives.
    """
    from jax.sharding import Mesh
    n = data * model
    devices = jax.local_devices() if local else jax.devices()
    kind = "local" if local else "visible"
    if len(devices) < n:
        raise ValueError(
            f"serving mesh {data}x{model} needs {n} devices, have "
            f"{len(devices)} {kind} (set XLA_FLAGS="
            "--xla_force_host_platform_device_count=N to emulate)")
    return Mesh(np.asarray(devices[:n]).reshape(data, model),
                ("data", "model"))


def mesh_axis_sizes(mesh) -> Tuple[int, int]:
    """(data, model) axis sizes of a serving mesh (absent axes = 1)."""
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return axes.get("data", 1), axes.get("model", 1)


# ---------------------------------------------------------------------------
# the host-0 decision record
# ---------------------------------------------------------------------------


def encode_request(req: Request) -> Dict[str, Any]:
    """Wire-encode a :class:`~repro.serve.scheduler.Request` for the
    plan broadcast (JSON scalars + a token-id list; the rid must be a
    JSON scalar to be mesh-servable)."""
    return {"rid": req.rid,
            "prompt": [int(t) for t in req.prompt],
            "max_new": int(req.max_new),
            "eos_id": req.eos_id,
            "temperature": float(req.temperature),
            "seed": req.seed,
            "ttft_deadline_ms": req.ttft_deadline_ms,
            "tpot_deadline_ms": req.tpot_deadline_ms,
            "ntok_base": int(req.ntok_base)}


def decode_request(d: Dict[str, Any]) -> Request:
    """Inverse of :func:`encode_request` (follower-side)."""
    return Request(rid=d["rid"],
                   prompt=np.asarray(d["prompt"], np.int32),
                   max_new=d["max_new"], eos_id=d.get("eos_id"),
                   temperature=d.get("temperature", 0.0),
                   seed=d.get("seed"),
                   ttft_deadline_ms=d.get("ttft_deadline_ms"),
                   tpot_deadline_ms=d.get("tpot_deadline_ms"),
                   ntok_base=int(d.get("ntok_base", 0)))


@dataclass
class StepPlan:
    """One scheduler step's broadcastable decisions.

    ``winner`` — registry step of a newly found tournament winner
    (None: no swap this step); ``submits`` — wire-encoded requests
    that entered host 0's queue since the last step (followers enqueue
    them verbatim, which is how network-fed requests reach every
    host); ``cancels`` — ``[rid, reason]`` pairs applied before
    admission (client disconnects + deadline sheds — both clock-driven
    host-0 decisions); ``admits`` — rids admitted, in order; ``stop``
    — coordinated-shutdown marker (followers exit their replay loop).
    Everything else the schedulers do is a deterministic function of
    replicated state, so this is the WHOLE control-plane wire format.
    Request ids must be JSON scalars (int / str) to be mesh-servable.
    """
    winner: Optional[int] = None
    admits: List[Any] = field(default_factory=list)
    submits: List[Dict[str, Any]] = field(default_factory=list)
    cancels: List[List[Any]] = field(default_factory=list)
    stop: bool = False
    # online-LTFB arena: the member host 0's match evaluation promoted
    # to champion this step (None: no promotion).  Followers apply the
    # identical promotion before admission replay.
    promote: Optional[str] = None

    def encode(self) -> bytes:
        """Serialize to the JSON wire format (bytes)."""
        return json.dumps({"winner": self.winner,
                           "admits": list(self.admits),
                           "submits": list(self.submits),
                           "cancels": [list(c) for c in self.cancels],
                           "stop": self.stop,
                           "promote": self.promote}).encode()

    @classmethod
    def decode(cls, payload: bytes) -> "StepPlan":
        """Parse the JSON wire format (tolerates plans from older
        writers that lack the submit/cancel/stop/promote fields)."""
        d = json.loads(payload.decode())
        return cls(winner=d["winner"], admits=d["admits"],
                   submits=d.get("submits", []),
                   cancels=d.get("cancels", []),
                   stop=d.get("stop", False),
                   promote=d.get("promote"))


def broadcast_plan(plan: StepPlan) -> StepPlan:
    """Host-0 -> all-hosts broadcast of a step plan over DEVICE
    collectives.

    Multi-process: two ``broadcast_one_to_all`` rounds (length, then
    the padded byte buffer) — requires a backend whose cross-process
    computations XLA supports (TPU/GPU; the CPU backend does not, use
    :class:`CoordServiceChannel` there).  Single-process: the encode ->
    decode round trip still runs, so the wire format is exercised by
    every CI step, not just the multi-host deployment.
    """
    payload = plan.encode()
    if jax.process_count() > 1:  # pragma: no cover (single-process CI)
        from jax.experimental import multihost_utils
        n = int(multihost_utils.broadcast_one_to_all(
            np.int32(len(payload))))
        # followers contribute zeros: their local plan is discarded by
        # the broadcast, and its length need not match host 0's
        buf = np.zeros((n,), np.uint8)
        if jax.process_index() == 0:
            buf[:n] = np.frombuffer(payload, np.uint8)[:n]
        payload = multihost_utils.broadcast_one_to_all(buf).tobytes()
    return StepPlan.decode(payload)


# ---------------------------------------------------------------------------
# plan transports
# ---------------------------------------------------------------------------

# Distinguishes sequential channel lifetimes inside one process AND
# stays aligned across processes (every process constructs its
# schedulers in the same deterministic order).
_CHANNEL_SEQ = [0]


class PlanChannel:
    """Host-0 -> all-hosts transport for :class:`StepPlan` bytes.

    ``broadcast(plan)`` takes the decided plan on host 0 and ``None``
    on followers; every process receives the plan host 0 sent.  All
    transports round-trip the wire encoding, so host 0's returned plan
    is exactly what followers decode.  ``retries`` counts transient
    fetch retries a degradation-capable transport performed before
    succeeding (surfaced as the ``plan_retries`` serve counter).
    """

    retries: int = 0

    def broadcast(self, plan: Optional[StepPlan]) -> StepPlan:
        """Send (host 0) / receive (followers) one plan; blocking."""
        raise NotImplementedError

    def gather(self, payload: bytes) -> Optional[List[bytes]]:
        """All-ranks → host-0 gather of small stats payloads.

        Every rank calls this once per exchange with its own payload
        (symmetric, like a collective).  Host 0 receives the ordered
        list ``[rank0, rank1, …]``; followers receive ``None``.
        Transports that cannot aggregate return ``None`` everywhere —
        host 0's export then covers its own shard only.
        """
        return None

    def close(self) -> None:
        """Release transport resources (idempotent)."""


class LoopbackChannel(PlanChannel):
    """Single-process transport: the plan round-trips its wire
    encoding so the format is exercised on every step."""

    def broadcast(self, plan: Optional[StepPlan]) -> StepPlan:
        """Encode + decode the plan in-process (host 0 only)."""
        if plan is None:
            raise RuntimeError(
                "LoopbackChannel has no peer to receive from "
                "(follower replay passes the plan explicitly)")
        return StepPlan.decode(plan.encode())

    def gather(self, payload: bytes) -> Optional[List[bytes]]:
        """Single-process gather: host 0 is the only rank, so the
        aggregation path runs on every CI step with world size 1."""
        return [bytes(payload)]


class CollectiveChannel(PlanChannel):
    """Multi-process transport over device collectives
    (``multihost_utils.broadcast_one_to_all``) — TPU/GPU deployments
    where XLA runs cross-process computations."""

    def broadcast(self, plan: Optional[StepPlan]) -> StepPlan:
        """Two broadcast_one_to_all rounds; followers pass None."""
        return broadcast_plan(plan if plan is not None else StepPlan())

    def gather(self, payload: bytes) -> Optional[List[bytes]]:
        """All-gather fixed-width padded payloads over the device
        collective; host 0 strips the padding per rank."""
        if jax.process_count() == 1:
            return [bytes(payload)]
        from jax.experimental import multihost_utils  # pragma: no cover
        buf = np.frombuffer(payload, np.uint8)  # pragma: no cover
        lens = multihost_utils.process_allgather(  # pragma: no cover
            np.int32(len(buf)))
        width = int(lens.max())  # pragma: no cover
        pad = np.zeros((width,), np.uint8)  # pragma: no cover
        pad[:len(buf)] = buf  # pragma: no cover
        allp = multihost_utils.process_allgather(pad)  # pragma: no cover
        if jax.process_index() != 0:  # pragma: no cover
            return None
        return [allp[r, :int(lens[r])].tobytes()  # pragma: no cover
                for r in range(allp.shape[0])]


def _capture(fn, *args):
    """Run ``fn`` and box the outcome (worker-thread helper for
    :meth:`CoordServiceChannel._deadlined`)."""
    try:
        return ("ok", fn(*args))
    except Exception as e:  # noqa: BLE001 — re-raised by the caller
        return ("err", e)


class CoordServiceChannel(PlanChannel):
    """Multi-process transport over the jax coordination service.

    The gRPC key-value store ``jax.distributed.initialize`` stands up
    is host-side — no device hop, and it works on the CPU backend
    where XLA's cross-process computations do not.  Per step ``n``:
    host 0 ``key_value_set_bytes(<ns>/<n>, plan)``, followers
    ``blocking_key_value_get_bytes`` it with ``timeout_s``, then all
    processes meet at barrier ``<ns>/b<n>`` (same timeout), after
    which host 0 deletes the key — the store holds at most one
    in-flight plan.  A dead peer turns into ``DEADLINE_EXCEEDED``
    at the barrier/get instead of an indefinite hang; we re-raise it
    as a RuntimeError naming the step and timeout.

    **Degradation**: the blocking KV *fetches* (follower plan get,
    host-0 stats gather) are retried ``max_retries`` times with
    exponential backoff before the peer is declared dead — a host
    paused by a GC stall or a slow NFS poll gets another chance; the
    retry count is surfaced as ``plan_retries``.  The delivery
    *barrier* is NOT retried: barrier state on the coordination
    service is not safely re-enterable after a timeout, so a barrier
    deadline is treated as confirmed peer death immediately.
    """

    def __init__(self, timeout_s: float = 60.0,
                 namespace: Optional[str] = None,
                 max_retries: int = 2, backoff_s: float = 0.05):
        from jax._src import distributed
        client = distributed.global_state.client
        if client is None:
            raise RuntimeError(
                "CoordServiceChannel needs jax.distributed.initialize() "
                "(no coordination-service client in this process)")
        self._client = client
        # rank from the coordination client, NOT jax.process_index():
        # the latter lazily initializes the device backend, whose
        # multi-process topology exchange hangs if a peer is already
        # dead — exactly when this channel must raise, not hang
        self._rank = int(distributed.global_state.process_id or 0)
        self._world = int(distributed.global_state.num_processes or 1)
        self._timeout_ms = max(1, int(timeout_s * 1000))
        if namespace is None:
            namespace = f"repro/plan{_CHANNEL_SEQ[0]}"
            _CHANNEL_SEQ[0] += 1
        self._ns = namespace
        self._seq = 0
        self._gseq = 0
        self._max_retries = max(0, int(max_retries))
        self._backoff_s = float(backoff_s)
        self.retries = 0

    def _deadlined(self, fn, *args):
        """Run a blocking coordination-service call with a HARD
        client-side deadline.

        The service's own timeouts are not sufficient: a peer that
        exits through Python's atexit (jax's distributed-shutdown
        handshake) leaves its connection half-closed, and
        ``wait_at_barrier`` has been observed to block far past its
        deadline in that state.  The call runs on a daemon thread and
        we abandon it after ``timeout + 5s`` — the thread leaks, but
        the caller is about to tear the process down anyway.
        """
        import queue as queue_mod
        import threading
        out: "queue_mod.Queue" = queue_mod.Queue()
        t = threading.Thread(
            target=lambda: out.put(_capture(fn, *args)), daemon=True)
        t.start()
        try:
            kind, val = out.get(timeout=self._timeout_ms / 1000 + 5.0)
        except queue_mod.Empty:
            raise TimeoutError(
                f"coordination-service call did not return within "
                f"{self._timeout_ms} ms (+5 s grace)") from None
        if kind == "err":
            raise val
        return val

    def _get_with_retry(self, key: str) -> bytes:
        """Blocking KV fetch with bounded retry + exponential backoff
        (the mesh-degradation knob: a slow peer is retried before
        being declared dead; each retry counts into ``retries``)."""
        delay = self._backoff_s
        for attempt in range(self._max_retries + 1):
            try:
                return self._deadlined(
                    self._client.blocking_key_value_get_bytes,
                    key, self._timeout_ms)
            except Exception:
                if attempt >= self._max_retries:
                    raise
                self.retries += 1
                print(f"[mesh] fetch of {key!r} timed out; retry "
                      f"{attempt + 1}/{self._max_retries} in {delay:.2f}s",
                      flush=True)
                time.sleep(delay)
                delay *= 2

    def broadcast(self, plan: Optional[StepPlan]) -> StepPlan:
        """One KV publish/fetch + delivery barrier; blocking with the
        channel's timeout (fetches retried per the channel's
        degradation policy).  Raises RuntimeError on confirmed peer
        death."""
        key = f"{self._ns}/{self._seq}"
        try:
            if self._rank == 0:
                if plan is None:
                    plan = StepPlan()
                self._client.key_value_set_bytes(key, plan.encode())
                payload = plan.encode()
            else:
                payload = self._get_with_retry(key)
            self._deadlined(self._client.wait_at_barrier,
                            f"{self._ns}/b{self._seq}", self._timeout_ms)
        except Exception as e:  # DEADLINE_EXCEEDED / TimeoutError
            raise RuntimeError(
                f"plan broadcast for step {self._seq} timed out after "
                f"{self._timeout_ms} ms — a peer process likely died "
                f"({type(e).__name__}: {e})") from e
        if self._rank == 0:
            self._client.key_value_delete(key)
        self._seq += 1
        return StepPlan.decode(payload)

    def gather(self, payload: bytes) -> Optional[List[bytes]]:
        """Followers publish their payload under a per-exchange key;
        host 0 blocking-gets every rank's (with the channel's hard
        deadline) and deletes the keys.  No barrier needed: the
        blocking gets ARE the synchronization, and the next plan
        broadcast's barrier keeps steps aligned."""
        seq = self._gseq
        self._gseq += 1
        try:
            if self._rank != 0:
                self._client.key_value_set_bytes(
                    f"{self._ns}/stats{seq}/{self._rank}", payload)
                return None
            out = [bytes(payload)]
            for r in range(1, self._world):
                out.append(self._get_with_retry(
                    f"{self._ns}/stats{seq}/{r}"))
            for r in range(1, self._world):
                self._client.key_value_delete(f"{self._ns}/stats{seq}/{r}")
            return out
        except Exception as e:  # DEADLINE_EXCEEDED / TimeoutError
            raise RuntimeError(
                f"stats gather {seq} timed out after {self._timeout_ms} "
                f"ms — a peer process likely died "
                f"({type(e).__name__}: {e})") from e


def make_plan_channel(timeout_s: float = 60.0) -> PlanChannel:
    """Pick the plan transport for this process topology: loopback
    single-process, device collectives where XLA supports them
    cross-process (TPU/GPU), the coordination service on CPU."""
    if jax.process_count() == 1:
        return LoopbackChannel()
    if jax.default_backend() in ("gpu", "tpu"):  # pragma: no cover
        return CollectiveChannel()
    return CoordServiceChannel(timeout_s=timeout_s)


# ---------------------------------------------------------------------------
# sharded decode session
# ---------------------------------------------------------------------------

# The serving-mesh rule set the mesh jits trace under.  Two runtime
# overrides on the dry-run preset: dense KV rows shard their HEADS
# over `model` instead of the sequence dim (the per-row decode scatter
# into the seq dim must stay shard-local), and recurrent STATE rows
# stay whole per slot (splitting the state contraction over `model`
# would reorder f32 accumulation and cost mesh-vs-single-device token
# identity for hybrid/ssm stacks).
MESH_SERVE_RULES = serve_rules(kv_seq=None, state=None)


# Mesh-DEDICATED jitted entry points, with the Mesh itself a STATIC
# argument.  This is load-bearing, not a convenience: jax caches traced
# jaxprs by aval (sharding excluded), so if the mesh path shared the
# single-device session's jits, whichever traced first would bake its
# trace-time decisions — `constrain` targets and the shard_map
# paged-gather dispatch — into the other's lowering.  A separate
# function object keyed on the mesh guarantees every mesh trace happens
# inside the mesh's sharding context, and two meshes never alias.


@partial(jax.jit, static_argnums=(1, 2), donate_argnums=(4,))
def _mesh_step_fn(params, cfg, mesh, tokens, cache, index, valid):
    with use_sharding(mesh, **MESH_SERVE_RULES):
        return lm.lm_decode(params, cfg, tokens, cache, index,
                            valid=valid)


@partial(jax.jit, static_argnums=(1, 2), donate_argnums=(4,))
def _mesh_step_tables_fn(params, cfg, mesh, tokens, cache, index,
                         tables, valid):
    with use_sharding(mesh, **MESH_SERVE_RULES):
        return lm.lm_decode(params, cfg, tokens, cache, index,
                            tables=tables, valid=valid)


@partial(jax.jit, static_argnums=(1, 2))
def _mesh_prefill_fn(params, cfg, mesh, toks, last_pos):
    with use_sharding(mesh, **MESH_SERVE_RULES):
        return lm.lm_prefill(params, cfg, {"tokens": toks},
                             last_pos=last_pos)


@partial(jax.jit, static_argnums=(1, 2), donate_argnums=(4,))
def _mesh_chunk_fn(params, cfg, mesh, toks, cache, tables, hist, plen,
                   last_pos):
    with use_sharding(mesh, **MESH_SERVE_RULES):
        return lm.lm_prefill(params, cfg, {"tokens": toks},
                             last_pos=last_pos, cache=cache,
                             tables=tables, hist_len=hist,
                             prompt_len=plen)


@partial(jax.jit, static_argnums=(1, 2, 7), donate_argnums=(4,))
def _mesh_draft_fn(params, cfg, mesh, tok0, cache, index, valid, steps):
    with use_sharding(mesh, **MESH_SERVE_RULES):
        return _draft_unroll(params, cfg, tok0, cache, index, valid,
                             steps, None)


@partial(jax.jit, static_argnums=(1, 2, 7), donate_argnums=(4,))
def _mesh_draft_tables_fn(params, cfg, mesh, tok0, cache, index, valid,
                          steps, tables):
    with use_sharding(mesh, **MESH_SERVE_RULES):
        return _draft_unroll(params, cfg, tok0, cache, index, valid,
                             steps, tables)


class MeshDecodeSession(DecodeSession):
    """A DecodeSession whose model calls trace under the serving mesh.

    All host-side marshalling is inherited; only the jit-indirection
    hooks are overridden to bind the mesh-dedicated jits above (the
    Mesh injected as their static argument), so every trace runs
    inside :func:`use_sharding`: ``constrain`` calls in the model
    resolve against the mesh (activations stay ``data``-sharded) and
    the paged-gather dispatch in ``kernels/ops.py`` lowers to its
    shard_map form.  ``params`` is the RAW (host / single-device)
    tree; the session places it — and re-places on ``set_params`` hot
    swaps, skipping the transfer when handed the same object (the
    engine calls ``set_params`` before every generate).
    """

    def __init__(self, cfg: ModelConfig, params, layout, mesh, rules,
                 placer):
        super().__init__(cfg, params, layout)
        self.mesh = mesh
        self.rules = rules
        self._place = placer
        self._src_params = None
        self.set_params(params)

    def set_params(self, params) -> None:
        """Hot-swap weights, re-placing them onto the mesh (no-op when
        the same pytree is already installed)."""
        if params is self._src_params:
            return
        self._src_params = params
        self.params = self._place(params)

    # -- jit indirection: mesh-dedicated executables -------------------------
    def _call_prefill(self, params, cfg, *args):
        return _mesh_prefill_fn(params, cfg, self.mesh, *args)

    def _call_chunk(self, params, cfg, *args):
        return _mesh_chunk_fn(params, cfg, self.mesh, *args)

    def _call_step(self, params, cfg, *args):
        return _mesh_step_fn(params, cfg, self.mesh, *args)

    def _call_step_tables(self, params, cfg, *args):
        return _mesh_step_tables_fn(params, cfg, self.mesh, *args)

    def _call_draft(self, params, cfg, *args):
        return _mesh_draft_fn(params, cfg, self.mesh, *args)

    def _call_draft_tables(self, params, cfg, *args):
        return _mesh_draft_tables_fn(params, cfg, self.mesh, *args)

    def step(self, tokens: np.ndarray, index: np.ndarray,
             valid: Optional[np.ndarray] = None,
             width: Optional[int] = None,
             rows: Optional[np.ndarray] = None,
             tables: Optional[np.ndarray] = None) -> jax.Array:
        """One full-batch decode step on the mesh (row subsets are a
        single-host optimization the sharded path rejects)."""
        if rows is not None or tables is not None:
            raise ValueError(
                "row-subset / explicit-table steps cannot run on the "
                "mesh (rows must stay in their data shard)")
        return super().step(tokens, index, valid=valid, width=width)


def cache_placer(mesh, rules):
    """(cache, axes) -> device-placed cache, under the serve rules —
    the ONE placement implementation every mesh session/layout uses."""
    def place(cache, axes):
        return jax.device_put(
            cache, tree_shardings(mesh, axes, cache, **rules))
    return place


def param_placer(mesh, rules, cfg: ModelConfig):
    """params -> device-placed params for ``cfg``.  The logical axes
    come from one eval_shape at closure build time, so hot swaps
    re-place with cached axes."""
    _, axes = specs_lib.param_specs(cfg)

    def place(params):
        return jax.device_put(
            params, tree_shardings(mesh, axes, params, **rules))
    return place


def make_engine_session(cfg: ModelConfig, params, mesh, batch: int,
                        max_len: int) -> MeshDecodeSession:
    """A mesh-sharded SlotLayout session for the batch Engine path."""
    rules = MESH_SERVE_RULES
    data, _ = mesh_axis_sizes(mesh)
    if batch % data:
        raise ValueError(
            f"engine batch {batch} must be divisible by the mesh data "
            f"axis ({data})")
    layout = SlotLayout(cfg, batch, max_len,
                        placer=cache_placer(mesh, rules))
    return MeshDecodeSession(cfg, params, layout, mesh, rules,
                             param_placer(mesh, rules, cfg))


# ---------------------------------------------------------------------------
# the mesh scheduler
# ---------------------------------------------------------------------------


class MeshScheduler(Scheduler):
    """Continuous-batching scheduler over a ("data", "model") mesh.

    All scheduling semantics are inherited — admission by token
    budget, chunked prefill, prefix sharing/pinning per shard,
    drain-aware hot swap, speculative decoding — with three mesh
    specifics:

    * **geometry** — ``num_slots`` / ``num_blocks`` are rounded up to
      multiples of the ``data`` axis; each request's pages live wholly
      in its slot's shard, so the per-request cap is the SHARD's
      capacity, not the pool's;
    * **decisions** — :meth:`step` produces a :class:`StepPlan` on
      host 0 and routes it through :func:`broadcast_plan`; a follower
      replica replays with ``step(plan=...)`` and must land in an
      identical state (asserted, and tested);
    * **dispatch** — every session is a :class:`MeshDecodeSession`;
      the ragged width-split subset dispatch is disabled (a subset of
      rows cannot be re-sharded over ``data`` without breaking the
      slot <-> shard alignment).
    """

    def __init__(self, cfg: ModelConfig, params, *, mesh=None,
                 mesh_shape: Optional[Tuple[int, int]] = None,
                 channel: Optional[PlanChannel] = None,
                 local_mesh: bool = False,
                 step_timeout_s: float = 60.0,
                 stats_every: int = 1, **kwargs):
        if mesh is None:
            if mesh_shape is None:
                mesh_shape = (jax.device_count(), 1)
            mesh = make_serve_mesh(*mesh_shape, local=local_mesh)
        self.mesh = mesh
        self.channel = channel if channel is not None \
            else make_plan_channel(timeout_s=step_timeout_s)
        # every N steps each rank ships a stats snapshot to host 0 over
        # the channel's gather (0 disables the exchange entirely);
        # MUST be identical on every rank — the exchange is symmetric
        self.stats_every = max(0, int(stats_every))
        # host-0 decisions pending broadcast in the next step's plan
        self._pending_submits: List[Dict[str, Any]] = []
        self._pending_cancels: List[Tuple[Any, str]] = []
        self.data_shards, self.model_shards = mesh_axis_sizes(mesh)
        self.rules = MESH_SERVE_RULES
        D = self.data_shards
        num_slots = kwargs.get("num_slots", 8)
        kwargs["num_slots"] = -(-num_slots // D) * D
        max_len = kwargs.get("max_len", 1024)
        block_size = kwargs.get("block_size", 16)
        n_blocks = kwargs.get("num_blocks")
        if n_blocks is None:
            n_blocks = kwargs["num_slots"] * blocks_for(max_len,
                                                        block_size)
        kwargs["num_blocks"] = -(-n_blocks // D) * D
        super().__init__(cfg, params, **kwargs)
        # subset dispatch cannot keep rows in their shard's partition
        self._group_decode = False

    # -- construction hooks --------------------------------------------------
    def _make_layout(self, cfg: ModelConfig):
        g = self._geom
        placer = cache_placer(self.mesh, self.rules)
        if self.paged:
            return PagedLayout(cfg, g["num_slots"], g["n_blocks"],
                               block_size=g["block_size"],
                               max_seq=g["max_seq"],
                               pin_prefix=g["pin_prefix"],
                               data_shards=self.data_shards,
                               placer=placer)
        return SlotLayout(cfg, g["num_slots"], g["max_len"],
                          block_size=g["block_size"],
                          num_blocks=g["num_blocks"],
                          placer=placer)

    def _make_session(self, cfg: ModelConfig, params,
                      layout) -> DecodeSession:
        return MeshDecodeSession(
            cfg, params, layout, self.mesh, self.rules,
            param_placer(self.mesh, self.rules, cfg))

    # -- admission (shard-aligned drafter) -----------------------------------
    def _can_admit_head(self) -> bool:
        if not self.paged or self.draft is None \
                or self.data_shards == 1:
            return super()._can_admit_head()
        req = self.queue[0]
        total = req.prompt_len + req.max_new
        if not self._pool_can_admit(self.pool, total, head=True):
            return False
        head = self._head_share
        shared = head[1][0] if head is not None and head[0] == req.rid \
            else ()
        shard = self.pool.peek_shard(total, shared)
        if shard is None:
            return False
        # the drafter's mirror admit lands at the SAME slot, hence the
        # same shard — its capacity must hold there, not just anywhere
        return self.draft.layout.shards[shard].blocks.can_allocate(total)

    # -- host-0 intake (recorded for the next plan broadcast) ----------------
    def submit(self, req: Request) -> None:
        """Enqueue a request AND record its wire encoding for the next
        plan broadcast, so followers that never saw the network request
        (gateway ingress lands on host 0 only) enqueue an identical
        copy before replaying the admission decisions."""
        super().submit(req)
        self._pending_submits.append(encode_request(req))

    def cancel(self, rid) -> bool:
        """Request cancellation of ``rid`` (host 0 only).

        Deferred to the next :meth:`step` so the drop happens at the
        same point of the step on every host (broadcast in the plan's
        ``cancels``).  Returns True if the rid is currently live; the
        cancel is a no-op if the request finishes first.
        """
        if rid not in self.active and rid not in self.prefilling and \
                not any(q.rid == rid for q in self.queue):
            return False
        self._pending_cancels.append((rid, "cancel"))
        return True

    def shed_expired(self) -> List[Any]:
        """Host-0 TTFT-deadline shedding: the clock is read HERE only;
        the victims ride the next plan's ``cancels`` so followers drop
        exactly the same queued requests.  Returns the rids shed."""
        now = time.perf_counter()
        pending = {rid for rid, _ in self._pending_cancels}
        shed = [q.rid for q in self.queue
                if q.rid not in pending
                and q.ttft_deadline_ms is not None
                and (now - getattr(q, "_submit_t", now)) * 1e3
                > q.ttft_deadline_ms]
        self._pending_cancels.extend((rid, "deadline") for rid in shed)
        return shed

    # -- host-0 plan / broadcast / replay ------------------------------------
    def step(self, plan: Optional[StepPlan] = None) -> StepPlan:
        """One scheduler iteration.

        ``plan=None`` on host 0: poll + decide + broadcast (the plan
        ALWAYS round-trips its wire encoding, single-process included).
        ``plan=None`` on a follower process: receive host 0's plan from
        the channel (blocking, with the channel's timeout).
        ``plan=...``: the explicit replay path — apply host 0's
        decisions verbatim, then run the identical jitted phases.
        Returns the plan that was executed; ``plan.stop`` means host 0
        initiated shutdown and no phases ran.
        """
        self.stats.start()
        self.telemetry.step_begin(self._step_count + 1)
        if self.faults is not None:
            self.faults.on_step(self, self._step_count + 1)
        if plan is None and jax.process_index() == 0:
            winner = self._poll_registry()
            self._step_count += 1
            self._apply_swap(winner)
            self._arena_rotate()
            promote = self._arena_decide()
            self._arena_apply(promote)
            submits = list(self._pending_submits)
            self._pending_submits.clear()
            cancels = [[rid, reason] for rid, reason
                       in self._pending_cancels
                       if self._cancel_now(rid, reason)]
            self._pending_cancels.clear()
            admits = self._admission_phase()
            plan = self.channel.broadcast(StepPlan(
                winner=winner, admits=admits, submits=submits,
                cancels=cancels, promote=promote))
        else:
            if plan is None:  # pragma: no cover (multi-host follower)
                plan = self.channel.broadcast(None)
            if plan.stop:
                # balance step_begin (closes an armed profiler window)
                self.telemetry.step_end()
                return plan
            self._step_count += 1
            if plan.winner is not None and self.registry is not None:
                self.registry.load_step(plan.winner)
                self._apply_swap(plan.winner)
            else:
                # no registry attached: there is nothing to swap to —
                # but still run the pending-drain half of the check
                self._apply_swap(None)
            # arena: followers replay host 0's promotion verbatim (the
            # rotation itself is a pure function of replicated state)
            self._arena_rotate()
            self._arena_apply(plan.promote)
            self._apply_submits(plan.submits)
            for rid, reason in plan.cancels:
                self._cancel_now(rid, reason)
            self._replay_admissions(plan.admits)
        tel = self.telemetry
        had_pf = bool(self._pending_draft or self._pending_onepass
                      or self.prefilling)
        t0 = time.perf_counter()
        self._prefill_phase()
        t1 = time.perf_counter()
        tel.phase("prefill", t0, t1, emit=had_pf)
        had_dec = bool(self.active)
        self._decode_phase()
        tel.phase("decode", t1, time.perf_counter(), emit=had_dec)
        self._exchange_stats()
        self.stats.sample_step(len(self.queue),
                               len(self.active) + len(self.prefilling))
        self.stats.plan_retries = getattr(self.channel, "retries", 0)
        self._journal_step()
        tel.step_end()
        return plan

    def _exchange_stats(self) -> None:
        """Symmetric per-step stats exchange: every rank ships its
        :func:`repro.serve.telemetry.stats_snapshot` to host 0 through
        the channel's ``gather``; host 0 keeps the latest snapshot per
        rank in ``remote_stats`` (what ``GET /metrics`` and the
        distributed launcher export).  Runs every ``stats_every`` steps
        on ALL ranks or none — the gather is a collective."""
        if not self.stats_every or self._step_count % self.stats_every:
            return
        rank = jax.process_index()
        snap = stats_snapshot(self, rank=rank)
        got = self.channel.gather(json.dumps(snap).encode())
        if got is not None:
            snaps = [json.loads(p.decode()) for p in got]
            self.remote_stats = {int(s["rank"]): s for s in snaps}

    def shutdown(self) -> StepPlan:
        """Host 0: broadcast the coordinated-shutdown plan and close
        the channel.  Followers return from :meth:`step` (or
        :meth:`run_follower`) when they receive it, so every process
        exits its serve loop on the same step."""
        plan = self.channel.broadcast(StepPlan(stop=True))
        self.stats.stop()
        self.channel.close()
        return plan

    def run_follower(self) -> Dict[Any, np.ndarray]:
        """Follower serve loop: replay broadcast plans until host 0's
        stop plan arrives (or the channel times out — a dead host 0
        raises instead of hanging).  Returns the replica's results,
        which mirror host 0's exactly."""
        while True:
            plan = self.step()
            if plan.stop:
                break
        self.stats.stop()
        self.channel.close()
        return self.results

    def _apply_submits(self, submits: List[Dict[str, Any]]) -> None:
        """Enqueue host 0's newly submitted requests on a follower.

        Requests this process already holds (replicated feeds, or the
        host-0 replica itself replaying its own plan in tests) are
        recognized by rid and skipped — the wire copy and the local
        copy are identical by construction.
        """
        self._pending_submits.clear()
        known = {q.rid for q in self.queue}
        # host 0 already ruled on overload at ingress time; replaying
        # the max_queue check here (against the batched queue depth)
        # could diverge, so it is suspended for the replay
        saved, self.max_queue = self.max_queue, None
        try:
            for d in submits:
                rid = d["rid"]
                if rid in known or rid in self.active \
                        or rid in self.prefilling or rid in self.results:
                    continue
                Scheduler.submit(self, decode_request(d))
        finally:
            self.max_queue = saved

    def _replay_admissions(self, admits: List[Any]) -> None:
        """Apply host 0's admission decisions on a follower: the local
        queue must agree (requests are submitted identically on every
        host), and local accounting must accept each admission — any
        divergence is a hard error, not a silent drift."""
        for rid in admits:
            if not self.queue or self.queue[0].rid != rid:
                raise RuntimeError(
                    f"follower queue diverged from host 0: expected "
                    f"{rid!r} at the head, have "
                    f"{self.queue[0].rid if self.queue else None!r}")
            if not self._can_admit_head():
                raise RuntimeError(
                    f"follower cannot admit {rid!r}: scheduler state "
                    "diverged from host 0")
            self._admit(self.queue.popleft())
