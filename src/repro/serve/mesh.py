"""Multi-host serving mesh: sharded decode with a host-0 scheduler.

Training already runs on a device mesh; this module puts the SERVING
stack on one.  The existing :class:`repro.serve.session.DecodeSession`
/ :class:`repro.serve.kv_cache.CacheLayout` machinery is reused
unchanged — the mesh runtime only decides *where things live* and *who
decides*:

**Axis layout** (the dry-run "serve" preset,
:data:`repro.parallel.sharding.SERVE_RULES`):

  * **weights** — stationary, tensor-parallel over ``model`` (vocab /
    head / mlp / expert dims); never gathered, per-token collectives
    are tiny activation all-reduces;
  * **decode batch** — the ``num_slots`` rows split over ``data``:
    tokens, write indices, block tables, logits;
  * **cache leaves** — every one over ``data``: dense KV rows and
    recurrent state on their batch dim, paged pools on the PAGE dim.
    The paged pool becomes ``data``-many private sub-pools, each with
    its own null page, each accounted by a host-local
    :class:`repro.serve.kv_cache.PageShard`; block tables hold global
    page ids and the shard_map gather dispatch
    (:func:`repro.kernels.ops.paged_attention`) rebases them
    per-shard, so decode NEVER moves a KV page across ``data``.

**Control plane**: scheduling state (queue, slot maps, block
managers, prefix caches) is replicated host-side and evolves
deterministically — with two exceptions, both decided by **host 0**
and broadcast as a :class:`StepPlan` each step:

  * *admission* — which queued requests enter the batch this step
    (and implicitly which pinned pages get reclaimed for them);
  * *hot swap* — whether a newer tournament winner was found on disk
    (filesystem reads race the trainer; followers load exactly the
    broadcast step).

After the plan lands, every host executes the SAME jitted prefill /
decode dispatches on the sharded arrays.  In this container jax runs
single-process (multi-host is emulated with
``--xla_force_host_platform_device_count``); the plan still round-trips
through its wire encoding on every step, and the follower path is the
``step(plan=...)`` replay the tests drive a second scheduler replica
with.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from functools import partial
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch import specs as specs_lib
from repro.models import lm
from repro.parallel.sharding import (serve_rules, tree_shardings,
                                     use_sharding)
from repro.serve.kv_cache import PagedLayout, SlotLayout, blocks_for
from repro.serve.scheduler import Scheduler
from repro.serve.session import DecodeSession, _draft_unroll


# ---------------------------------------------------------------------------
# mesh construction
# ---------------------------------------------------------------------------


def parse_mesh(spec: str) -> Tuple[int, int]:
    """Parse ``--mesh`` values: "4,2" / "data=4,model=2" / "8" (pure
    data parallelism) -> (data, model)."""
    parts = [p.strip() for p in spec.split(",") if p.strip()]
    named = {}
    sizes = []
    for p in parts:
        if "=" in p:
            k, v = p.split("=", 1)
            named[k.strip()] = int(v)
        else:
            sizes.append(int(p))
    if named:
        return named.get("data", 1), named.get("model", 1)
    if len(sizes) == 1:
        return sizes[0], 1
    if len(sizes) == 2:
        return sizes[0], sizes[1]
    raise ValueError(f"cannot parse mesh spec {spec!r}")


def make_serve_mesh(data: int, model: int = 1):
    """("data", "model") mesh over the first data*model visible devices."""
    from jax.sharding import Mesh
    n = data * model
    devices = jax.devices()
    if len(devices) < n:
        raise ValueError(
            f"serving mesh {data}x{model} needs {n} devices, have "
            f"{len(devices)} (set XLA_FLAGS="
            "--xla_force_host_platform_device_count=N to emulate)")
    return Mesh(np.asarray(devices[:n]).reshape(data, model),
                ("data", "model"))


def mesh_axis_sizes(mesh) -> Tuple[int, int]:
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return axes.get("data", 1), axes.get("model", 1)


# ---------------------------------------------------------------------------
# the host-0 decision record
# ---------------------------------------------------------------------------


@dataclass
class StepPlan:
    """One scheduler step's broadcastable decisions.

    ``winner`` — registry step of a newly found tournament winner
    (None: no swap this step); ``admits`` — rids admitted, in order.
    Everything else the schedulers do is a deterministic function of
    replicated state, so this is the WHOLE control-plane wire format.
    Request ids must be JSON scalars (int / str) to be mesh-servable.
    """
    winner: Optional[int] = None
    admits: List[Any] = field(default_factory=list)

    def encode(self) -> bytes:
        return json.dumps({"winner": self.winner,
                           "admits": list(self.admits)}).encode()

    @classmethod
    def decode(cls, payload: bytes) -> "StepPlan":
        d = json.loads(payload.decode())
        return cls(winner=d["winner"], admits=d["admits"])


def broadcast_plan(plan: StepPlan) -> StepPlan:
    """Host-0 -> all-hosts broadcast of a step plan.

    Multi-process: two ``broadcast_one_to_all`` rounds (length, then
    the padded byte buffer).  Single-process (this container): the
    encode -> decode round trip still runs, so the wire format is
    exercised by every CI step, not just the multi-host deployment.
    """
    payload = plan.encode()
    if jax.process_count() > 1:  # pragma: no cover (single-process CI)
        from jax.experimental import multihost_utils
        n = int(multihost_utils.broadcast_one_to_all(
            np.int32(len(payload))))
        # followers contribute zeros: their local plan is discarded by
        # the broadcast, and its length need not match host 0's
        buf = np.zeros((n,), np.uint8)
        if jax.process_index() == 0:
            buf[:n] = np.frombuffer(payload, np.uint8)[:n]
        payload = multihost_utils.broadcast_one_to_all(buf).tobytes()
    return StepPlan.decode(payload)


# ---------------------------------------------------------------------------
# sharded decode session
# ---------------------------------------------------------------------------

# The serving-mesh rule set the mesh jits trace under.  Two runtime
# overrides on the dry-run preset: dense KV rows shard their HEADS
# over `model` instead of the sequence dim (the per-row decode scatter
# into the seq dim must stay shard-local), and recurrent STATE rows
# stay whole per slot (splitting the state contraction over `model`
# would reorder f32 accumulation and cost mesh-vs-single-device token
# identity for hybrid/ssm stacks).
MESH_SERVE_RULES = serve_rules(kv_seq=None, state=None)


# Mesh-DEDICATED jitted entry points, with the Mesh itself a STATIC
# argument.  This is load-bearing, not a convenience: jax caches traced
# jaxprs by aval (sharding excluded), so if the mesh path shared the
# single-device session's jits, whichever traced first would bake its
# trace-time decisions — `constrain` targets and the shard_map
# paged-gather dispatch — into the other's lowering.  A separate
# function object keyed on the mesh guarantees every mesh trace happens
# inside the mesh's sharding context, and two meshes never alias.


@partial(jax.jit, static_argnums=(1, 2), donate_argnums=(4,))
def _mesh_step_fn(params, cfg, mesh, tokens, cache, index, valid):
    with use_sharding(mesh, **MESH_SERVE_RULES):
        return lm.lm_decode(params, cfg, tokens, cache, index,
                            valid=valid)


@partial(jax.jit, static_argnums=(1, 2), donate_argnums=(4,))
def _mesh_step_tables_fn(params, cfg, mesh, tokens, cache, index,
                         tables, valid):
    with use_sharding(mesh, **MESH_SERVE_RULES):
        return lm.lm_decode(params, cfg, tokens, cache, index,
                            tables=tables, valid=valid)


@partial(jax.jit, static_argnums=(1, 2))
def _mesh_prefill_fn(params, cfg, mesh, toks, last_pos):
    with use_sharding(mesh, **MESH_SERVE_RULES):
        return lm.lm_prefill(params, cfg, {"tokens": toks},
                             last_pos=last_pos)


@partial(jax.jit, static_argnums=(1, 2), donate_argnums=(4,))
def _mesh_chunk_fn(params, cfg, mesh, toks, cache, tables, hist, plen,
                   last_pos):
    with use_sharding(mesh, **MESH_SERVE_RULES):
        return lm.lm_prefill(params, cfg, {"tokens": toks},
                             last_pos=last_pos, cache=cache,
                             tables=tables, hist_len=hist,
                             prompt_len=plen)


@partial(jax.jit, static_argnums=(1, 2, 7), donate_argnums=(4,))
def _mesh_draft_fn(params, cfg, mesh, tok0, cache, index, valid, steps):
    with use_sharding(mesh, **MESH_SERVE_RULES):
        return _draft_unroll(params, cfg, tok0, cache, index, valid,
                             steps, None)


@partial(jax.jit, static_argnums=(1, 2, 7), donate_argnums=(4,))
def _mesh_draft_tables_fn(params, cfg, mesh, tok0, cache, index, valid,
                          steps, tables):
    with use_sharding(mesh, **MESH_SERVE_RULES):
        return _draft_unroll(params, cfg, tok0, cache, index, valid,
                             steps, tables)


class MeshDecodeSession(DecodeSession):
    """A DecodeSession whose model calls trace under the serving mesh.

    All host-side marshalling is inherited; only the jit-indirection
    hooks are overridden to bind the mesh-dedicated jits above (the
    Mesh injected as their static argument), so every trace runs
    inside :func:`use_sharding`: ``constrain`` calls in the model
    resolve against the mesh (activations stay ``data``-sharded) and
    the paged-gather dispatch in ``kernels/ops.py`` lowers to its
    shard_map form.  ``params`` is the RAW (host / single-device)
    tree; the session places it — and re-places on ``set_params`` hot
    swaps, skipping the transfer when handed the same object (the
    engine calls ``set_params`` before every generate).
    """

    def __init__(self, cfg: ModelConfig, params, layout, mesh, rules,
                 placer):
        super().__init__(cfg, params, layout)
        self.mesh = mesh
        self.rules = rules
        self._place = placer
        self._src_params = None
        self.set_params(params)

    def set_params(self, params) -> None:
        if params is self._src_params:
            return
        self._src_params = params
        self.params = self._place(params)

    # -- jit indirection: mesh-dedicated executables -------------------------
    def _call_prefill(self, params, cfg, *args):
        return _mesh_prefill_fn(params, cfg, self.mesh, *args)

    def _call_chunk(self, params, cfg, *args):
        return _mesh_chunk_fn(params, cfg, self.mesh, *args)

    def _call_step(self, params, cfg, *args):
        return _mesh_step_fn(params, cfg, self.mesh, *args)

    def _call_step_tables(self, params, cfg, *args):
        return _mesh_step_tables_fn(params, cfg, self.mesh, *args)

    def _call_draft(self, params, cfg, *args):
        return _mesh_draft_fn(params, cfg, self.mesh, *args)

    def _call_draft_tables(self, params, cfg, *args):
        return _mesh_draft_tables_fn(params, cfg, self.mesh, *args)

    def step(self, tokens: np.ndarray, index: np.ndarray,
             valid: Optional[np.ndarray] = None,
             width: Optional[int] = None,
             rows: Optional[np.ndarray] = None,
             tables: Optional[np.ndarray] = None) -> jax.Array:
        if rows is not None or tables is not None:
            raise ValueError(
                "row-subset / explicit-table steps cannot run on the "
                "mesh (rows must stay in their data shard)")
        return super().step(tokens, index, valid=valid, width=width)


def cache_placer(mesh, rules):
    """(cache, axes) -> device-placed cache, under the serve rules —
    the ONE placement implementation every mesh session/layout uses."""
    def place(cache, axes):
        return jax.device_put(
            cache, tree_shardings(mesh, axes, cache, **rules))
    return place


def param_placer(mesh, rules, cfg: ModelConfig):
    """params -> device-placed params for ``cfg``.  The logical axes
    come from one eval_shape at closure build time, so hot swaps
    re-place with cached axes."""
    _, axes = specs_lib.param_specs(cfg)

    def place(params):
        return jax.device_put(
            params, tree_shardings(mesh, axes, params, **rules))
    return place


def make_engine_session(cfg: ModelConfig, params, mesh, batch: int,
                        max_len: int) -> MeshDecodeSession:
    """A mesh-sharded SlotLayout session for the batch Engine path."""
    rules = MESH_SERVE_RULES
    data, _ = mesh_axis_sizes(mesh)
    if batch % data:
        raise ValueError(
            f"engine batch {batch} must be divisible by the mesh data "
            f"axis ({data})")
    layout = SlotLayout(cfg, batch, max_len,
                        placer=cache_placer(mesh, rules))
    return MeshDecodeSession(cfg, params, layout, mesh, rules,
                             param_placer(mesh, rules, cfg))


# ---------------------------------------------------------------------------
# the mesh scheduler
# ---------------------------------------------------------------------------


class MeshScheduler(Scheduler):
    """Continuous-batching scheduler over a ("data", "model") mesh.

    All scheduling semantics are inherited — admission by token
    budget, chunked prefill, prefix sharing/pinning per shard,
    drain-aware hot swap, speculative decoding — with three mesh
    specifics:

    * **geometry** — ``num_slots`` / ``num_blocks`` are rounded up to
      multiples of the ``data`` axis; each request's pages live wholly
      in its slot's shard, so the per-request cap is the SHARD's
      capacity, not the pool's;
    * **decisions** — :meth:`step` produces a :class:`StepPlan` on
      host 0 and routes it through :func:`broadcast_plan`; a follower
      replica replays with ``step(plan=...)`` and must land in an
      identical state (asserted, and tested);
    * **dispatch** — every session is a :class:`MeshDecodeSession`;
      the ragged width-split subset dispatch is disabled (a subset of
      rows cannot be re-sharded over ``data`` without breaking the
      slot <-> shard alignment).
    """

    def __init__(self, cfg: ModelConfig, params, *, mesh=None,
                 mesh_shape: Optional[Tuple[int, int]] = None, **kwargs):
        if mesh is None:
            if mesh_shape is None:
                mesh_shape = (jax.device_count(), 1)
            mesh = make_serve_mesh(*mesh_shape)
        self.mesh = mesh
        self.data_shards, self.model_shards = mesh_axis_sizes(mesh)
        self.rules = MESH_SERVE_RULES
        D = self.data_shards
        num_slots = kwargs.get("num_slots", 8)
        kwargs["num_slots"] = -(-num_slots // D) * D
        max_len = kwargs.get("max_len", 1024)
        block_size = kwargs.get("block_size", 16)
        n_blocks = kwargs.get("num_blocks")
        if n_blocks is None:
            n_blocks = kwargs["num_slots"] * blocks_for(max_len,
                                                        block_size)
        kwargs["num_blocks"] = -(-n_blocks // D) * D
        super().__init__(cfg, params, **kwargs)
        # subset dispatch cannot keep rows in their shard's partition
        self._group_decode = False

    # -- construction hooks --------------------------------------------------
    def _make_layout(self, cfg: ModelConfig):
        g = self._geom
        placer = cache_placer(self.mesh, self.rules)
        if self.paged:
            return PagedLayout(cfg, g["num_slots"], g["n_blocks"],
                               block_size=g["block_size"],
                               max_seq=g["max_seq"],
                               pin_prefix=g["pin_prefix"],
                               data_shards=self.data_shards,
                               placer=placer)
        return SlotLayout(cfg, g["num_slots"], g["max_len"],
                          block_size=g["block_size"],
                          num_blocks=g["num_blocks"],
                          placer=placer)

    def _make_session(self, cfg: ModelConfig, params,
                      layout) -> DecodeSession:
        return MeshDecodeSession(
            cfg, params, layout, self.mesh, self.rules,
            param_placer(self.mesh, self.rules, cfg))

    # -- admission (shard-aligned drafter) -----------------------------------
    def _can_admit_head(self) -> bool:
        if not self.paged or self.draft is None \
                or self.data_shards == 1:
            return super()._can_admit_head()
        req = self.queue[0]
        total = req.prompt_len + req.max_new
        if not self._pool_can_admit(self.pool, total, head=True):
            return False
        head = self._head_share
        shared = head[1][0] if head is not None and head[0] == req.rid \
            else ()
        shard = self.pool.peek_shard(total, shared)
        if shard is None:
            return False
        # the drafter's mirror admit lands at the SAME slot, hence the
        # same shard — its capacity must hold there, not just anywhere
        return self.draft.layout.shards[shard].blocks.can_allocate(total)

    # -- host-0 plan / broadcast / replay ------------------------------------
    def step(self, plan: Optional[StepPlan] = None) -> StepPlan:
        """One scheduler iteration.

        ``plan=None`` on host 0: poll + decide + broadcast (the plan
        ALWAYS round-trips its wire encoding, single-process included).
        ``plan=...``: the follower replay path — apply host 0's
        decisions verbatim, then run the identical jitted phases.
        Returns the plan that was executed.
        """
        self.stats.start()
        if plan is None and jax.process_index() == 0:
            winner = self._poll_registry()
            self._step_count += 1
            self._apply_swap(winner)
            admits = self._admission_phase()
            plan = broadcast_plan(StepPlan(winner=winner, admits=admits))
        else:
            if plan is None:  # pragma: no cover (multi-host follower)
                plan = broadcast_plan(StepPlan())
            self._step_count += 1
            if plan.winner is not None and self.registry is not None:
                self.registry.load_step(plan.winner)
                self._apply_swap(plan.winner)
            else:
                # no registry attached: there is nothing to swap to —
                # but still run the pending-drain half of the check
                self._apply_swap(None)
            self._replay_admissions(plan.admits)
        self._prefill_phase()
        self._decode_phase()
        self.stats.sample_step(len(self.queue),
                               len(self.active) + len(self.prefilling))
        return plan

    def _replay_admissions(self, admits: List[Any]) -> None:
        """Apply host 0's admission decisions on a follower: the local
        queue must agree (requests are submitted identically on every
        host), and local accounting must accept each admission — any
        divergence is a hard error, not a silent drift."""
        for rid in admits:
            if not self.queue or self.queue[0].rid != rid:
                raise RuntimeError(
                    f"follower queue diverged from host 0: expected "
                    f"{rid!r} at the head, have "
                    f"{self.queue[0].rid if self.queue else None!r}")
            if not self._can_admit_head():
                raise RuntimeError(
                    f"follower cannot admit {rid!r}: scheduler state "
                    "diverged from host 0")
            self._admit(self.queue.popleft())
