"""Population-aware model loading: serve the tournament winner.

Bridges training and serving: ``launch/ltfb.py`` checkpoints its whole
population through :mod:`repro.checkpoint.ckpt`
(``step_<n>_trainer_<i>.ckpt`` + ``step_<n>.manifest``); this module

  * **exports a winner** from a population step — by tournament metric
    on a validation batch when one is supplied, else by the win counts
    the tournament recorded in each trainer's checkpoint metadata — to
    a self-contained ``winner_step_<n>.ckpt``;
  * **hot-swaps** newer winners into a running server: a
    :class:`ModelRegistry` polled between scheduler steps reloads when
    a newer winner file (or, with ``auto_export``, a newer population
    step) appears, so serving follows training live.

Hot-swap is **transactional**: exports write a sha256 sidecar manifest
(``winner_step_<n>.ckpt.sha256``) next to the atomically-renamed
checkpoint, and the polling path verifies it before touching
``self.params``.  A corrupt or torn winner (a writer that died
mid-copy, a truncated rsync) is *quarantined* — renamed to
``*.corrupt`` and counted in ``rejected_corrupt`` — while the previous
winner keeps serving; with ``auto_export`` the next poll re-exports a
good copy from the population checkpoints.  Mesh followers load with
``strict=True`` instead: host 0 already verified the winner before
broadcasting the step, so a follower-side failure must raise rather
than silently diverge the mesh.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint import ckpt
from repro.serve.telemetry import log_event

Params = Any

_WINNER_RE = re.compile(r"^winner_step_(\d+)\.ckpt$")


def checksum_path(path: str) -> str:
    """The sha256 sidecar manifest for a checkpoint file."""
    return path + ".sha256"


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def write_checksum(path: str) -> str:
    """Write the sha256+size sidecar for ``path`` (atomic tmp+rename);
    returns the sidecar path."""
    side = checksum_path(path)
    rec = {"sha256": _sha256(path), "size": os.path.getsize(path)}
    tmp = side + ".tmp"
    with open(tmp, "w") as f:
        json.dump(rec, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, side)
    return side


def verify_checkpoint(path: str) -> None:
    """Verify a checkpoint against its sidecar manifest.

    Raises ``ValueError`` on a size or sha256 mismatch (torn/corrupt
    file).  A missing sidecar passes silently — it is a legacy export
    or one mid-write; ``ckpt.restore`` itself still raises if the file
    is unreadable.
    """
    side = checksum_path(path)
    if not os.path.exists(side):
        return
    with open(side) as f:
        rec = json.load(f)
    size = os.path.getsize(path)
    if size != int(rec.get("size", -1)):
        raise ValueError(
            f"checkpoint {path!r} is {size} bytes, manifest says "
            f"{rec.get('size')} (torn write?)")
    digest = _sha256(path)
    if digest != rec.get("sha256"):
        raise ValueError(
            f"checkpoint {path!r} sha256 mismatch: file {digest[:12]}… "
            f"!= manifest {str(rec.get('sha256'))[:12]}… (corrupt)")


def winner_path(ckpt_dir: str, step: int) -> str:
    """The exported-winner checkpoint file for ``step``."""
    return os.path.join(ckpt_dir, f"winner_step_{step}.ckpt")


def latest_winner_step(ckpt_dir: str) -> Optional[int]:
    """Newest exported-winner step in a checkpoint dir (None if none)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := _WINNER_RE.match(f))]
    return max(steps) if steps else None


def population_steps(ckpt_dir: str) -> List[int]:
    """All population-checkpoint steps in a dir, oldest first."""
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(int(f[len("step_"):-len(".manifest")])
                  for f in os.listdir(ckpt_dir)
                  if f.startswith("step_") and f.endswith(".manifest"))


def check_draft_compat(target_cfg, draft_cfg,
                       member: Optional[str] = None) -> None:
    """Serving a draft arch different from the target's is fine — the
    drafter only PROPOSES tokens — but the two must share a token
    space: draft samples index the target's embedding, so an unequal
    vocab is a tokenizer mismatch, not a shape detail.  Raises a clear
    ValueError — naming the offending member/checkpoint via ``member``
    (arena rosters make multi-member load failures common) and both
    vocab sizes — instead of letting the embedding lookup break later."""
    if draft_cfg.vocab_size != target_cfg.vocab_size:
        who = f"draft member {member!r} (arch {draft_cfg.name!r})" \
            if member else f"draft arch {draft_cfg.name!r}"
        raise ValueError(
            f"{who} has vocab_size "
            f"{draft_cfg.vocab_size} but the target {target_cfg.name!r} "
            f"has {target_cfg.vocab_size}: the two models are tokenizer-"
            "incompatible — draft proposals would index the wrong "
            "embedding rows. Pick a drafter trained on the same "
            "tokenizer (any LTFB population checkpoint of the target "
            "arch qualifies).")


def _embed_vocab(params: Params) -> Optional[int]:
    embed = params.get("embed") if isinstance(params, dict) else None
    return None if embed is None else int(embed.shape[0])


def load_draft(path: str, like_params: Params,
               step: Optional[int] = None,
               expect_vocab: Optional[int] = None) -> Tuple[Params, dict]:
    """Load a DRAFTER for population speculative decoding.

    The LTFB population is a free source of draft models: any
    earlier/smaller checkpoint proposes tokens the current winner
    verifies.  ``path`` is either a self-contained ``.ckpt`` file or a
    population checkpoint dir — there the EARLIEST step's winner is
    used by default (``step`` overrides), exported on demand.
    ``like_params`` is the DRAFT arch's parameter template (which may
    be smaller than the target's); ``expect_vocab`` is the TARGET's
    vocab size — checked against the restored embedding so an
    incompatible drafter fails with a clear error instead of shape
    breakage mid-serve.  Returns (params, info).
    """
    if os.path.isfile(path):
        params, meta = _restore_draft(path, like_params)
    else:
        steps = population_steps(path)
        if not steps:
            raise FileNotFoundError(f"no population checkpoint in {path!r}")
        s = step if step is not None else steps[0]
        if not os.path.exists(winner_path(path, s)):
            export_winner(path, like_params, step=s)
        params, meta = _restore_draft(winner_path(path, s), like_params)
    if expect_vocab is not None:
        got = _embed_vocab(params)
        if got is not None and got != expect_vocab:
            kind = "member dir" if os.path.isdir(path) else "checkpoint"
            raise ValueError(
                f"draft {kind} {path!r} has vocab_size {got} but "
                f"the serving target expects vocab_size {expect_vocab}: "
                "the drafter is tokenizer-incompatible with the target.")
    return params, meta


def _restore_draft(path: str, like_params: Params) -> Tuple[Params, dict]:
    try:
        verify_checkpoint(path)
        tree, meta = ckpt.restore(path, {"params": like_params})
    except Exception as e:
        raise ValueError(
            f"draft checkpoint {path!r} does not match the draft arch's "
            f"parameter tree (wrong --draft-arch for this checkpoint?): "
            f"{type(e).__name__}: {e}") from e
    return tree["params"], meta


def load_population_params(ckpt_dir: str, step: int, like_params: Params
                           ) -> Tuple[List[Params], List[dict]]:
    """All trainer params (+ checkpoint metadata) of one population step.

    Only the ``params`` subtree is materialized — trainer checkpoints
    also hold optimizer state, which serving never needs.
    """
    import json

    with open(os.path.join(ckpt_dir, f"step_{step}.manifest")) as f:
        manifest = json.load(f)
    params, metas = [], []
    for i in range(manifest["num_trainers"]):
        member = os.path.join(ckpt_dir, f"step_{step}_trainer_{i}.ckpt")
        try:
            tree, meta = ckpt.restore(member, {"params": like_params})
        except Exception as e:
            raise ValueError(
                f"population member trainer_{i} of {ckpt_dir!r} failed "
                f"to restore from {member!r}: {type(e).__name__}: {e} "
                "(wrong --arch for this population, or a torn trainer "
                "checkpoint?)") from e
        params.append(tree["params"])
        metas.append(meta)
    return params, metas


def select_winner(params: List[Params], metas: List[dict],
                  metric_fn: Optional[Callable] = None,
                  val_batch: Optional[dict] = None
                  ) -> Tuple[int, Dict[str, float]]:
    """Winning trainer index: tournament metric (lower = better) on
    `val_batch` when given, else the trainer with the most recorded
    tournament wins."""
    if metric_fn is not None and val_batch is not None:
        scores = [float(metric_fn(p, val_batch)) for p in params]
        idx = int(np.argmin(scores))
        return idx, {"selected_by": "metric", "metric": scores[idx]}
    wins = [int(m.get("wins", 0)) for m in metas]
    idx = int(np.argmax(wins))
    return idx, {"selected_by": "wins"}


def export_winner(ckpt_dir: str, like_params: Params,
                  step: Optional[int] = None,
                  metric_fn: Optional[Callable] = None,
                  val_batch: Optional[dict] = None) -> Tuple[str, dict]:
    """Export the winning trainer of a population step to
    ``winner_step_<n>.ckpt``; returns (path, info)."""
    if step is None:
        step = ckpt.latest_population_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(
                f"no population checkpoint in {ckpt_dir!r}")
    params, metas = load_population_params(ckpt_dir, step, like_params)
    idx, how = select_winner(params, metas, metric_fn, val_batch)
    info = {"step": step, "trainer": idx,
            "steps": int(metas[idx].get("steps", 0)),
            "wins": int(metas[idx].get("wins", 0)), **how}
    path = winner_path(ckpt_dir, step)
    ckpt.save(path, {"params": params[idx]}, metadata=info)
    write_checksum(path)
    return path, info


def archive_member(ckpt_dir: str, name: str, params: Params,
                   generation: int, tag: str = "retired") -> str:
    """Archive an arena roster member as a dated registry generation.

    The online-LTFB promotion transaction (``serve/arena.py``) calls
    this twice — once to quarantine the dethroned champion
    (``tag="retired"``) and once to export the winner
    (``tag="champion"``) — writing
    ``<ckpt_dir>/arena/gen_<NNNN>_<date>_<tag>_<name>.ckpt`` with a
    sha256 sidecar, so every promotion leaves an auditable, restorable
    trail.  Returns the checkpoint path.
    """
    import datetime

    adir = os.path.join(ckpt_dir, "arena")
    os.makedirs(adir, exist_ok=True)
    date = datetime.date.today().isoformat()
    path = os.path.join(
        adir, f"gen_{int(generation):04d}_{date}_{tag}_{name}.ckpt")
    ckpt.save(path, {"params": params},
              metadata={"member": name, "generation": int(generation),
                        "tag": tag, "date": date})
    write_checksum(path)
    return path


class ModelRegistry:
    """Winner loading + between-steps hot-swap for a serving process.

    ``refresh()`` is the scheduler-facing poll: it returns True when a
    newer winner was loaded into ``self.params``.  With ``auto_export``
    the registry also exports winners for population steps the trainer
    has checkpointed since the last poll, so a server pointed at a live
    ``launch/ltfb.py`` checkpoint dir tracks the tournament frontier
    without any extra plumbing.
    """

    def __init__(self, ckpt_dir: str, like_params: Params,
                 metric_fn: Optional[Callable] = None,
                 val_batch: Optional[dict] = None,
                 auto_export: bool = False):
        self.ckpt_dir = ckpt_dir
        self.like_params = like_params
        self.metric_fn = metric_fn
        self.val_batch = val_batch
        self.auto_export = auto_export
        self.params: Optional[Params] = None
        self.step: int = -1
        self.info: dict = {}
        self.swaps: int = 0
        # transactional hot-swap state: corrupt winners are renamed to
        # *.corrupt (or, if the rename fails, remembered here) so the
        # poll never re-trips on the same bad file
        self.rejected_corrupt: int = 0
        self._quarantined: set = set()

    def _maybe_export(self) -> None:
        pop_step = ckpt.latest_population_step(self.ckpt_dir)
        if pop_step is None:
            return
        win_step = latest_winner_step(self.ckpt_dir)
        if win_step is None or pop_step > win_step:
            export_winner(self.ckpt_dir, self.like_params, step=pop_step,
                          metric_fn=self.metric_fn, val_batch=self.val_batch)
            # a fresh export supersedes any quarantine of that step —
            # self-healing: the corrupt file was renamed away, this one
            # was just written+checksummed from the population
            self._quarantined.discard(pop_step)

    def refresh(self) -> bool:
        """Load the newest winner if it is newer than what is serving.

        The ``--watch-every`` polling path: NEVER raises on a corrupt
        or torn winner file — the bad file is quarantined, the counter
        ``rejected_corrupt`` increments, and the previous winner keeps
        serving (the driver stays up).
        """
        if self.auto_export:
            self._maybe_export()
        step = latest_winner_step(self.ckpt_dir)
        if step is None or step <= self.step \
                or step in self._quarantined:
            return False
        return self.load_step(step, strict=False)

    def _quarantine(self, step: int, err: Exception) -> None:
        """Reject a corrupt winner: rename it (and its sidecar) to
        ``*.corrupt`` so ``latest_winner_step`` stops seeing it, fall
        back to an in-memory skip set when the rename fails."""
        self.rejected_corrupt += 1
        self._quarantined.add(step)
        path = winner_path(self.ckpt_dir, step)
        for p in (path, checksum_path(path)):
            try:
                if os.path.exists(p):
                    os.replace(p, p + ".corrupt")
            except OSError:
                pass
        print(f"[registry] REJECTED corrupt winner step {step}: "
              f"{type(err).__name__}: {err} — previous winner "
              f"(step {self.step}) keeps serving", flush=True)
        log_event("swap_rejected_corrupt", step=step,
                  serving_step=self.step, error=str(err))

    def load_step(self, step: int, strict: bool = True) -> bool:
        """Load a SPECIFIC exported winner (no newer-than scan).

        The mesh-follower path: host 0 polls the filesystem, decides,
        and broadcasts the winning step; followers load exactly that
        step so every host swaps to the same weights on the same
        scheduler step even if their filesystem views are racing the
        trainer's writes.  ``strict=True`` (followers, startup) raises
        on a corrupt file — host 0 verified the winner before
        broadcasting, so failure here must not silently diverge the
        mesh; ``strict=False`` (host-0 polling) quarantines instead
        and returns False, keeping the previous winner serving.
        """
        if step == self.step:
            return False
        path = winner_path(self.ckpt_dir, step)
        try:
            verify_checkpoint(path)
            tree, meta = ckpt.restore(path, {"params": self.like_params})
        except FileNotFoundError:
            if strict:
                raise
            return False        # raced a quarantine/cleanup: just skip
        except Exception as e:
            if strict:
                raise ValueError(
                    f"winner checkpoint {path!r} is corrupt or torn: "
                    f"{type(e).__name__}: {e}") from e
            self._quarantine(step, e)
            return False
        had = self.params is not None
        self.params = tree["params"]
        self.step = step
        self.info = meta
        if had:
            self.swaps += 1
        return True

    def load(self) -> Params:
        """Initial load (export first if allowed); raises if nothing to
        serve."""
        if not self.refresh() and self.params is None:
            raise FileNotFoundError(
                f"no winner or population checkpoint in {self.ckpt_dir!r}")
        return self.params
