"""Batched ICF-surrogate serving (the paper's actual end product).

The trained CycleGAN surrogate answers "what does the experiment
produce for inputs x?" queries — `x (5,) -> output bundle (15 scalars +
12 images)` via :func:`repro.models.icf_cyclegan.predict`.  Queries of
any size are micro-batched: the queue is drained up to ``max_batch``
rows per step and padded to a bucket so the jitted forward compiles for
a bounded set of shapes.  A :class:`repro.serve.registry.ModelRegistry`
can be attached for the same between-steps winner hot-swap the LM
scheduler does.

**Host/device overlap** — the same double-buffering the datastore's
:class:`repro.datastore.store.PrefetchLoader` applies to training
batches, in software-pipeline form: each ``step`` (1) dispatches the
device forward for the batch staged on the previous step (JAX dispatch
is async), (2) stages the NEXT micro-batch — drain, concatenate, pad —
while the device is busy, and only then (3) blocks on the in-flight
result and distributes it.  Host staging therefore costs zero
wall-clock whenever the device compute is longer, instead of
serializing with it as it did pre-paged-attention.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.icf_cyclegan import CycleGANConfig
from repro.models import icf_cyclegan as cg
from repro.serve.metrics import ServeStats
from repro.serve.telemetry import ServeTelemetry

# a staged micro-batch: (taken queue items, true rows, padded array)
_Staged = Tuple[List[Tuple[Any, np.ndarray, float]], int, np.ndarray]


class SurrogateEngine:
    """Micro-batching front end over the jitted surrogate forward."""

    def __init__(self, cfg: CycleGANConfig, params, max_batch: int = 64,
                 bucket: int = 8, registry=None, watch_every: int = 0,
                 telemetry: bool = True):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.bucket = bucket
        self.registry = registry
        self.watch_every = watch_every
        self._forward = jax.jit(lambda p, x: cg.predict(p["gen"], x))
        self.queue: deque[Tuple[Any, np.ndarray, float]] = deque()
        self.results: Dict[Any, np.ndarray] = {}
        self.stats = ServeStats(slots=max_batch)
        self.telemetry = ServeTelemetry(enabled=telemetry)
        self._step_count = 0
        # software pipeline state: the batch staged for the next
        # dispatch, and the batch whose device compute is in flight
        self._staged: Optional[_Staged] = None
        self._pending: Optional[Tuple[List, int, int, jax.Array]] = None
        self.overlapped_stages = 0

    def submit(self, rid: Any, x: np.ndarray) -> None:
        """x: (n, input_dim) float batch of experiment-parameter rows."""
        x = np.atleast_2d(np.asarray(x, np.float32))
        if x.shape[1] != self.cfg.input_dim:
            self.stats.rejected += 1
            raise ValueError(
                f"query {rid!r}: expected (n, {self.cfg.input_dim}), "
                f"got {x.shape}")
        self.stats.submitted += 1
        t0 = time.perf_counter()
        self.queue.append((rid, x, t0))
        self.telemetry.req_instant(rid, "enqueue", t=t0,
                                   rows=int(x.shape[0]))

    def _pad(self, n: int) -> int:
        b = self.bucket
        return ((n + b - 1) // b) * b

    def _stage(self) -> Optional[_Staged]:
        """Drain up to max_batch rows off the queue and assemble the
        padded host array (the host work the pipeline overlaps)."""
        taken, rows = [], 0
        while self.queue and rows + self.queue[0][1].shape[0] \
                <= self.max_batch:
            item = self.queue.popleft()
            taken.append(item)
            rows += item[1].shape[0]
        if not taken and self.queue:
            # head query alone exceeds max_batch: serve it as its own
            # (oversized) micro-batch rather than stalling the queue
            item = self.queue.popleft()
            taken.append(item)
            rows = item[1].shape[0]
        if not taken:
            return None
        x = np.concatenate([t[1] for t in taken])
        padded = self._pad(rows)
        if padded > rows:
            x = np.concatenate([x, np.zeros((padded - rows, x.shape[1]),
                                            np.float32)])
        return taken, rows, x

    def _dispatch(self, staged: _Staged) -> None:
        taken, rows, x = staged
        y = self._forward(self.params, jnp.asarray(x))   # async dispatch
        self._pending = (taken, rows, x.shape[0], y)

    def _collect(self) -> None:
        """Block on the in-flight forward and distribute its results."""
        taken, rows, padded, y = self._pending
        self._pending = None
        tc = time.perf_counter()
        y = np.asarray(y.astype(jnp.float32))
        now = time.perf_counter()
        self.telemetry.phase("surrogate_collect", tc, now, rows=rows)
        off = 0
        for rid, q, t0 in taken:
            n = q.shape[0]
            self.results[rid] = y[off:off + n]
            off += n
            self.stats.completed += 1
            self.stats.ttft.append(now - t0)
            self.stats.latency.append(now - t0)
            self.telemetry.terminal(rid, "finish", t=now,
                                    latency_s=now - t0, rows=n)
        self.stats.prefills += 1
        self.stats.prefill_tokens += rows       # true query rows
        self.stats.padded_prefill_tokens += padded
        self.stats.decode_steps += 1
        self.stats.decode_tokens += rows
        self.stats.decode_slot_steps += padded
        self.stats.sample_step(len(self.queue), rows)

    def step(self) -> None:
        """One pipeline step: dispatch the staged batch, stage the next
        one while the device computes, then collect."""
        self.stats.start()
        self._step_count += 1
        if (self.registry is not None and self.watch_every > 0
                and self._step_count % self.watch_every == 0
                and self.registry.refresh()):
            self.params = self.registry.params
            self.stats.hot_swaps += 1
        staged = self._staged if self._staged is not None else self._stage()
        self._staged = None
        if staged is not None:
            self._dispatch(staged)
        # overlap: assemble the NEXT micro-batch while the device is
        # busy with the one just dispatched
        self._staged = self._stage()
        if self._pending is not None:
            if self._staged is not None:
                self.overlapped_stages += 1
            self._collect()
        else:
            self.stats.sample_step(len(self.queue), 0)

    def run(self, max_steps: Optional[int] = None) -> Dict[Any, np.ndarray]:
        """Drain the query queue (optionally bounded); returns results
        keyed by query id."""
        steps = 0
        while self.queue or self._staged is not None \
                or self._pending is not None:
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        if self._pending is not None:    # flush the in-flight batch
            self._collect()
        self.stats.stop()
        return self.results
