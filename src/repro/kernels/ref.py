"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """q: (B, H, S, D); k/v: (B, Hkv, S, D). Dense softmax attention."""
    B, H, Sq, D = q.shape
    Hkv = k.shape[1]
    g = H // Hkv
    qg = q.reshape(B, Hkv, g, Sq, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf) / math.sqrt(D)
    if causal:
        Sk = k.shape[2]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", w, vf)
    return out.reshape(B, H, Sq, D).astype(q.dtype)


def paged_attention_ref(q: jax.Array, k_pages: jax.Array,
                        v_pages: jax.Array, tables: jax.Array,
                        lengths: jax.Array) -> jax.Array:
    """Gather-decode/verify oracle over a paged KV pool (the jnp twin
    the models use off-TPU).

    q: (B, H, D) single-token decode, or (B, K, H, D) for a K-token
    verify step (speculative decoding: the K queries of one row are
    consecutive positions of the same request); k_pages/v_pages:
    (P, bs, Hkv, D); tables: (B, W) int32 physical page ids; lengths:
    (B,) valid KV tokens for the FIRST query of each row — query t of a
    row sees ``lengths[b] + t`` tokens, the intra-block causal
    staircase.  Returns the same rank as ``q``.  Gathers each row's
    pages into logical order and runs masked attention; HBM traffic is
    O(B * W * bs) — the Pallas kernel performs the same gather
    page-by-page in VMEM.  The gather width W should be bucketed by the
    caller to the batch's true maximum page count (the scheduler
    additionally GROUPS rows by pow2 width so one long request does not
    widen every row's gather on CPU).
    """
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    B, K, H, D = q.shape
    _, bs, Hkv, _ = k_pages.shape
    W = tables.shape[1]
    g = H // Hkv
    kg = k_pages[tables].reshape(B, W * bs, Hkv, D).astype(jnp.float32)
    vg = v_pages[tables].reshape(B, W * bs, Hkv, D).astype(jnp.float32)
    qg = q.reshape(B, K, Hkv, g, D).astype(jnp.float32)
    s = jnp.einsum("bthgd,bkhd->bthgk", qg, kg) / math.sqrt(D)
    pos = jnp.arange(W * bs, dtype=jnp.int32)
    lens = lengths[:, None] + jnp.arange(K, dtype=jnp.int32)[None, :]
    valid = pos[None, None, :] < lens[..., None]         # (B, K, W*bs)
    s = jnp.where(valid[:, :, None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bthgk,bkhd->bthgd", w, vg)
    out = out.reshape(B, K, H, D).astype(q.dtype)
    return out[:, 0] if squeeze else out


def rmsnorm_ref(x: jax.Array, scale: jax.Array,
                eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def mamba_scan_ref(dt: jax.Array, xc: jax.Array, bm: jax.Array,
                   cm: jax.Array, a: jax.Array) -> jax.Array:
    """Sequential selective-scan oracle.

    dt/xc: (B,S,d); bm/cm: (B,S,N); a: (d,N) -> y: (B,S,d)."""
    B, S, d = dt.shape
    N = a.shape[1]

    def step(h, inputs):
        dt_t, xc_t, bm_t, cm_t = inputs              # (B,d),(B,d),(B,N),(B,N)
        da = jnp.exp(dt_t[..., None] * a)            # (B,d,N)
        dbx = (dt_t * xc_t)[..., None] * bm_t[:, None, :]
        h1 = da * h + dbx
        y = jnp.einsum("bdn,bn->bd", h1, cm_t)
        return h1, y

    h0 = jnp.zeros((B, d, N), jnp.float32)
    _, ys = jax.lax.scan(
        step, h0,
        (dt.swapaxes(0, 1), xc.swapaxes(0, 1),
         bm.swapaxes(0, 1), cm.swapaxes(0, 1)))
    return ys.swapaxes(0, 1)


def slstm_ref(gx: jax.Array, r_h: jax.Array, num_heads: int) -> jax.Array:
    """Sequential sLSTM oracle (stabilized exponential gating).

    gx: (B,S,4d) input gates [i|f|z|o]; r_h: (H, dh, 4dh) block-diagonal
    recurrent weights -> h: (B,S,d)."""
    B, S, d4 = gx.shape
    d = d4 // 4
    H = num_heads
    dh = d // H

    def step(state, g):
        h0, c0, n0, m0 = state
        rec = jnp.einsum("bhd,hde->bhe", h0.reshape(B, H, dh), r_h)
        rec = rec.reshape(B, H, 4, dh).transpose(0, 2, 1, 3) \
                 .reshape(B, 4 * d)
        gates = g + rec
        it, ft, zt, ot = jnp.split(gates, 4, axis=-1)
        lf = jax.nn.log_sigmoid(ft)
        m1 = jnp.maximum(lf + m0, it)
        ip = jnp.exp(it - m1)
        fp = jnp.exp(lf + m0 - m1)
        c1 = fp * c0 + ip * jnp.tanh(zt)
        n1 = jnp.maximum(fp * n0 + ip, 1e-6)
        h1 = jax.nn.sigmoid(ot) * c1 / n1
        return (h1, c1, n1, m1), h1

    z = jnp.zeros((B, d), jnp.float32)
    state0 = (z, z, z, jnp.full((B, d), -1e9, jnp.float32))
    _, hs = jax.lax.scan(step, state0, gx.swapaxes(0, 1))
    return hs.swapaxes(0, 1)
