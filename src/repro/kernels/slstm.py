"""Pallas TPU fused sLSTM recurrence kernel.

The sLSTM is sequential over time — at the HLO level every timestep
re-reads the recurrent weights and state from HBM, which makes the
xlstm-125m roofline 99.5% sLSTM traffic (EXPERIMENTS.md §Perf).  The
xLSTM authors solved this with a fused CUDA kernel; this is the TPU
analogue (DESIGN.md §2 hardware adaptation):

  * grid = (batch_blocks, seq_chunks); the sequence dimension iterates
    sequentially (TPU grids are lexicographic), so the (h, c, n, m)
    state lives in VMEM scratch ACROSS chunk steps;
  * the block-diagonal per-head recurrent weights r_h (H, dh, 4dh) are
    small (<1 MB) and stay VMEM-resident for the whole sweep;
  * HBM traffic collapses to one read of the precomputed input gates
    gx = x W_x + b and one write of the outputs — the kernel-credit the
    roofline applies for the deployed configuration.

Inputs:  gx (B, S, 4d) f32 with gate layout [i|f|z|o], r_h (H, dh, 4dh)
Outputs: h  (B, S, d) f32
Oracle:  repro.kernels.ref.slstm_ref (== models.xlstm scan path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None


def _kernel(gx_ref, r_ref, o_ref, h_ref, c_ref, n_ref, m_ref, *,
            chunk: int, num_heads: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e9)

    r = r_ref[...]                                 # (H, dh, 4dh)
    bb, d = h_ref.shape
    H = num_heads
    dh = d // H

    def step(t, _):
        h0 = h_ref[...]
        c0 = c_ref[...]
        n0 = n_ref[...]
        m0 = m_ref[...]
        rec = jax.lax.dot_general(
            h0.reshape(bb, H, dh), r,
            (((2,), (1,)), ((1,), (0,))),
            preferred_element_type=jnp.float32)    # (H, bb, 4dh)
        rec = rec.transpose(1, 0, 2).reshape(bb, H, 4, dh) \
                 .transpose(0, 2, 1, 3).reshape(bb, 4 * d)
        gates = gx_ref[:, t, :] + rec
        it = gates[:, 0 * d:1 * d]
        ft = gates[:, 1 * d:2 * d]
        zt = gates[:, 2 * d:3 * d]
        ot = gates[:, 3 * d:4 * d]
        lf = -jax.nn.softplus(-ft)                 # log sigmoid
        m1 = jnp.maximum(lf + m0, it)
        ip = jnp.exp(it - m1)
        fp = jnp.exp(lf + m0 - m1)
        c1 = fp * c0 + ip * jnp.tanh(zt)
        n1 = jnp.maximum(fp * n0 + ip, 1e-6)
        h1 = jax.nn.sigmoid(ot) * c1 / n1
        h_ref[...] = h1
        c_ref[...] = c1
        n_ref[...] = n1
        m_ref[...] = m1
        o_ref[:, t, :] = h1
        return ()

    jax.lax.fori_loop(0, chunk, step, ())


def slstm_scan(gx: jax.Array, r_h: jax.Array, block_b: int = 8,
               chunk: int = 128, interpret: bool = False) -> jax.Array:
    """gx: (B, S, 4d) f32; r_h: (H, dh, 4dh) f32 -> h: (B, S, d) f32."""
    B, S, d4 = gx.shape
    d = d4 // 4
    H = r_h.shape[0]
    block_b = min(block_b, B)
    while B % block_b:
        block_b -= 1
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    grid = (B // block_b, S // chunk)

    scratch = [_VMEM((block_b, d), jnp.float32) for _ in range(4)] \
        if _VMEM else []

    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, num_heads=H),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, chunk, d4), lambda i, j: (i, j, 0)),
            pl.BlockSpec((H, d // H, 4 * (d // H)), lambda i, j: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, chunk, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, d), jnp.float32),
        scratch_shapes=scratch,
        interpret=interpret,
    )(gx, r_h)
