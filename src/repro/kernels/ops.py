"""jit'd public wrappers for the Pallas kernels.

On TPU the kernels run compiled; everywhere else (this CPU container)
they run in ``interpret=True`` mode, which executes the kernel body in
Python per grid point — bit-comparable against the ``ref.py`` oracles.

``paged_attention`` additionally carries the SERVING-MESH dispatch:
when model code is traced under a sharding context whose mesh has a
``data`` axis of size > 1 (see :mod:`repro.serve.mesh`), the gather
runs inside a ``shard_map`` over the mesh — each data shard gathers
ONLY from its own slice of the page pool (block-table entries are
global page ids; the shard subtracts its pool offset), so a decode
step never moves KV pages across the ``data`` axis.  The dispatch
happens at trace time, outside any jit cache, so mesh and single-
device callers can never alias each other's lowering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import flash_attention as fa
from repro.kernels import paged_attention as pa
from repro.kernels import ref
from repro.kernels import rmsnorm as rn
from repro.parallel import sharding as _sharding


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = None):
    """q: (B, H, S, D); k/v: (B, Hkv, S, D) -> (B, H, S, D)."""
    if interpret is None:
        interpret = not _on_tpu()
    return fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                              block_k=block_k, interpret=interpret)


def _paged_attention_local(q, k_pages, v_pages, tables, lengths,
                           interpret=None):
    """Single-shard gather: Pallas kernel on TPU (or when interpret
    mode is explicitly requested), jnp oracle everywhere else —
    interpret mode executes the grid in Python and is far too slow for
    a decode loop."""
    if interpret is None:
        if not _on_tpu():
            return ref.paged_attention_ref(q, k_pages, v_pages, tables,
                                           lengths)
        interpret = False
    return pa.paged_attention(q, k_pages, v_pages, tables, lengths,
                              interpret=interpret)


def paged_attention_sharded(mesh, q, k_pages, v_pages, tables, lengths,
                            interpret: bool = None):
    """Gather-decode over a page pool sharded on the mesh ``data`` axis.

    The pool's page dim is split into ``data``-many private sub-pools
    (each with its own trailing null page); ``tables`` holds GLOBAL
    page ids, and every row's pages live in that row's shard — the
    invariant :class:`repro.serve.mesh.MeshPagedLayout` maintains.
    Inside the shard_map each shard rebases its table slice to local
    ids and runs the ordinary single-shard kernel/oracle, so no KV
    page ever crosses the ``data`` axis.  Heads additionally split
    over ``model`` when both q and kv head counts divide it (GQA
    grouping preserved); otherwise heads stay replicated.
    """
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    data = axes.get("data", 1)
    model = axes.get("model", 1)
    B = q.shape[0]
    H, Hkv = q.shape[-2], k_pages.shape[2]
    if B % data != 0 or k_pages.shape[0] % data != 0:
        raise ValueError(
            f"paged_attention_sharded: batch {B} and pool pages "
            f"{k_pages.shape[0]} must be divisible by the data axis "
            f"({data})")
    shard_heads = model > 1 and H % model == 0 and Hkv % model == 0
    mspec = "model" if shard_heads else None
    q_spec = P("data", None, mspec, None) if q.ndim == 4 \
        else P("data", mspec, None)
    kv_spec = P("data", None, mspec, None)
    pages_per_shard = k_pages.shape[0] // data

    def local(qs, ks, vs, ts, ls):
        shard = jax.lax.axis_index("data")
        local_t = jnp.clip(ts - shard * pages_per_shard, 0,
                           pages_per_shard - 1).astype(jnp.int32)
        return _paged_attention_local(qs, ks, vs, local_t, ls,
                                      interpret=interpret)

    return _sharding.shard_map_compat(
        local, mesh,
        in_specs=(q_spec, kv_spec, kv_spec, P("data", None), P("data")),
        out_specs=q_spec)(q, k_pages, v_pages, tables, lengths)


def paged_attention(q, k_pages, v_pages, tables, lengths,
                    interpret: bool = None):
    """Gather-decode/verify attention over scattered KV pages.

    q: (B, H, D), or (B, K, H, D) for a K-token speculative-verify
    step; k_pages/v_pages: (P, bs, Hkv, D); tables: (B, W); lengths:
    (B,) valid KV tokens for the FIRST query of each row (query t sees
    ``lengths + t``) -> same rank as q.

    Dispatch (decided at trace time — deliberately NOT a jit boundary,
    so a mesh trace can never reuse a single-device lowering):

    * a sharding context with a ``data`` axis of size > 1 active ->
      :func:`paged_attention_sharded` (shard_map; pages stay on-shard);
    * TPU -> the compiled Pallas kernel; explicit ``interpret=True``
      runs it in interpret mode (tests);
    * otherwise -> the jnp oracle ``ref.paged_attention_ref``.
    """
    mesh = _sharding.current_mesh()
    if mesh is not None and "data" in mesh.axis_names \
            and dict(zip(mesh.axis_names, mesh.devices.shape))["data"] > 1:
        return paged_attention_sharded(mesh, q, k_pages, v_pages, tables,
                                       lengths, interpret=interpret)
    return _paged_attention_local(q, k_pages, v_pages, tables, lengths,
                                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm(x, scale, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = None):
    if interpret is None:
        interpret = not _on_tpu()
    return rn.rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                      interpret=interpret)
