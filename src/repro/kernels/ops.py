"""jit'd public wrappers for the Pallas kernels.

On TPU the kernels run compiled; everywhere else (this CPU container)
they run in ``interpret=True`` mode, which executes the kernel body in
Python per grid point — bit-comparable against the ``ref.py`` oracles.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import flash_attention as fa
from repro.kernels import paged_attention as pa
from repro.kernels import ref
from repro.kernels import rmsnorm as rn


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = None):
    """q: (B, H, S, D); k/v: (B, Hkv, S, D) -> (B, H, S, D)."""
    if interpret is None:
        interpret = not _on_tpu()
    return fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                              block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, tables, lengths,
                    interpret: bool = None):
    """Gather-decode/verify attention over scattered KV pages.

    q: (B, H, D), or (B, K, H, D) for a K-token speculative-verify
    step; k_pages/v_pages: (P, bs, Hkv, D); tables: (B, W); lengths:
    (B,) valid KV tokens for the FIRST query of each row (query t sees
    ``lengths + t``) -> same rank as q.  Runs the Pallas kernel
    compiled on TPU and in interpret mode when explicitly requested
    (tests); the CPU serving path uses the jnp oracle directly —
    interpret mode executes the grid in Python and is far too slow for
    a decode loop.
    """
    if interpret is None:
        if not _on_tpu():
            return ref.paged_attention_ref(q, k_pages, v_pages, tables,
                                           lengths)
        interpret = False
    return pa.paged_attention(q, k_pages, v_pages, tables, lengths,
                              interpret=interpret)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm(x, scale, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = None):
    if interpret is None:
        interpret = not _on_tpu()
    return rn.rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                      interpret=interpret)
