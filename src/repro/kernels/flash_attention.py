"""Pallas TPU flash attention (causal, GQA) — the VMEM-resident kernel
whose pure-JAX twin is ``repro.models.layers.chunked_attention``.

TPU adaptation of the CUDA flash-attention idea (DESIGN.md §2/§6):
  * grid = (batch, q_heads, q_blocks, kv_blocks); the KV-block dimension
    is innermost so the (block_q, head_dim) accumulator lives in VMEM
    scratch across the KV sweep — HBM traffic is exactly Q, K, V reads +
    O writes (what the roofline credits as the kernel-deployed memory
    term);
  * block shapes are MXU-aligned (multiples of 128 on the matmul dims —
    block_q x head_dim tiles hit the 128x128 systolic array);
  * GQA is expressed in the K/V BlockSpec index_map (q head h reads kv
    head h // group), so no KV duplication is materialized;
  * causal masking skips fully-masked KV blocks via ``pl.when``.

Numerics: online softmax with running (m, l) in f32 scratch, inputs may
be bf16/f32; output is cast back to the query dtype.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific memory spaces; interpret mode works without them
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, block_q: int, block_k: int, causal: bool,
            num_k_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    if causal:
        # skip blocks strictly above the diagonal
        run = (ki * block_k) <= (qi * block_q + block_q - 1)
    else:
        run = ki >= 0

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)               # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            k_pos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, S, D); k/v: (B, Hkv, S, D) with H % Hkv == 0.

    Returns (B, H, S, D) attention output.
    """
    B, H, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert H % Hkv == 0
    g = H // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (
        f"seq lens ({Sq},{Sk}) must tile by ({block_q},{block_k})")
    nq, nk = Sq // block_q, Sk // block_k
    scale = 1.0 / math.sqrt(D)

    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, num_k_blocks=nk)

    scratch = [
        _VMEM((block_q, D), jnp.float32) if _VMEM else
        pl.MemorySpace.ANY,   # pragma: no cover (non-TPU build)
        _VMEM((block_q,), jnp.float32) if _VMEM else pl.MemorySpace.ANY,
        _VMEM((block_q,), jnp.float32) if _VMEM else pl.MemorySpace.ANY,
    ]

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, g=g: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, g=g: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)
