"""Pallas TPU paged-attention decode/verify kernel (gather over
scattered KV pages).

The serving pool stores each layer's KV cache as one
``(num_pages + 1, block_size, n_kv_heads, head_dim)`` array; a request's
tokens live in whatever pages its block table names, in logical order
but physically scattered (the last page, index ``num_pages``, is the
null page that inactive batch rows point at).  This kernel computes
decode attention for a batch of requests directly against that layout,
for ``K >= 1`` query tokens per row — K = 1 is the classic decode step,
K > 1 is the speculative-decoding verify step where the K queries of a
row are consecutive positions of the same request:

  * grid = (batch, kv_heads, table_width) with the page dimension
    innermost, so the (K * group, head_dim) accumulator lives in VMEM
    scratch across a request's page sweep;
  * the block table and per-row sequence lengths ride in as
    **scalar-prefetch** operands (``pltpu.PrefetchScalarGridSpec``): the
    K/V BlockSpec index_map reads ``tables[b, p]`` to DMA the right
    physical page HBM->VMEM — the gather never materializes a
    contiguous copy of the request's KV;
  * per-query causality: query t of row b attends over
    ``lengths[b] + t`` tokens (the intra-block staircase a K-token
    verify needs), expressed as a per-accumulator-row position bound;
  * pages wholly past every query's reach are skipped via ``pl.when``
    (their table entries are the null page), and the tail page is
    masked positionwise;
  * GQA is expressed by blocking q as (kv_heads, K * group) so q head
    ``h*g+j`` meets kv head ``h`` without duplication.

Numerics match ``repro.kernels.ref.paged_attention_ref``: online
softmax with running (m, l) in f32, output cast back to the query
dtype.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
    _GRIDSPEC = pltpu.PrefetchScalarGridSpec
except Exception:  # pragma: no cover (build without pallas.tpu)
    # no functional fallback: scalar-prefetch index maps ARE the gather;
    # callers without pallas.tpu must use kernels.ref.paged_attention_ref
    # (which ops.paged_attention selects automatically off-TPU)
    _VMEM = None
    _GRIDSPEC = None

NEG_INF = -1e30


def _kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale: float, block_size: int,
            group: int, k_tokens: int):
    b = pl.program_id(0)
    p = pl.program_id(2)
    num_p = pl.num_programs(2)
    length = lengths_ref[b]

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # pages wholly past the LAST query's reach hold either the null
    # page or stale state — skip the compute, keep the accumulator
    @pl.when(p * block_size < length + k_tokens - 1)
    def _compute():
        rows = q_ref.shape[2]                             # K * g
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (K*g, d)
        k = k_ref[0, :, 0].astype(jnp.float32)            # (bs, d)
        v = v_ref[0, :, 0].astype(jnp.float32)            # (bs, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        pos = p * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (rows, block_size), 1)
        # accumulator row r is query token r // group: it reaches
        # length + r // group tokens (intra-block causal staircase)
        reach = length + jax.lax.broadcasted_iota(
            jnp.int32, (rows, block_size), 0) // group
        s = jnp.where(pos < reach, s, NEG_INF)            # tail-page mask
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        w = jnp.exp(s - m_new[:, None])
        w = jnp.where(s <= NEG_INF / 2, 0.0, w)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(w, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            w, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(p == num_p - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    tables: jax.Array, lengths: jax.Array,
                    interpret: bool = False) -> jax.Array:
    """Decode/verify attention over a paged KV pool.

    q: (B, H, D) single-token queries, or (B, K, H, D) for K
    consecutive query tokens per row (speculative verify);
    k_pages/v_pages: (num_pages [+1], block_size, Hkv, D) physical
    pools; tables: (B, W) int32 physical page ids (logical page j of
    row b at ``tables[b,j]``, null-page entries past the used length);
    lengths: (B,) int32 valid KV tokens for the FIRST query of each row
    (query t sees ``lengths[b] + t``).  Returns the same rank as ``q``.
    """
    if _GRIDSPEC is None:  # pragma: no cover
        raise RuntimeError(
            "jax.experimental.pallas.tpu is unavailable in this build; "
            "use repro.kernels.ref.paged_attention_ref (ops."
            "paged_attention does this automatically off-TPU)")
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    B, K, H, D = q.shape
    _, bs, Hkv, _ = k_pages.shape
    W = tables.shape[1]
    assert H % Hkv == 0
    g = H // Hkv
    scale = 1.0 / math.sqrt(D)
    # fold the K query tokens into the accumulator rows: row t*g + j is
    # (query token t, grouped head j) of kv head h
    qg = q.reshape(B, K, Hkv, g, D).transpose(0, 2, 1, 3, 4) \
          .reshape(B, Hkv, K * g, D)

    kernel = functools.partial(_kernel, scale=scale, block_size=bs,
                               group=g, k_tokens=K)
    grid_spec = _GRIDSPEC(
        num_scalar_prefetch=2,
        grid=(B, Hkv, W),
        in_specs=[
            pl.BlockSpec((1, 1, K * g, D),
                         lambda b, h, p, tbl, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda b, h, p, tbl, ln: (tbl[b, p], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda b, h, p, tbl, ln: (tbl[b, p], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, K * g, D),
                               lambda b, h, p, tbl, ln: (b, h, 0, 0)),
        scratch_shapes=[
            _VMEM((K * g, D), jnp.float32),
            _VMEM((K * g,), jnp.float32),
            _VMEM((K * g,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, K * g, D), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32),
      qg, k_pages, v_pages)
    out = out.reshape(B, Hkv, K, g, D).transpose(0, 2, 1, 3, 4) \
             .reshape(B, K, H, D)
    return out[:, 0] if squeeze else out
