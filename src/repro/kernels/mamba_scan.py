"""Pallas TPU selective-scan (Mamba) kernel.

Jamba's remaining roofline memory term is the chunked selective scan:
at the HLO level each chunk materializes (B, Q, d_in, N) discretized-SSM
tensors in HBM.  The fused kernel keeps the (block_d, N) state and the
per-step (block_d, N) discretization products in VMEM — HBM traffic
collapses to reading (dt, xc) and writing y (+ the small B/C mats),
the same adaptation the CUDA selective-scan kernel makes on GPU
(DESIGN.md §2).

Grid: (batch, d_blocks, seq_chunks); the sequence dimension iterates
sequentially so the state scratch persists across chunks.

Inputs (all f32):
  dt  (B, S, d_in)  — post-softplus step sizes
  xc  (B, S, d_in)  — post-conv/silu activations
  Bm  (B, S, N)     — input projections
  Cm  (B, S, N)     — output projections
  A   (d_in, N)     — negative state matrix
Output: y (B, S, d_in) with y[t] = C[t] . h[t],
  h[t] = exp(dt[t] A) h[t-1] + (dt[t] xc[t]) B[t].
Oracle: repro.kernels.ref.mamba_scan_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None


def _kernel(dt_ref, xc_ref, bm_ref, cm_ref, a_ref, y_ref, h_ref, *,
            chunk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...]                                   # (bd, N)

    def step(t, _):
        h = h_ref[...]                               # (1, bd, N)
        dt = dt_ref[0, t, :]                         # (bd,)
        xc = xc_ref[0, t, :]
        bm = bm_ref[0, t, :]                         # (N,)
        cm = cm_ref[0, t, :]
        da = jnp.exp(dt[:, None] * a)                # (bd, N)
        dbx = (dt * xc)[:, None] * bm[None, :]       # (bd, N)
        h1 = da * h[0] + dbx
        h_ref[...] = h1[None]
        y_ref[0, t, :] = h1 @ cm                     # (bd,)
        return ()

    jax.lax.fori_loop(0, chunk, step, ())


def mamba_scan(dt: jax.Array, xc: jax.Array, bm: jax.Array, cm: jax.Array,
               a: jax.Array, block_d: int = 512, chunk: int = 128,
               interpret: bool = False) -> jax.Array:
    """Fused selective scan. Shapes per module docstring."""
    B, S, d_in = dt.shape
    N = a.shape[1]
    block_d = min(block_d, d_in)
    while d_in % block_d:
        block_d -= 1
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    grid = (B, d_in // block_d, S // chunk)

    scratch = [_VMEM((1, block_d, N), jnp.float32)] if _VMEM else []

    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, i, k: (b, k, i)),
            pl.BlockSpec((1, chunk, block_d), lambda b, i, k: (b, k, i)),
            pl.BlockSpec((1, chunk, N), lambda b, i, k: (b, k, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, i, k: (b, k, 0)),
            pl.BlockSpec((block_d, N), lambda b, i, k: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d),
                               lambda b, i, k: (b, k, i)),
        out_shape=jax.ShapeDtypeStruct((B, S, d_in), jnp.float32),
        scratch_shapes=scratch,
        interpret=interpret,
    )(dt, xc, bm, cm, a)
