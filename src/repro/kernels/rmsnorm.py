"""Pallas TPU fused RMSNorm kernel.

Row-tiled: grid over row blocks; each program normalizes a
(block_rows, d) tile in VMEM — one HBM read of x, one write of y, with
the f32 mean-square reduction and scale fused (XLA would otherwise emit
separate reduce + broadcast-multiply passes).  d is padded to the lane
width (128) by the caller contract; block_rows is sublane-aligned (8).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
            block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x: (..., d); scale: (d,). Fused RMSNorm over the last axis."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    while rows % block_rows:
        block_rows -= 1
    grid = (rows // block_rows,)

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(orig_shape)
