"""Train/serve step builders — the functions the launcher jits and the
dry-run lowers for every (arch x shape) cell.

``make_lm_train_step``   -> train_4k cells (loss + grads + optimizer)
``make_lm_prefill_step`` -> prefill_32k cells
``make_lm_decode_step``  -> decode_32k / long_500k cells
``make_gan_steps``       -> the paper's CycleGAN (generator+discriminator)
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax

from repro.configs.base import MeshConfig, ModelConfig, OptimizerConfig
from repro.configs.icf_cyclegan import CycleGANConfig
from repro.models import icf_cyclegan as cg
from repro.models import lm
from repro.optim import optimizers as opt_lib

Params = Any


# ---------------------------------------------------------------------------
# LM steps
# ---------------------------------------------------------------------------


def make_lm_train_step(cfg: ModelConfig, opt_cfg: OptimizerConfig,
                       mesh_cfg: Optional[MeshConfig] = None) -> Callable:
    mesh_cfg = mesh_cfg or MeshConfig()
    optimizer = opt_lib.make_optimizer(opt_cfg)

    def train_step(state: Dict[str, Any], batch: Dict[str, jax.Array]):
        params = state["params"]

        def loss_fn(p):
            return lm.lm_loss(p, cfg, batch, remat=mesh_cfg.remat)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        if opt_cfg.grad_clip_norm:
            grads, gnorm = opt_lib.clip_by_global_norm(
                grads, opt_cfg.grad_clip_norm)
            metrics = {**metrics, "grad_norm": gnorm}
        lr = opt_lib.lr_schedule(opt_cfg, state["opt_state"]["step"])
        new_params, new_opt = optimizer.update(grads, state["opt_state"],
                                               params, lr)
        new_state = {"params": new_params, "opt_state": new_opt}
        return new_state, {**metrics, "loss": loss, "lr": lr}

    return train_step


def make_lm_eval_metric(cfg: ModelConfig) -> Callable:
    """Tournament metric for LM archs: held-out CE (lower better)."""

    def metric(params, batch):
        loss, _ = lm.lm_loss(params, cfg, batch)
        return loss

    return metric


def make_lm_population_fns(cfg: ModelConfig, opt_cfg: OptimizerConfig,
                           mesh_cfg: Optional[MeshConfig] = None):
    """(init, train_step, metric) adapter so LM architectures plug into
    the LTFB population/tournament orchestrator exactly like the GAN.

    The LM step drives its own lr schedule from the optimizer step
    count; the hparams dict carries the base lr for PBT bookkeeping but
    perturbations do not rewire the compiled schedule.
    """
    step_fn = jax.jit(make_lm_train_step(cfg, opt_cfg, mesh_cfg))
    metric = jax.jit(make_lm_eval_metric(cfg))

    def init(seed: int):
        state, _ = init_lm_state(cfg, opt_cfg, jax.random.PRNGKey(seed))
        return state["params"], state["opt_state"], {"lr": opt_cfg.lr}

    def train_step(params, opt_state, batch, hparams):
        new_state, metrics = step_fn(
            {"params": params, "opt_state": opt_state}, batch)
        return new_state["params"], new_state["opt_state"], metrics

    return init, train_step, metric


def make_lm_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch):
        return lm.lm_prefill(params, cfg, batch)

    return prefill_step


def make_lm_decode_step(cfg: ModelConfig) -> Callable:
    def decode_step(params, tokens, cache, index):
        return lm.lm_decode(params, cfg, tokens, cache, index)

    return decode_step


def init_lm_state(cfg: ModelConfig, opt_cfg: OptimizerConfig,
                  key: jax.Array) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Returns (state, axes) where axes mirrors state for sharding."""
    params, p_axes = lm.init_lm(cfg, key)
    optimizer = opt_lib.make_optimizer(opt_cfg)
    opt_state = optimizer.init(params)
    o_axes = opt_state_axes(opt_cfg, p_axes)
    return ({"params": params, "opt_state": opt_state},
            {"params": p_axes, "opt_state": o_axes})


def opt_state_axes(opt_cfg: OptimizerConfig, p_axes):
    """Optimizer-state logical axes (ZeRO: moments inherit param axes)."""
    is_axes = lambda t: isinstance(t, tuple) and all(
        x is None or isinstance(x, str) for x in t)
    if opt_cfg.name in ("adam", "adamw"):
        return {"m": p_axes, "v": p_axes, "step": ()}
    if opt_cfg.name == "adafactor":
        vr = jax.tree.map(lambda a: a[:-1] if len(a) >= 2 else a,
                          p_axes, is_leaf=is_axes)
        vc = jax.tree.map(lambda a: a[:-2] + a[-1:] if len(a) >= 2
                          else (None,), p_axes, is_leaf=is_axes)
        return {"vr": vr, "vc": vc, "step": ()}
    if opt_cfg.name == "sgd":
        return {"mom": p_axes, "step": ()}
    raise ValueError(opt_cfg.name)


# ---------------------------------------------------------------------------
# CycleGAN steps (the paper's model)
# ---------------------------------------------------------------------------


def make_gan_steps(ccfg: CycleGANConfig, opt_cfg: OptimizerConfig):
    """Returns (init, train_step, metric) suitable for
    repro.core.population.TrainerFns.  One train_step = one discriminator
    update + one generator update (standard simultaneous GAN schedule).
    """
    optimizer = opt_lib.make_optimizer(opt_cfg)

    def init(seed: int):
        params, _ = cg.init_cyclegan(ccfg, jax.random.PRNGKey(seed))
        opt_state = {"gen": optimizer.init(params["gen"]),
                     "disc": optimizer.init(params["disc"])}
        return params, opt_state, {"lr": opt_cfg.lr}

    @jax.jit
    def train_step(params, opt_state, batch, hparams):
        lr = hparams["lr"]
        # --- discriminator ---
        (d_loss, d_metrics), d_grads = jax.value_and_grad(
            cg.discriminator_loss, has_aux=True)(
                params["disc"], params["gen"], ccfg, batch)
        new_disc, new_dopt = optimizer.update(
            d_grads, opt_state["disc"], params["disc"], lr)
        # --- generator ---
        (g_loss, g_metrics), g_grads = jax.value_and_grad(
            cg.generator_loss, has_aux=True)(
                params["gen"], new_disc, ccfg, batch)
        new_gen, new_gopt = optimizer.update(
            g_grads, opt_state["gen"], params["gen"], lr)
        params = {"gen": new_gen, "disc": new_disc}
        opt_state = {"gen": new_gopt, "disc": new_dopt}
        metrics = {"g_loss": g_loss, "d_loss": d_loss,
                   **d_metrics, **g_metrics}
        return params, opt_state, metrics

    @jax.jit
    def metric(params, batch):
        return cg.validation_metric(params, ccfg, batch)

    return init, train_step, metric


def make_gan_disc_metric(ccfg: CycleGANConfig):
    """The paper's GAN tournament metric (Fig. 6b): score a (possibly
    foreign) generator against the LOCAL discriminator."""

    @jax.jit
    def metric(params, batch):
        return cg.discriminator_metric(params, ccfg, batch)

    return metric
