"""Training-side telemetry for the LTFB tournament (paper §IV measurements).

The paper's headline results are *measurements* — 70.2x speedup, 109%
parallel efficiency, exchange-byte accounting — so the training stack
gets the same first-class observability PR 7 gave serving, speaking the
same dialect (one trace viewer, one log pipeline, one Prometheus
scraper for a train→serve→train deployment):

* :class:`TrainTelemetry` — per-trainer step-time attribution.  Every
  trainer gets its own Chrome-trace row (``trainer N``); the population
  loop emits ``data_wait`` / ``step`` / ``train_round`` spans, the
  tournament emits ``tournament_eval`` / ``partner_exchange`` spans
  (also from executor threads — emission is locked), and the
  orchestrator emits round/checkpoint spans on the orchestrator row.
  Export with :func:`repro.telemetry.write_trace` (``--trace-out``).
* :class:`GenealogyLog` / :func:`replay_genealogy` — the tournament
  genealogy: one JSONL record per match / round / rescale / failure /
  recovery / checkpoint / arena promotion, flushed per record, with
  torn-tail-tolerant replay (same discipline as ``serve/journal.py``)
  so a champion's full descent is reconstructable from artifacts
  (``python -m repro.launch.lineage``).
* :func:`train_prometheus` / :func:`write_prom` /
  :class:`MetricsServer` — Prometheus text exposition (``repro_train_``
  prefix) of rounds, steps/s, per-trainer loss/metric gauges, exchange
  bytes + effective exchange bandwidth, datastore ingestion counters,
  checkpoint/restore durations and the live efficiency figures; written
  to ``--prom-out`` each round or served from a stdlib HTTP endpoint
  (``--metrics-port``) for long runs.
* :func:`efficiency_snapshot` / :func:`step_flops` — the paper's
  speedup/efficiency computed online from instrumented timings, in both
  samples/s and model-FLOP/s terms (per-compiled-step FLOPs via the
  ``parallel/hlo_analysis`` cost-analysis shim).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Dict, List, Optional

from repro.telemetry import (
    SCHED_TID,
    Tracer,
    log_event,
    prom_counter,
    prom_gauge,
    prom_labeled,
)

__all__ = [
    "TrainTelemetry",
    "GenealogyLog",
    "replay_genealogy",
    "train_prometheus",
    "write_prom",
    "MetricsServer",
    "efficiency_snapshot",
    "step_flops",
]


class TrainTelemetry:
    """Per-trainer tracing + phase attribution for the LTFB loop.

    Wraps a :class:`repro.telemetry.Tracer` whose per-entity rows are
    keyed by trainer index (``trainer 0``, ``trainer 1``, …; the
    orchestrator row is tid 0).  Tournament-eval spans are emitted from
    the async-eval executor's threads, so every tracer mutation is
    guarded by one lock.  ``phase_seconds`` accumulates wall time per
    phase (``data_wait`` / ``compute`` / ``tournament_eval`` /
    ``partner_exchange`` / ``checkpoint`` / ``restore``) for the
    Prometheus export.
    """

    def __init__(self, enabled: bool = True, trace_capacity: int = 8192):
        self.enabled = bool(enabled)
        self.tracer = Tracer(trace_capacity, row_name="orchestrator",
                             row_prefix="trainer")
        self.phase_seconds: Dict[str, float] = {}
        self.phase_calls: Dict[str, int] = {}
        self._lock = threading.Lock()

    def add_phase(self, name: str, seconds: float) -> None:
        """Accumulate phase wall time without emitting a span."""
        with self._lock:
            self.phase_seconds[name] = \
                self.phase_seconds.get(name, 0.0) + max(0.0, seconds)
            self.phase_calls[name] = self.phase_calls.get(name, 0) + 1

    def trainer_span(self, name: str, trainer: int, t0: float, t1: float,
                     phase: Optional[str] = None, **args: Any) -> None:
        """Emit a complete span on a trainer's trace row (thread-safe).

        ``phase`` additionally accumulates the duration into
        :attr:`phase_seconds` under that name.
        """
        if phase is not None:
            self.add_phase(phase, t1 - t0)
        if not self.enabled:
            return
        with self._lock:
            self.tracer.req_span(name, trainer, t0, t1, **args)

    def span(self, name: str, t0: float, t1: float,
             phase: Optional[str] = None, **args: Any) -> None:
        """Emit a complete span on the orchestrator row (thread-safe)."""
        if phase is not None:
            self.add_phase(phase, t1 - t0)
        if not self.enabled:
            return
        with self._lock:
            self.tracer.complete(name, SCHED_TID, t0, t1, **args)

    def event(self, name: str, **args: Any) -> None:
        """Emit an instant event on the orchestrator row (rescale,
        failure, recovery, resume, …)."""
        if not self.enabled:
            return
        with self._lock:
            self.tracer.instant(name, SCHED_TID, **args)


# ---- tournament genealogy -------------------------------------------------


class GenealogyLog:
    """Append-only JSONL genealogy of an LTFB population.

    One record per event, ``{"t": <kind>, ...}`` exactly like the
    serving journal's dialect: ``init``, ``match`` (one per pairwise
    comparison: round, trainer, partner, both metric values, winner,
    whether the model was adopted, the pairing seed), ``round`` (per
    round: best metric, timings, efficiency), ``rescale`` / ``fail`` /
    ``recover`` (ancestry-relevant topology changes), ``checkpoint`` /
    ``resume``, and ``promotion`` (an online-arena champion change —
    the arena appends to the SAME file, so training rounds and arena
    generations form one chain).  Records are flushed per append and
    fsynced on :meth:`sync`/:meth:`close`; a torn final line is
    tolerated on replay.
    """

    def __init__(self, path: str):
        self.path = str(path)
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._f = open(self.path, "a")
        self.records_written = 0

    def append(self, t: str, **fields: Any) -> None:
        """Append one ``{"t": t, **fields}`` record (flushed, not yet
        fsynced — call :meth:`sync` at durability points)."""
        rec = {"t": t}
        rec.update(fields)
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()
        self.records_written += 1

    def sync(self) -> None:
        """fsync the log (ordered before checkpoint/promotion effects)."""
        self._f.flush()
        os.fsync(self._f.fileno())

    def close(self) -> None:
        """Sync and close (idempotent)."""
        if not self._f.closed:
            self.sync()
            self._f.close()


def replay_genealogy(path: str) -> List[dict]:
    """Read a genealogy JSONL, tolerating a torn final line.

    Same discipline as ``serve/journal.py``: replay stops at the first
    undecodable record (the writer died mid-line), so a crashed run's
    log is still usable up to its last durable record.
    """
    try:
        raw = open(path, "rb").read()
    except FileNotFoundError:
        return []
    records: List[dict] = []
    for line in raw.split(b"\n"):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except ValueError:
            break                       # torn tail — stop replay here
        records.append(rec)
    return records


# ---- live parallel-efficiency accounting ----------------------------------


def step_flops(train_step, *example_args) -> Optional[float]:
    """Per-compiled-step FLOPs via the XLA cost-analysis shim.

    ``train_step`` must be a jitted callable; ``example_args`` are one
    step's concrete arguments.  Returns None when the backend does not
    expose cost analysis (the efficiency figures then stay in
    samples/s only).
    """
    try:
        from repro.parallel.hlo_analysis import xla_cost_analysis
        compiled = train_step.lower(*example_args).compile()
        flops = xla_cost_analysis(compiled).get("flops")
        return float(flops) if flops else None
    except Exception:
        return None


def efficiency_snapshot(per_trainer: List[Dict[str, float]],
                        batch_size: int, tournament_seconds: float,
                        round_wall_seconds: float,
                        flops_per_step: Optional[float] = None
                        ) -> Dict[str, Any]:
    """The paper's speedup/efficiency figures from one round's timings.

    ``per_trainer`` holds per-trainer deltas for the round: ``steps``,
    ``train_seconds`` (wall inside the train loop) and
    ``data_wait_seconds``.  The single-trainer-equivalent baseline is
    the mean per-trainer training rate (samples per train-loop second);
    the parallel rate divides aggregate samples by the *parallel* round
    time — the slowest trainer plus the tournament — because on real
    hardware trainers run concurrently on their own mesh slices while
    this container time-shares them (``round_wall_seconds`` reports the
    measured serialized wall for reference).  ``speedup`` is the
    parallel rate over the single-trainer rate; ``efficiency`` divides
    by the trainer count (>1.0 = superlinear, the paper's cache
    effect).  With ``flops_per_step`` the same figures are restated in
    model-FLOP/s.
    """
    active = [d for d in per_trainer if d.get("steps", 0) > 0]
    k = len(active)
    out: Dict[str, Any] = {
        "trainers": k,
        "tournament_seconds": tournament_seconds,
        "round_wall_seconds": round_wall_seconds,
        "data_wait_seconds": sum(d.get("data_wait_seconds", 0.0)
                                 for d in active),
    }
    if not active:
        return out
    samples = sum(d["steps"] * batch_size for d in active)
    rates = [d["steps"] * batch_size / d["train_seconds"]
             for d in active if d.get("train_seconds", 0.0) > 0]
    slowest = max(d.get("train_seconds", 0.0) for d in active)
    parallel_seconds = slowest + max(0.0, tournament_seconds)
    out["samples"] = samples
    if not rates or parallel_seconds <= 0:
        return out
    single_rate = sum(rates) / len(rates)
    parallel_rate = samples / parallel_seconds
    out["single_trainer_samples_per_s"] = single_rate
    out["parallel_samples_per_s"] = parallel_rate
    out["speedup"] = parallel_rate / single_rate if single_rate else 0.0
    out["efficiency"] = out["speedup"] / k
    if flops_per_step:
        steps = sum(d["steps"] for d in active)
        out["flops_per_step"] = flops_per_step
        out["model_flops_per_s"] = flops_per_step * steps / parallel_seconds
    return out


# ---- prometheus exposition ------------------------------------------------

_PREFIX = "repro_train_"

# StoreStats counters exported per trainer and in total (keys match
# repro.datastore.store.StoreStats.as_dict)
_STORE_COUNTERS = (
    ("samples_fetched", "samples fetched from the datastore"),
    ("file_opens", "bundle file opens"),
    ("bytes_read", "bytes read from bundle files"),
    ("exchange_bytes", "datastore owner->consumer exchange bytes"),
    ("cache_hits", "datastore cache hits"),
    ("cache_misses", "datastore cache misses"),
)


def train_prometheus(stats: Dict[str, Any],
                     phase_seconds: Optional[Dict[str, float]] = None
                     ) -> str:
    """Render :meth:`TournamentOrchestrator.stats` as Prometheus text.

    Same exposition dialect as ``serve/telemetry.py`` (format 0.0.4,
    ``repro_train_`` prefix): round/step/sample counters, per-trainer
    ``{trainer=...}`` gauges for the last train-step metrics and
    tournament metric, wins/adoptions, partition sizes, datastore
    ingestion counters, model-exchange bytes + effective exchange
    bandwidth, checkpoint/restore durations, phase attribution and the
    live speedup/efficiency figures.
    """
    out: List[str] = []
    per = stats.get("per_trainer", [])
    total = stats.get("total", {})
    prom_counter(out, f"{_PREFIX}rounds_total", "tournament rounds",
                 int(stats.get("round", 0)))
    prom_counter(out, f"{_PREFIX}steps_total", "train steps (all trainers)",
                 int(sum(d.get("steps", 0) for d in per)))
    prom_counter(out, f"{_PREFIX}tournament_exchange_bytes_total",
                 "model bytes exchanged by tournaments",
                 int(stats.get("tournament_exchange_bytes", 0)))
    for key, help_ in (
            ("train_seconds", "wall seconds inside the train loop"),
            ("data_wait_seconds", "wall seconds waiting on batches"),
            ("tournament_seconds", "wall seconds running tournaments"),
            ("checkpoint_seconds", "wall seconds saving checkpoints"),
            ("restore_seconds", "wall seconds restoring checkpoints"),
            ("prefetch_wait_seconds",
             "wall seconds the train loop blocked on the prefetch queue"),
    ):
        v = stats.get(key)
        if v is None:
            v = sum(d.get(key, 0.0) for d in per)
        prom_counter(out, f"{_PREFIX}{key}_total", help_, float(v))
    for key, help_ in (
            ("rescales", "elastic rescale events"),
            ("failures", "trainer failure events"),
            ("recoveries", "trainer recovery events"),
            ("checkpoints", "population checkpoints saved"),
            ("restores", "population checkpoints restored"),
    ):
        prom_counter(out, f"{_PREFIX}{key}_total", help_,
                     int(stats.get("events", {}).get(key, 0)))
    for key, help_ in _STORE_COUNTERS:
        prom_counter(out, f"{_PREFIX}datastore_{key}_total", help_,
                     int(total.get(key, 0)))
        prom_labeled(
            out, f"{_PREFIX}trainer_{key}_total", "counter",
            f"{help_} (per trainer)",
            [({"trainer": i}, int(d.get(key, 0)))
             for i, d in enumerate(per)])

    def per_gauge(key: str, help_: str, cast=float) -> None:
        prom_labeled(out, f"{_PREFIX}trainer_{key}", "gauge", help_,
                     [({"trainer": i}, cast(d.get(key, 0)))
                      for i, d in enumerate(per)])

    per_gauge("wins", "pairwise tournament wins", int)
    per_gauge("adoptions", "partner models adopted", int)
    per_gauge("steps", "train steps taken", int)
    per_gauge("alive", "trainer liveness", bool)
    per_gauge("files", "manifest files in the trainer's partition", int)
    per_gauge("partition_samples", "samples in the trainer's partition",
              int)
    prom_labeled(
        out, f"{_PREFIX}trainer_tournament_metric", "gauge",
        "last tournament metric on local held-out data (lower is better)",
        [({"trainer": i}, float(d["tournament_metric"]))
         for i, d in enumerate(per)
         if d.get("tournament_metric") is not None])
    metric_samples = []
    for i, d in enumerate(per):
        for name, v in sorted(d.get("train_metrics", {}).items()):
            metric_samples.append(({"trainer": i, "metric": name},
                                   float(v)))
    prom_labeled(out, f"{_PREFIX}trainer_loss", "gauge",
                 "last train-step metrics", metric_samples)

    exch = int(stats.get("tournament_exchange_bytes", 0))
    tourn_s = float(stats.get("tournament_seconds", 0.0))
    prom_gauge(out, f"{_PREFIX}exchange_bandwidth_bytes_per_s",
               "effective model-exchange bandwidth "
               "(tournament bytes / tournament seconds)",
               exch / tourn_s if tourn_s > 0 else 0.0)
    eff = stats.get("efficiency") or {}
    for key, help_ in (
            ("single_trainer_samples_per_s",
             "single-trainer-equivalent training rate"),
            ("parallel_samples_per_s", "aggregate parallel training rate"),
            ("speedup", "parallel speedup over one trainer (paper fig11)"),
            ("efficiency", "parallel efficiency = speedup / trainers"),
            ("flops_per_step", "XLA-estimated FLOPs per compiled step"),
            ("model_flops_per_s", "aggregate model FLOP/s"),
    ):
        v = eff.get(key)
        if v is not None:
            prom_gauge(out, f"{_PREFIX}{key}", help_, float(v))
    if phase_seconds:
        prom_labeled(out, f"{_PREFIX}phase_seconds_total", "counter",
                     "cumulative wall seconds per phase",
                     [({"phase": ph}, float(phase_seconds[ph]))
                      for ph in sorted(phase_seconds)])
    return "\n".join(out) + "\n"


def write_prom(text: str, path: str) -> None:
    """Atomically write a Prometheus exposition snapshot (tmp+rename,
    so a scraper reading mid-round never sees a half-written file)."""
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)


class MetricsServer:
    """Tiny stdlib HTTP endpoint serving the latest Prometheus snapshot.

    ``GET /metrics`` (any path, really) returns the text last passed to
    :meth:`update` — enough for a Prometheus scraper against a long
    training run without pulling in any web framework.
    """

    def __init__(self, port: int = 0):
        import http.server

        server = self

        class Handler(http.server.BaseHTTPRequestHandler):
            """Serves the owning MetricsServer's latest snapshot."""

            def do_GET(self):  # noqa: N802 (stdlib handler API)
                """Return the latest exposition text."""
                body = server.text.encode()
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):
                """Silence per-request stderr logging."""

        self.text = ""
        self._httpd = http.server.ThreadingHTTPServer(
            ("127.0.0.1", int(port)), Handler)
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        daemon=True)
        self._thread.start()
        log_event("metrics_server_started", port=self.port)

    def update(self, text: str) -> None:
        """Swap in a fresh exposition snapshot."""
        self.text = text

    def close(self) -> None:
        """Stop serving and join the thread."""
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)
