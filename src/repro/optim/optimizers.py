"""Optimizers (pure-JAX, no external deps): Adam/AdamW, Adafactor, SGD.

The paper trains with Adam, lr=1e-3 (Section IV).  Adafactor (factored
second moment) is provided for the 398B-parameter configs where full
Adam moments would not fit HBM; ``moment_dtype`` halves optimizer memory
when set to bfloat16.  Optimizer state mirrors the parameter sharding
(ZeRO: FSDP-sharded params imply FSDP-sharded moments).

All updates use flatten/unflatten (not multi-output tree_map) because
model param trees contain tuple internal nodes (scan period stacks).
"""
from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig

Params = Any


class Optimizer(NamedTuple):
    init: Callable[[Params], Any]
    update: Callable[..., Tuple[Params, Any]]   # (grads, state, params, lr)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """warmup + {constant|cosine|linear} decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / max(1, cfg.warmup_steps))
    if cfg.schedule == "cosine":
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(1, cfg.total_steps - cfg.warmup_steps), 0, 1)
        decay = 0.5 * (1 + jnp.cos(math.pi * frac))
    elif cfg.schedule == "linear":
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(1, cfg.total_steps - cfg.warmup_steps), 0, 1)
        decay = 1.0 - frac
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def _map_zip(fn, *trees):
    """Like tree_map over N trees returning a TUPLE of result trees
    (safe for trees whose internal nodes are tuples/dicts)."""
    flat, treedef = jax.tree.flatten(trees[0])
    others = [treedef.flatten_up_to(t) for t in trees[1:]]
    results = [fn(*leaves) for leaves in zip(flat, *others)]
    n_out = len(results[0])
    return tuple(jax.tree.unflatten(treedef, [r[i] for r in results])
                 for i in range(n_out))


# ---------------------------------------------------------------------------
# Adam / AdamW
# ---------------------------------------------------------------------------


def make_adam(cfg: OptimizerConfig) -> Optimizer:
    mdt = jnp.dtype(cfg.moment_dtype)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, mdt)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr=None):
        lr_ = cfg.lr if lr is None else lr
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        b1, b2 = cfg.b1, cfg.b2
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            mh = m32 / bc1
            vh = v32 / bc2
            delta = lr_ * mh / (jnp.sqrt(vh) + cfg.eps)
            if cfg.weight_decay:
                delta = delta + lr_ * cfg.weight_decay \
                    * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - delta).astype(p.dtype),
                    m32.astype(mdt), v32.astype(mdt))

        new_p, new_m, new_v = _map_zip(upd, grads, state["m"], state["v"],
                                       params)
        return new_p, {"m": new_m, "v": new_v, "step": step}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; for the 398B configs)
# ---------------------------------------------------------------------------


def make_adafactor(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        def leaf(p):
            if p.ndim >= 2:
                return (jnp.zeros(p.shape[:-1], jnp.float32),
                        jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32))
            return (jnp.zeros(p.shape, jnp.float32),
                    jnp.zeros((1,), jnp.float32))   # unused pad slot
        vr, vc = _map_zip(leaf, params)
        return {"vr": vr, "vc": vc, "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr=None):
        lr_ = cfg.lr if lr is None else lr
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-0.8)           # Adafactor decay schedule
        eps = 1e-30

        def upd(g, vr, vc, p):
            g32 = g.astype(jnp.float32)
            if p.ndim >= 2:
                nvr = beta * vr + (1 - beta) * jnp.mean(g32 * g32, axis=-1)
                nvc = beta * vc + (1 - beta) * jnp.mean(g32 * g32, axis=-2)
                denom = jnp.maximum(
                    jnp.mean(nvr, axis=-1, keepdims=True), eps)
                v = (nvr[..., None] * nvc[..., None, :]) / denom[..., None]
            else:
                nvr = beta * vr + (1 - beta) * g32 * g32
                nvc = vc
                v = nvr
            u = g32 / jnp.sqrt(v + 1e-12)
            rms = jnp.sqrt(jnp.mean(u ** 2) + 1e-12)   # update clipping d=1
            u = u / jnp.maximum(1.0, rms)
            return ((p.astype(jnp.float32) - lr_ * u).astype(p.dtype),
                    nvr, nvc)

        new_p, new_vr, new_vc = _map_zip(upd, grads, state["vr"],
                                         state["vc"], params)
        return new_p, {"vr": new_vr, "vc": new_vc, "step": step}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# SGD (+momentum)
# ---------------------------------------------------------------------------


def make_sgd(cfg: OptimizerConfig, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {"mom": jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr=None):
        lr_ = cfg.lr if lr is None else lr

        def upd(g, m, p):
            m32 = momentum * m + g.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr_ * m32).astype(p.dtype),
                    m32)

        new_p, new_m = _map_zip(upd, grads, state["mom"], params)
        return new_p, {"mom": new_m, "step": state["step"] + 1}

    return Optimizer(init, update)


def make_optimizer(cfg: OptimizerConfig) -> Optimizer:
    if cfg.name in ("adam", "adamw"):
        return make_adam(cfg)
    if cfg.name == "adafactor":
        return make_adafactor(cfg)
    if cfg.name == "sgd":
        return make_sgd(cfg)
    raise ValueError(cfg.name)
