"""Int8 error-feedback gradient compression for the cross-pod (DCN) axis.

Beyond-paper distributed-optimization trick (DESIGN.md §2): within a pod,
gradients reduce over fast ICI; *across* pods, bandwidth is the scarce
resource, so the cross-pod reduction exchanges int8-quantized gradients
via ``lax.ppermute`` (1 byte/element on the wire instead of 2–4) and
accumulates the quantization error into an error-feedback buffer that is
re-injected the next step — preserving convergence (error-feedback SGD).

For a 2-pod mesh a single ppermute IS the all-reduce; for P pods a
recursive-doubling ladder of log2(P) ppermutes is generated (with
re-quantization at each rung, absorbed by the same feedback buffer).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Params = Any


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum_pod(grads: Params, error: Params, axis: str = "pod",
                        num_pods: int = 2) -> Tuple[Params, Params]:
    """Cross-pod gradient mean with int8 wire format + error feedback.

    MUST run inside shard_map with `axis` in scope.  Returns
    (mean_grads, new_error).  Wire volume: 1 byte/element/rung vs 4
    (f32 all-reduce) or 2 (bf16).
    """
    steps = max(1, num_pods.bit_length() - 1)

    def one(g, e):
        acc = g.astype(jnp.float32) + e
        total = acc
        err = jnp.zeros_like(acc)
        for r in range(steps):
            q, s = quantize_int8(total)
            err = err + (total - dequantize_int8(q, s))
            perm = [(i, i ^ (1 << r)) for i in range(num_pods)]
            q_o = jax.lax.ppermute(q, axis, perm)
            s_o = jax.lax.ppermute(s, axis, perm)
            total = dequantize_int8(q, s) + dequantize_int8(q_o, s_o)
        return total / num_pods, err

    out = jax.tree.map(one, grads, error)
    mean = jax.tree.map(lambda o: o[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda o: o[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return mean, new_err


def compression_ratio(dtype=jnp.float32) -> float:
    return jnp.dtype(dtype).itemsize / jnp.dtype(jnp.int8).itemsize
