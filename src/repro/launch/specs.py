"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell.

No device allocation happens here — array stand-ins come from
``jax.ShapeDtypeStruct`` / ``jax.eval_shape``; the logical-axes trees
(pure Python) are captured by closure while tracing the init functions,
so the FULL 398B configs cost nothing to "initialize".
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, OptimizerConfig, ShapeConfig
from repro.models import lm
from repro.train import steps as steps_lib

SDS = jax.ShapeDtypeStruct


def _shapes_and_aux(fn):
    """eval_shape a function returning (arrays, python_aux)."""
    captured = {}

    def wrapper(*args):
        arrays, aux = fn(*args)
        captured["aux"] = aux
        return arrays

    shapes = jax.eval_shape(wrapper)
    return shapes, captured["aux"]


def param_specs(cfg: ModelConfig):
    """(param ShapeDtypeStructs, logical axes) — no allocation."""
    return _shapes_and_aux(
        lambda: lm.init_lm(cfg, jax.random.PRNGKey(0)))


def state_specs(cfg: ModelConfig, opt_cfg: OptimizerConfig):
    """Train state (params + opt state) specs and axes."""
    p_shapes, p_axes = param_specs(cfg)
    optimizer_init = steps_lib.opt_lib.make_optimizer(opt_cfg).init
    o_shapes = jax.eval_shape(optimizer_init, p_shapes)
    o_axes = steps_lib.opt_state_axes(opt_cfg, p_axes)
    return ({"params": p_shapes, "opt_state": o_shapes},
            {"params": p_axes, "opt_state": o_axes})


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    return _shapes_and_aux(lambda: lm.init_cache(cfg, batch, max_len))


def serve_cache_specs(cfg: ModelConfig, num_slots: int, num_pages: int,
                      block_size: int = 16):
    """PAGED serving-cache specs (ShapeDtypeStructs + logical axes).

    The stand-in for the live serving mesh's cache pytree: attention
    layers get ``(num_pages + 1, block_size, Hkv, D)`` pools (axes
    include ``"pages"``, which the serve rules shard over ``data``),
    recurrent layers per-slot state rows (``"batch"`` over ``data``).
    Lets capacity studies resolve the mesh placement of any
    (arch x pool) cell without allocating a byte — the same axes the
    runtime (:mod:`repro.serve.mesh`) places the real pools with.
    """
    return _shapes_and_aux(
        lambda: lm.init_cache(cfg, num_slots,
                              pages=(num_pages, block_size)))


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        return {
            "embeds": SDS((B, S, cfg.d_model), jnp.bfloat16),
            "positions": SDS((3, B, S), jnp.int32),
            "labels": SDS((B, S), jnp.int32),
        }
    return {"tokens": SDS((B, S), jnp.int32),
            "labels": SDS((B, S), jnp.int32)}


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig
                        ) -> Dict[str, Any]:
    specs = train_input_specs(cfg, shape)
    specs.pop("labels")
    return specs


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """(tokens, cache, index) specs + cache axes; cache len = seq_len."""
    B, S = shape.global_batch, shape.seq_len
    cache_sh, cache_ax = cache_specs(cfg, B, S)
    return SDS((B, 1), jnp.int32), cache_sh, cache_ax, SDS((), jnp.int32)


# logical axes for input batches
TRAIN_BATCH_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "embeds": ("batch", "seq", "act_embed"),
    "positions": (None, "batch", "seq"),
}


def batch_axes(specs: Dict[str, Any]) -> Dict[str, Tuple]:
    return {k: TRAIN_BATCH_AXES[k] for k in specs}
