"""Serving launcher: continuous-batching inference over tournament winners.

Serves the models ``launch/ltfb.py`` trains.  Two workloads behind one
CLI:

  * **lm** (any registered LM arch) — a mixed-length synthetic request
    trace through the continuous-batching scheduler
    (:mod:`repro.serve.scheduler`): token-budget admission, slot-based
    prefill/decode interleave, per-request completion.
  * **surrogate** (``--arch icf-cyclegan``) — batched ICF-surrogate
    queries through :mod:`repro.serve.surrogate`.

With ``--ckpt-dir`` pointing at an LTFB population checkpoint the
launcher serves the tournament winner (exporting ``winner_step_<n>.ckpt``
if needed) and, with ``--watch-every N``, hot-swaps newer winners
between scheduler steps — serving follows training live.

With ``--gateway`` the synthetic trace is replaced by the HTTP front
door (:mod:`repro.serve.gateway`): requests arrive over ``POST
/v1/generate``, admission is bounded by ``--max-queue`` (429 on
overload), and tokens stream back as NDJSON chunks.

Fault tolerance: ``--journal`` appends every admitted request and
every decoded token to a write-ahead journal
(:mod:`repro.serve.journal`); after a crash (or a SIGTERM-driven
rolling restart of the gateway) the next generation passes
``--resume-journal`` and resumes every unfinished request
**token-identically**.  ``--fault-spec`` arms the deterministic
fault-injection harness (:mod:`repro.serve.faults`) for crash drills.

  python -m repro.launch.serve --arch qwen3-0.6b --smoke --requests 8
  python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --ckpt-dir /tmp/pop --watch-every 4
  python -m repro.launch.serve --arch icf-cyclegan --smoke --queries 32
  python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --gateway --port 8000 --max-queue 64
"""
from __future__ import annotations

import argparse
import json
import signal
import sys
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.configs.registry import ARCHS, get_config
from repro.data.tokens import token_stream
from repro.serve import telemetry as telemetry_mod
from repro.serve.registry import ModelRegistry
from repro.serve.scheduler import Request, Scheduler


def parse_lens(spec: str) -> List[int]:
    """Parse a comma-separated prompt-length list ("8,16,24")."""
    return [int(x) for x in spec.split(",") if x]


def build_requests(cfg, requests: int, prompt_lens: List[int],
                   max_new: int, eos_id: Optional[int] = None,
                   temperature: float = 0.0, seed: int = 0
                   ) -> List[Request]:
    """Deterministic mixed-length trace: prompt lengths cycle through
    `prompt_lens`, token ids from the synthetic stream."""
    lens = list(prompt_lens)
    stream = token_stream(sum(lens[i % len(lens)] for i in
                              range(requests)) + requests,
                          cfg.vocab_size, seed=seed)
    reqs, off = [], 0
    for i in range(requests):
        n = lens[i % len(lens)]
        reqs.append(Request(
            rid=i, prompt=np.asarray(stream[off:off + n], np.int32),
            max_new=max_new, eos_id=eos_id, temperature=temperature,
            seed=None if temperature <= 0 else seed + i))
        off += n
    return reqs


def make_registry(args, like_params, metric_fn=None,
                  val_batch=None) -> Optional[ModelRegistry]:
    if not args.ckpt_dir:
        return None
    return ModelRegistry(args.ckpt_dir, like_params, metric_fn=metric_fn,
                         val_batch=val_batch, auto_export=True)


def make_arena(args, cfg, like, rank: int = 0):
    """Build the online-LTFB arena from the CLI flags (None when
    ``--arena`` was not given).  With ``--resume-journal`` the arena
    state (champion, windows, generation) is restored from the journal
    BEFORE the scheduler is built, so the resumed process serves the
    journaled champion from its first step."""
    if not getattr(args, "arena", None):
        return None
    from repro.serve.arena import Arena, ArenaConfig
    acfg = ArenaConfig(policy=args.arena_policy,
                       window=args.arena_window,
                       min_samples=args.arena_min_samples,
                       margin=args.arena_margin,
                       hysteresis=args.arena_hysteresis,
                       check_every=args.arena_check_every,
                       seq_len=args.arena_seq)
    arena = Arena.from_population(
        args.arena, like, acfg,
        writeback_dir=getattr(args, "arena_writeback", None),
        vocab=cfg.vocab_size, rank=rank)
    if getattr(args, "resume_journal", None):
        from repro.serve import journal as journal_mod
        state = journal_mod.replay_arena(args.resume_journal)
        if state:
            arena.restore(state)
            print(f"[serve] arena: restored from journal — champion="
                  f"{arena.champion} generation={arena.generation} "
                  f"promotions={arena.promotions}")
    print(f"[serve] arena: {args.arena} policy={acfg.policy} "
          f"members={len(arena.members)} champion={arena.champion} "
          f"drafter={arena.active_drafter} window={acfg.window} "
          f"margin={acfg.margin} min_samples={acfg.min_samples} "
          f"hysteresis={acfg.hysteresis} "
          f"writeback={getattr(args, 'arena_writeback', None)}")
    return arena


def run_lm(args) -> Dict[str, object]:
    from repro.models.lm import init_lm
    from repro.serve.registry import check_draft_compat, load_draft

    cfg = get_config(args.arch, smoke=args.smoke)
    like, _ = init_lm(cfg, jax.random.PRNGKey(args.seed))
    params = like
    if args.arena and (args.ckpt_dir or args.draft_ckpt):
        raise SystemExit(
            "--arena replaces both --ckpt-dir (promotions ARE the hot "
            "swap) and --draft-ckpt (challengers ARE the drafters); "
            "drop those flags")
    arena = make_arena(args, cfg, like)
    # the arena replaces the registry: promotions drive the hot swap
    registry = make_registry(args, like) if arena is None else None
    if registry is not None:
        params = registry.load()
        print(f"[serve] winner: step={registry.step} "
              f"trainer={registry.info.get('trainer')} "
              f"wins={registry.info.get('wins')}")
    draft_params, draft_cfg = None, None
    if args.draft_ckpt:
        draft_like = like
        if args.draft_arch and args.draft_arch != args.arch:
            # a SMALLER draft arch: its own config + param template,
            # tokenizer-compat asserted before any restore is attempted
            draft_cfg = get_config(args.draft_arch, smoke=args.smoke)
            check_draft_compat(cfg, draft_cfg)
            draft_like, _ = init_lm(draft_cfg,
                                    jax.random.PRNGKey(args.seed))
        draft_params, dinfo = load_draft(args.draft_ckpt, draft_like,
                                         step=args.draft_step,
                                         expect_vocab=cfg.vocab_size)
        print(f"[serve] drafter: {args.draft_ckpt} "
              f"arch={(draft_cfg or cfg).name} "
              f"step={dinfo.get('step')} trainer={dinfo.get('trainer')} "
              f"spec_tokens={args.spec_tokens} "
              f"fused={not args.no_spec_fused} adapt={args.spec_adapt}")
    if arena is not None:
        params = arena.champion_params
        draft_params = arena.drafter_params
    journal = None
    if getattr(args, "journal", None):
        from repro.serve.journal import RequestJournal
        journal = RequestJournal(args.journal)
        print(f"[serve] journal: {args.journal} (write-ahead, fsync "
              f"per step)")
    faults = None
    if getattr(args, "fault_spec", None):
        from repro.serve.faults import FaultInjector
        faults = FaultInjector(args.fault_spec)
        print(f"[serve] fault harness armed: {args.fault_spec}")
    max_len = args.max_len or max(
        parse_lens(args.prompt_lens)) + args.max_new
    sched_kw = dict(
        num_slots=args.slots, max_len=max_len,
        block_size=args.block_size, num_blocks=args.num_blocks,
        max_seq=args.max_seq, layout=args.layout,
        policy=args.policy, prefill_chunk=args.prefill_chunk,
        prefix_sharing=not args.no_prefix_sharing,
        pin_prefix=args.pin_prefix,
        max_prefills_per_step=args.prefill_per_step,
        registry=registry, watch_every=args.watch_every,
        swap_mode=args.swap_mode,
        draft_params=draft_params, spec_tokens=args.spec_tokens,
        draft_cfg=draft_cfg, spec_fused=not args.no_spec_fused,
        spec_adapt=args.spec_adapt,
        max_queue=getattr(args, "max_queue", None),
        journal=journal, faults=faults, arena=arena,
        telemetry=not args.no_telemetry)
    if args.mesh:
        from repro.serve.mesh import MeshScheduler, parse_mesh
        data, model = parse_mesh(args.mesh)
        sched = MeshScheduler(cfg, params, mesh_shape=(data, model),
                              **sched_kw)
        print(f"[serve] mesh: data={data} model={model} "
              f"devices={data * model} slots={sched.pool.num_slots} "
              f"(host-0 scheduler, per-shard page pools)")
    else:
        sched = Scheduler(cfg, params, **sched_kw)
    if args.profile_steps > 0:
        sched.profile_steps(args.profile_steps, args.profile_dir)
        print(f"[serve] profiler armed: steps={args.profile_steps} "
              f"dir={args.profile_dir}")
    prefixes: Dict = {}
    resumed: set = set()
    journal_entries = None
    if getattr(args, "resume_journal", None):
        from repro.serve import journal as journal_mod
        journal_entries = journal_mod.replay(args.resume_journal)
        prefixes = journal_mod.resume_scheduler(sched, journal_entries)
        resumed = set(journal_entries)
        print(f"[serve] journal: replayed {len(journal_entries)} "
              f"request(s) from {args.resume_journal} "
              f"(requeued {sched.stats.journal_replayed} unfinished)")
    if getattr(args, "gateway", False):
        out = run_gateway(args, sched, journal_entries=journal_entries)
        _maybe_write_trace(args, sched)
        if arena is not None:
            arena.report()
            arena.close()
            out["arena"] = arena.snapshot()
        if journal is not None:
            journal.close()
        return out
    reqs = build_requests(cfg, args.requests, parse_lens(args.prompt_lens),
                          args.max_new, eos_id=args.eos_id,
                          temperature=args.temperature, seed=args.seed)
    print(f"[serve] arch={cfg.name} workload=lm layout={args.layout} "
          f"policy={args.policy} slots={args.slots} max_len={max_len} "
          f"max_seq={sched.max_seq} block_size={args.block_size} "
          f"prefill_chunk={args.prefill_chunk} "
          f"swap_mode={args.swap_mode} requests={len(reqs)} "
          f"max_new={args.max_new} spec_tokens={sched.spec_tokens}")
    for r in reqs:
        if r.rid in resumed:        # the journal already owns this rid
            continue
        try:
            sched.submit(r)
        except ValueError as e:     # counted in the rejected stat
            print(f"[serve] rejected request {r.rid}: {e}")
    results = sched.run()
    if prefixes:
        from repro.serve import journal as journal_mod
        results = journal_mod.stitched_results(results, prefixes)
    sched.stats.report()
    pd = sched.pool.as_dict()
    print(f"[serve] pool: slots={pd['num_slots']} "
          f"blocks_used_high_water={pd['high_water_blocks']}/"
          f"{pd['num_blocks']} block_allocs={pd['block_allocs']} "
          f"block_frees={pd['block_frees']}")
    if args.layout == "paged":
        print(f"[serve] prefix-cache: hits={pd['prefix_hits']} "
              f"shared_tokens={pd['prefix_shared_tokens']} "
              f"pinned={pd['pinned_blocks']} "
              f"prefill_chunks={sched.stats.prefill_chunks}")
    if args.spec_adapt and sched.spec_k_by_rid:
        ks = sched.spec_k_by_rid
        print(f"[serve] spec-adapt per-row K (final): "
              f"{ {r: ks[r] for r in sorted(ks, key=str)} } "
              f"k_mean={sched.stats.as_dict()['spec_k_mean']:.2f}")
    if registry is not None:
        print(f"[serve] registry: serving_step={registry.step} "
              f"hot_swaps={sched.stats.hot_swaps}")
    if arena is not None:
        arena.report()
        arena.close()
    sample = results.get(reqs[0].rid)
    if sample is None and results:
        sample = next(iter(results.values()))
    if sample is not None:
        print("[serve] sample continuation (token ids):",
              list(map(int, sample[:12])))
    _maybe_write_trace(args, sched)
    if journal is not None:
        journal.close()
    out = {"stats": sched.stats.as_dict(), "pool": pd,
           "registry_step": registry.step if registry else None,
           "results": results}
    if arena is not None:
        out["arena"] = arena.snapshot()
    _maybe_write_json(args, out)
    return out


def _maybe_write_json(args, out: Dict[str, object]) -> None:
    """Write the stats + full per-request token streams as JSON if
    ``--out-json`` was given (the crash-recovery CI lane diffs these
    files across an interrupted-then-resumed pair of runs)."""
    if not getattr(args, "out_json", None):
        return
    payload = {"stats": out["stats"],
               "results": {str(k): [int(t) for t in v]
                           for k, v in out.get("results", {}).items()}}
    if out.get("arena") is not None:
        payload["arena"] = out["arena"]
    with open(args.out_json, "w") as f:
        json.dump(payload, f)
    print(f"[serve] wrote {args.out_json}")


def _maybe_write_trace(args, sched) -> None:
    """Export the Chrome-trace ring buffer if --trace-out was given."""
    if not getattr(args, "trace_out", None):
        return
    telemetry_mod.write_trace(sched.telemetry.tracer, args.trace_out)
    tr = sched.telemetry.tracer
    print(f"[serve] trace: {args.trace_out} events={len(tr.events)} "
          f"dropped={tr.dropped} (chrome://tracing / ui.perfetto.dev)")


def run_gateway(args, sched, journal_entries=None) -> Dict[str, object]:
    """Serve HTTP on ``--host:--port`` until interrupted.

    Ctrl-C prints the ``[serve]`` report and exits cleanly.  SIGTERM
    triggers the graceful rolling-restart path: stop admission
    (:meth:`Gateway.begin_drain`), let in-flight work finish for up to
    ``--drain-grace`` seconds (a ``--journal`` makes the queue durable
    so the wait can be short), then exit 0 — the next generation
    resumes with ``--resume-journal``."""
    import asyncio

    from repro.serve.gateway import Gateway

    gw = Gateway(sched, host=args.host, port=args.port,
                 stream_buffer=args.stream_buffer)
    if journal_entries:
        from repro.serve.journal import idempotency_map
        gw.seed_idempotency(idempotency_map(journal_entries))

    async def _serve():
        await gw.start()
        print(f"[serve] gateway: http://{gw.host}:{gw.port} "
              f"max_queue={sched.max_queue} "
              f"stream_buffer={gw.stream_buffer} "
              f"(POST /v1/generate, GET /healthz, GET /readyz, "
              f"GET /metrics, GET /population, POST /arena/promote, "
              f"GET /debug/trace, POST /debug/profile)")
        loop = asyncio.get_running_loop()
        stop = asyncio.Event()

        def _on_sigterm():
            print(f"[serve] SIGTERM: draining "
                  f"(grace={args.drain_grace:.1f}s, journal="
                  f"{'on' if sched.journal is not None else 'off'})",
                  flush=True)
            gw.begin_drain()
            stop.set()

        try:
            loop.add_signal_handler(signal.SIGTERM, _on_sigterm)
        except (NotImplementedError, ValueError, RuntimeError):
            pass                      # non-main thread / exotic loop
        await stop.wait()
        # with a journal the queue is already durable; either way give
        # in-flight requests up to --drain-grace to finish streaming
        deadline = loop.time() + args.drain_grace
        while not gw.drained() and loop.time() < deadline:
            await asyncio.sleep(0.05)
        if sched.journal is not None:
            sched.journal.record_note("shutdown", drained=gw.drained())
        await gw.stop()

    try:
        asyncio.run(_serve())
    except KeyboardInterrupt:
        pass
    sched.stats.report()
    return {"stats": sched.stats.as_dict()}


def run_surrogate(args) -> Dict[str, object]:
    from repro.configs.icf_cyclegan import FULL, SMOKE
    from repro.data import jag
    from repro.models.icf_cyclegan import init_cyclegan
    from repro.serve.surrogate import SurrogateEngine

    ccfg = SMOKE if args.smoke else FULL
    params, _ = init_cyclegan(ccfg, jax.random.PRNGKey(args.seed))
    registry = make_registry(args, params)
    if registry is not None:
        params = registry.load()
        print(f"[serve] winner: step={registry.step} "
              f"trainer={registry.info.get('trainer')} "
              f"wins={registry.info.get('wins')}")
    eng = SurrogateEngine(ccfg, params, max_batch=args.slots * 16,
                          bucket=8, registry=registry,
                          watch_every=args.watch_every,
                          telemetry=not args.no_telemetry)
    print(f"[serve] arch={ccfg.name} workload=surrogate "
          f"queries={args.queries} query_batch={args.query_batch} "
          f"max_batch={eng.max_batch}")
    xs = jag.sample_inputs(args.queries * args.query_batch, args.seed)
    for i in range(args.queries):
        eng.submit(i, xs[i * args.query_batch:(i + 1) * args.query_batch])
    results = eng.run()
    eng.stats.report()
    if registry is not None:
        print(f"[serve] registry: serving_step={registry.step} "
              f"hot_swaps={eng.stats.hot_swaps}")
    _maybe_write_trace(args, eng)
    return {"stats": eng.stats.as_dict(),
            "registry_step": registry.step if registry else None,
            "results": results}


def build_parser() -> argparse.ArgumentParser:
    """The serve CLI's argument parser (separate from :func:`main` so
    ``docs/flags.md`` can be checked against it)."""
    ap = argparse.ArgumentParser(
        prog="repro.launch.serve",
        description="Continuous-batching inference over tournament "
                    "winners")
    ap.add_argument("--arch", default="qwen3-0.6b", choices=sorted(ARCHS))
    ap.add_argument("--workload", default=None,
                    choices=("lm", "surrogate"),
                    help="default: surrogate for icf-cyclegan, else lm")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="LTFB population checkpoint dir to serve the "
                         "tournament winner from")
    ap.add_argument("--watch-every", type=int, default=0,
                    help="poll for newer winners every N steps (0 = off)")
    ap.add_argument("--mesh", default=None,
                    help="serve over a device mesh: 'DATA,MODEL' (e.g. "
                         "'4,2') or 'data=4,model=2' — weights "
                         "tensor-parallel over `model`, decode batch + "
                         "every cache leaf (incl. per-shard page pools) "
                         "over `data`, admission decided on host 0 and "
                         "broadcast (lm workload)")
    # scheduler
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=0,
                    help="default per-request cap + pool sizing unit "
                         "(0 = fit the trace)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV page size in tokens")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="page-pool size (default: slots*max_len worth)")
    ap.add_argument("--max-seq", type=int, default=None,
                    help="per-request length cap (paged layout; default "
                         "max_len — raise it to admit requests longer "
                         "than the old dense per-slot ceiling)")
    ap.add_argument("--layout", default="paged",
                    choices=("paged", "dense"),
                    help="paged: scattered KV pages + gather-decode "
                         "kernel; dense: PR-2 slot rows (baseline)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="prefill long prompts in N-token chunks "
                         "interleaved with decode (0 = one-shot; "
                         "attention-only families)")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable copy-on-admit prompt prefix sharing")
    ap.add_argument("--pin-prefix", action="store_true",
                    help="keep registered prompt-prefix pages resident "
                         "across idle periods (eviction-priority tier; "
                         "reclaimed oldest-first under pool pressure)")
    # speculative decoding (population drafter)
    ap.add_argument("--draft-ckpt", default=None,
                    help="drafter checkpoint for speculative decoding: "
                         "a .ckpt file, or a population dir (earliest "
                         "step's winner by default) — the LTFB "
                         "population is a free source of draft models")
    ap.add_argument("--draft-step", type=int, default=None,
                    help="population step to draft from (with a dir "
                         "--draft-ckpt; default: earliest)")
    ap.add_argument("--draft-arch", default=None, choices=sorted(ARCHS),
                    help="the drafter's arch when it differs from the "
                         "target (a smaller model; must share the "
                         "target's vocab/tokenizer — checked at load)")
    ap.add_argument("--spec-tokens", type=int, default=0,
                    help="draft tokens proposed per speculative round "
                         "(0 = off); the target verifies K+1 tokens in "
                         "one multi-token step — output is token-"
                         "identical to target-only decoding")
    ap.add_argument("--no-spec-fused", action="store_true",
                    help="disable the fused draft step (K proposals in "
                         "ONE dispatch via on-device greedy feed + host "
                         "resample; off = K+1 sequential draft "
                         "dispatches per round)")
    ap.add_argument("--spec-adapt", action="store_true",
                    help="adapt the speculative depth PER ROW from its "
                         "accept-rate history (within [1, spec-tokens]); "
                         "per-row K reported in the [serve] metrics")
    # online LTFB arena (serve/arena.py: live-traffic tournament)
    ap.add_argument("--arena", default=None,
                    help="serve an N-member population roster from this "
                         "LTFB checkpoint dir as an ONLINE tournament: "
                         "the champion serves, challengers draft "
                         "speculatively, accept rate scores matches, "
                         "and winners are hot-swapped in (replaces "
                         "--ckpt-dir and --draft-ckpt; lm workload)")
    ap.add_argument("--arena-policy", default="champion",
                    choices=("champion", "epsilon", "shadow"),
                    help="challenger routing: champion = best "
                         "challenger drafts (exploit); epsilon = mostly "
                         "best, periodically round-robin (explore/"
                         "exploit); shadow = round-robin every stint "
                         "(even sampling)")
    ap.add_argument("--arena-window", type=int, default=128,
                    help="sliding accept-rate window per member, in "
                         "speculative row-rounds (the match metric)")
    ap.add_argument("--arena-margin", type=float, default=0.02,
                    help="a challenger must beat the champion's "
                         "promotion-time accept rate by this margin to "
                         "win a match")
    ap.add_argument("--arena-min-samples", type=int, default=32,
                    help="proposals a challenger's window must hold "
                         "before it can qualify for promotion")
    ap.add_argument("--arena-hysteresis", type=int, default=2,
                    help="consecutive winning match evaluations before "
                         "a promotion fires")
    ap.add_argument("--arena-check-every", type=int, default=8,
                    help="scheduler steps between match evaluations")
    ap.add_argument("--arena-writeback", default=None,
                    help="write finished request/response streams back "
                         "as datastore token shards in this dir — the "
                         "next launch/ltfb.py round ingests production "
                         "traffic (train->serve->train)")
    ap.add_argument("--arena-seq", type=int, default=64,
                    help="write-back row width minus one: rows are "
                         "(seq+1) tokens, matching launch/ltfb.py "
                         "--seq so shards re-ingest directly")
    ap.add_argument("--swap-mode", default="immediate",
                    choices=("immediate", "drain"),
                    help="hot-swap policy: immediate applies new "
                         "weights to in-flight requests; drain lets "
                         "them finish on the old weights first")
    ap.add_argument("--policy", default="continuous",
                    choices=("continuous", "static"))
    ap.add_argument("--prefill-per-step", type=int, default=1)
    # lm trace
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-lens", default="8,16,24",
                    help="comma list; requests cycle through these")
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--temperature", type=float, default=0.0)
    # surrogate trace
    ap.add_argument("--queries", type=int, default=16)
    ap.add_argument("--query-batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    # gateway (HTTP front door)
    ap.add_argument("--gateway", action="store_true",
                    help="serve HTTP (POST /v1/generate, GET /healthz, "
                         "GET /metrics) instead of the synthetic trace "
                         "(lm workload)")
    ap.add_argument("--host", default="127.0.0.1",
                    help="gateway bind address")
    ap.add_argument("--port", type=int, default=8000,
                    help="gateway bind port (0 = ephemeral)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bound the request queue; submits beyond it "
                         "are shed with HTTP 429 (default: unbounded)")
    ap.add_argument("--stream-buffer", type=int, default=64,
                    help="per-response token buffer; a consumer that "
                         "falls further behind is cancelled "
                         "(backpressure)")
    # fault tolerance (journal / crash recovery / fault injection)
    ap.add_argument("--journal", default=None,
                    help="write-ahead request journal (JSONL): every "
                         "admitted request and decoded token, fsync'd "
                         "per scheduler step — a crashed or restarted "
                         "server resumes from it token-identically "
                         "(lm workload)")
    ap.add_argument("--resume-journal", default=None,
                    help="replay a previous generation's --journal on "
                         "startup: finished requests return their "
                         "recorded tokens, unfinished ones are "
                         "requeued and resume token-identically")
    ap.add_argument("--fault-spec", default=None,
                    help="deterministic fault injection: comma list of "
                         "kind@step[:key=val...], kinds kill|crash|"
                         "stall|corrupt|oom|disconnect (e.g. "
                         "'kill@12,stall@4:secs=0.2') — crash drills "
                         "for the journal/recovery path")
    ap.add_argument("--out-json", default=None,
                    help="write final stats + per-request token "
                         "streams as JSON (the crash-recovery CI lane "
                         "diffs interrupted-vs-uninterrupted runs)")
    ap.add_argument("--drain-grace", type=float, default=5.0,
                    help="seconds SIGTERM waits for in-flight gateway "
                         "requests to finish before exiting (admission "
                         "stops immediately; the journal preserves "
                         "whatever does not finish)")
    # telemetry (tracing / metrics / profiler)
    ap.add_argument("--no-telemetry", action="store_true",
                    help="disable per-request trace spans and phase "
                         "spans (counters, histograms and the profiler "
                         "window stay on)")
    ap.add_argument("--trace-out", default=None,
                    help="write the per-request trace ring buffer as "
                         "Chrome-trace JSON on exit (open in "
                         "chrome://tracing or ui.perfetto.dev)")
    ap.add_argument("--profile-steps", type=int, default=0,
                    help="wrap jax.profiler.trace around the first N "
                         "scheduler steps (0 = off; lm workload)")
    ap.add_argument("--profile-dir", default="/tmp/repro_profile",
                    help="output dir for --profile-steps / POST "
                         "/debug/profile traces (TensorBoard-loadable)")
    ap.add_argument("--log-json", action="store_true",
                    help="emit [serve] reports and lifecycle events "
                         "(shed/cancel/hot-swap/profile) as one-line "
                         "JSON records on stdout")
    return ap


def main(argv=None) -> int:
    """CLI entry point: parse args, pick the workload, run it."""
    args = build_parser().parse_args(argv)

    if args.log_json:
        telemetry_mod.enable_json_logs()
    if (args.draft_ckpt or args.arena) and args.spec_tokens <= 0:
        args.spec_tokens = 4            # a drafter implies speculation
    workload = args.workload or \
        ("surrogate" if args.arch == "icf-cyclegan" else "lm")
    if workload == "surrogate":
        run_surrogate(args)
    else:
        if args.arch == "icf-cyclegan":
            raise SystemExit("lm workload needs an LM arch")
        run_lm(args)
    return 0


if __name__ == "__main__":
    sys.exit(main())
