"""Device meshes for production and LTFB runs.

Functions, not module-level constants — importing this module never
touches jax device state (required so smoke tests see 1 CPU device).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256-chip pod, or 2x16x16 = 512-chip two-pod mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    assert len(devices) == n, (
        f"need {n} devices, have {len(devices)} — the dry-run entrypoint "
        "must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
        "before importing jax")
    import numpy as np
    return Mesh(np.asarray(devices).reshape(shape), axes)


def make_ltfb_mesh(trainers: int, per_trainer_model: int = 16) -> Mesh:
    """LTFB population mesh: ('trainer', 'model').

    The paper's production point is 64 trainers x 16 GPUs; on a 512-chip
    2-pod system the analogue is 32 trainers x 16-way model/data
    parallelism per trainer.
    """
    n = trainers * per_trainer_model
    devices = jax.devices()[:n]
    assert len(devices) == n, f"need {n} devices, have {len(devices)}"
    import numpy as np
    return Mesh(np.asarray(devices).reshape(trainers, per_trainer_model),
                ("trainer", "model"))


def make_host_mesh(axes=("data",)) -> Mesh:
    """All visible devices on one axis (tests / small runs)."""
    import numpy as np
    devs = np.asarray(jax.devices())
    return Mesh(devs.reshape((len(devs),) + (1,) * (len(axes) - 1)), axes)
