"""Tournament-lineage report over a population genealogy log.

Reconstructs a champion's full ancestry from the ``genealogy.jsonl``
that LTFB training (``repro.launch.ltfb --ckpt-dir`` / ``--genealogy``)
and the serving arena (``repro.launch.serve --arena``) append to:
which trainer the serving champion descends from, every tournament
match where its model was adopted from a partner, rescale clones,
failure recoveries, and arena promotions — one chain across training
rounds AND arena generations.

  python -m repro.launch.lineage --genealogy ckpts/genealogy.jsonl
  python -m repro.launch.lineage --genealogy ckpts/genealogy.jsonl \
      --champion trainer_2 --json
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional

from repro.train.telemetry import replay_genealogy


def summarize(records: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Aggregate counts over a genealogy record stream."""
    kinds: Dict[str, int] = {}
    rounds = -1
    trainers = 0
    for r in records:
        kinds[r.get("t", "?")] = kinds.get(r.get("t", "?"), 0) + 1
        if r.get("t") == "init":
            trainers = int(r.get("trainers", trainers))
        if r.get("t") == "rescale":
            trainers = int(r.get("to_k", trainers))
        if r.get("t") in ("round", "match") and "round" in r:
            rounds = max(rounds, int(r["round"]))
    return {"records": len(records), "kinds": kinds,
            "rounds": rounds + 1, "trainers": trainers}


def default_champion(records: List[Dict[str, Any]]) -> Optional[str]:
    """Latest arena champion, else the best trainer of the last round."""
    for r in reversed(records):
        if r.get("t") == "promotion":
            return str(r["winner"])
        if r.get("t") == "round" and "best_trainer" in r:
            return f"trainer_{int(r['best_trainer'])}"
    return None


def _trainer_index(name: str) -> int:
    if name.startswith("trainer_"):
        return int(name[len("trainer_"):])
    return int(name)


def ancestry(records: List[Dict[str, Any]], champion: str
             ) -> List[Dict[str, Any]]:
    """Walk the genealogy backward from ``champion``.

    Returns the chain of provenance events oldest-first: every record
    that changed whose model the champion's weights descend from
    (adopted tournament matches, rescale clones, failure recoveries,
    arena promotions), ending at the population init.
    """
    target = _trainer_index(champion)
    chain: List[Dict[str, Any]] = []
    for r in reversed(records):
        t = r.get("t")
        if t == "promotion" and str(r.get("winner")) == f"trainer_{target}":
            chain.append(r)
        elif t == "match" and int(r.get("trainer", -1)) == target \
                and r.get("adopted"):
            chain.append(r)
            target = int(r["partner"])
        elif t == "recover" and int(r.get("trainer", -1)) == target:
            chain.append(r)
            if r.get("cloned_from") is not None:
                target = int(r["cloned_from"])
        elif t == "rescale" and target in (r.get("cloned") or []):
            chain.append(r)
            if r.get("clone_src") is not None:
                target = int(r["clone_src"])
        elif t == "init":
            chain.append({**r, "root_trainer": target})
    chain.reverse()
    return chain


def _describe(r: Dict[str, Any]) -> str:
    t = r.get("t")
    if t == "init":
        return (f"root: trainer_{r.get('root_trainer', '?')} "
                f"(population init, {r.get('trainers', '?')} trainers, "
                f"seed {r.get('seed', '?')})")
    if t == "match":
        return (f"round {r.get('round', '?')}: trainer_{r['trainer']} "
                f"adopted the model of trainer_{r['partner']} "
                f"({r.get('m_other', float('nan')):.4g} beat "
                f"{r.get('m_local', float('nan')):.4g})")
    if t == "rescale":
        return (f"round {r.get('round', '?')}: rescale "
                f"{r.get('from_k', '?')}->{r.get('to_k', '?')} cloned "
                f"trainer_{r.get('clone_src', '?')} into "
                f"{['trainer_%d' % i for i in (r.get('cloned') or [])]}")
    if t == "recover":
        return (f"round {r.get('round', '?')}: trainer_{r['trainer']} "
                f"recovered from failure"
                + (f" as a clone of trainer_{r['cloned_from']}"
                   if r.get("cloned_from") is not None else ""))
    if t == "promotion":
        return (f"arena generation {r.get('generation', '?')}: "
                f"{r['winner']} dethroned {r.get('loser', '?')} at serve "
                f"step {r.get('step', '?')} "
                f"(accept rate {r.get('rate', float('nan')):.2f})")
    return json.dumps(r)


def build_parser() -> argparse.ArgumentParser:
    """The lineage CLI's argument parser (separate from :func:`main`
    so ``docs/flags.md`` can be checked against it)."""
    ap = argparse.ArgumentParser(
        prog="repro.launch.lineage",
        description="reconstruct a champion's ancestry from a "
                    "population genealogy log")
    ap.add_argument("--genealogy", required=True,
                    help="path to genealogy.jsonl (written under "
                         "--ckpt-dir by repro.launch.ltfb)")
    ap.add_argument("--champion", default=None,
                    help="member to trace (e.g. trainer_2; default: "
                         "latest arena champion, else last round's "
                         "best trainer)")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable report on stdout")
    return ap


def main(argv=None) -> int:
    """Entry point: print the lineage report, return exit status."""
    args = build_parser().parse_args(argv)
    records = replay_genealogy(args.genealogy)
    if not records:
        print(f"[lineage] no genealogy records in {args.genealogy!r}",
              file=sys.stderr)
        return 1
    champ = args.champion or default_champion(records)
    if champ is None:
        print("[lineage] cannot infer a champion — pass --champion",
              file=sys.stderr)
        return 1
    chain = ancestry(records, champ)
    summ = summarize(records)
    if args.json:
        print(json.dumps({"champion": champ, "summary": summ,
                          "ancestry": chain}))
        return 0
    print(f"[lineage] {args.genealogy}: {summ['records']} records, "
          f"{summ['rounds']} rounds, {summ['trainers']} trainers, "
          f"kinds={summ['kinds']}")
    print(f"[lineage] champion: {champ}")
    print("[lineage] ancestry (oldest first):")
    for r in chain:
        print(f"[lineage]   {_describe(r)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
