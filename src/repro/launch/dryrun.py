import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. lowers the cell's step function (train_step / prefill_step /
     decode_step) with FSDP/TP/EP/SP shardings from the logical rules,
  3. compiles it — sharding mismatches, unsupported collectives or
     compile-time OOM are FAILURES of the framework,
  4. records memory_analysis / cost_analysis / collective bytes into a
     JSON report consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
  python -m repro.launch.dryrun --ltfb            # paper-technique cell
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MeshConfig, OptimizerConfig, replace
from repro.configs.registry import dryrun_cells, get_config, get_shape
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_ltfb_mesh, make_production_mesh
from repro.parallel import roofline
from repro.parallel.sharding import serve_rules, tree_shardings, use_sharding
from repro.train import steps as steps_lib


def default_opt_for(cfg) -> OptimizerConfig:
    """Adafactor for >=30B params (Adam moments would not fit HBM)."""
    if cfg.param_count() >= 30e9:
        return OptimizerConfig(name="adafactor")
    return OptimizerConfig(name="adam")


# sharding presets (perf-iteration levers, EXPERIMENTS.md §Perf):
#  base — FSDP over data, TP/EP over model, SP on the residual stream
#  dp   — pure data parallelism: batch over BOTH axes, weights replicated
#         (right for <1B models where 16-way TP is pure collective waste)
#  dp_fsdp — batch over both axes, weights FSDP over data (1-8B models)
PRESETS = {
    "base": {},
    "dp": {"batch": ("pod", "data", "model"), "heads": None,
           "kv_heads": None, "mlp_act": None, "experts_act": None,
           "seq_sp": None, "state": None, "act_embed": None,
           "embed": None, "vocab": None, "heads_w": None, "mlp": None,
           "experts": None, "state_w": None, "kv_seq": None},
    "dp_fsdp": {"batch": ("pod", "data", "model"), "heads": None,
                "kv_heads": None, "mlp_act": None, "experts_act": None,
                "seq_sp": None, "state": None, "act_embed": None,
                "embed": ("data",), "vocab": ("model",),
                "heads_w": None, "mlp": None,
                "experts": ("model",), "state_w": None,
                "kv_seq": ("model",)},
    # serve — weights-stationary decode: pure TP over `model` (weights
    # never gathered; per-token collectives are tiny activation
    # all-reduces), batch DP over (pod, data), KV cache seq over `model`.
    # The rule set lives in parallel/sharding.py because the LIVE
    # serving mesh (serve/mesh.py) places weights and cache pools with
    # the same rules this dry-run preset compiles against.
    "serve": serve_rules(),
}


def mesh_label(multi_pod: bool) -> str:
    return "2pod_2x16x16" if multi_pod else "1pod_16x16"


def _sharded_bytes(shapes_tree, shardings_tree) -> int:
    """Exact per-chip resident bytes of a sharded pytree."""
    total = 0
    for sds, sh in zip(jax.tree.leaves(shapes_tree),
                       jax.tree.leaves(shardings_tree)):
        shard_shape = sh.shard_shape(sds.shape)
        n = sds.dtype.itemsize
        for d in shard_shape:
            n *= d
        total += n
    return total


def _residual_bytes(cfg, shape, chips: int, seq_parallel: bool) -> int:
    """Analytic activation-residual residency for remat='full': one saved
    (B, S, d_model) input per layer, sharded over batch (+ seq if SP)."""
    div = chips if seq_parallel else max(1, chips // 16)
    per_layer = shape.global_batch * shape.seq_len * cfg.d_model * 2
    return cfg.num_layers * per_layer // max(1, div)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rules: Optional[Dict[str, Any]] = None,
             mesh_cfg: Optional[MeshConfig] = None,
             preset: str = "base",
             cfg_overrides: Optional[Dict[str, Any]] = None,
             verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = replace(cfg, **cfg_overrides)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rules = dict(PRESETS.get(preset, {}), **(rules or {}))
    mesh_cfg = mesh_cfg or MeshConfig(remat="full")
    if mesh_cfg.seq_parallel and preset == "base":
        rules.setdefault("seq_sp", "model")
    opt_cfg = default_opt_for(cfg)

    t0 = time.perf_counter()
    with mesh, use_sharding(mesh, **rules):
        if shape.kind == "train":
            state_sh, state_ax = specs_lib.state_specs(cfg, opt_cfg)
            state_shardings = tree_shardings(mesh, state_ax, state_sh,
                                             **rules)
            batch_sh = specs_lib.train_input_specs(cfg, shape)
            batch_shardings = tree_shardings(
                mesh, specs_lib.batch_axes(batch_sh), batch_sh, **rules)
            step = steps_lib.make_lm_train_step(cfg, opt_cfg, mesh_cfg)
            jitted = jax.jit(step,
                             in_shardings=(state_shardings, batch_shardings),
                             out_shardings=(state_shardings, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_sh, batch_sh)
        elif shape.kind == "prefill":
            p_sh, p_ax = specs_lib.param_specs(cfg)
            p_shardings = tree_shardings(mesh, p_ax, p_sh, **rules)
            batch_sh = specs_lib.prefill_input_specs(cfg, shape)
            batch_shardings = tree_shardings(
                mesh, specs_lib.batch_axes(batch_sh), batch_sh, **rules)
            step = steps_lib.make_lm_prefill_step(cfg)
            jitted = jax.jit(step,
                             in_shardings=(p_shardings, batch_shardings))
            lowered = jitted.lower(p_sh, batch_sh)
        else:  # decode
            p_sh, p_ax = specs_lib.param_specs(cfg)
            p_shardings = tree_shardings(mesh, p_ax, p_sh, **rules)
            tok_sh, cache_sh, cache_ax, idx_sh = \
                specs_lib.decode_input_specs(cfg, shape)
            cache_shardings = tree_shardings(mesh, cache_ax, cache_sh,
                                             **rules)
            tok_shardings = tree_shardings(
                mesh, {"t": ("batch", None)}, {"t": tok_sh}, **rules)["t"]
            step = steps_lib.make_lm_decode_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(p_shardings, tok_shardings, cache_shardings,
                              None),
                out_shardings=(None, cache_shardings),
                donate_argnums=(2,))
            lowered = jitted.lower(p_sh, tok_sh, cache_sh, idx_sh)

        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    report = roofline.analyze(
        arch, shape_name, mesh_label(multi_pod), chips, cost, hlo,
        roofline.model_flops_for(cfg, shape), mem)
    elapsed = time.perf_counter() - t0

    # analytic residency (TPU target): weights/opt-state/cache are exactly
    # sharded; + remat residuals for training. The XLA-CPU temp arena is
    # schedule-pessimistic (no TPU liveness-minimizing passes), so both
    # numbers are recorded.
    if shape.kind == "train":
        analytic_state = _sharded_bytes(state_sh, state_shardings)
        analytic_resident = analytic_state + _residual_bytes(
            cfg, shape, chips, mesh_cfg.seq_parallel)
    elif shape.kind == "prefill":
        analytic_resident = _sharded_bytes(p_sh, p_shardings)
    else:
        analytic_resident = _sharded_bytes(p_sh, p_shardings) \
            + _sharded_bytes(cache_sh, cache_shardings)

    # "kernel-deployed" variant: tagged pure-JAX scan traffic replaced by
    # the analytic HBM traffic of the corresponding Pallas kernels
    # (kernels/flash_attention.py, kernels/slstm.py) — DESIGN.md §6.
    credits = roofline.kernel_credit_bytes(cfg, shape, chips)
    tagged = report.tag_bytes or {}
    credited = sum(tagged.get(t, 0.0) for t in credits)
    bytes_kernel = report.bytes_per_chip - credited \
        + sum(v for t, v in credits.items() if tagged.get(t, 0.0) > 0)
    t_memory_kernel = bytes_kernel / roofline.HBM_BW
    # collective credit: manual-VJP kernels all-reduce weight grads once
    coll_credits = roofline.kernel_credit_coll_bytes(cfg, shape, chips)
    tagged_coll = report.tag_coll_bytes or {}
    coll_kernel = report.coll_bytes_per_chip \
        - sum(tagged_coll.get(t, 0.0) for t in coll_credits) \
        + sum(v for t, v in coll_credits.items()
              if tagged_coll.get(t, 0.0) > 0)
    t_coll_kernel = coll_kernel / roofline.ICI_BW

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_label(multi_pod),
        "chips": chips, "ok": True, "compile_seconds": elapsed,
        "optimizer": opt_cfg.name,
        "params": cfg.param_count(),
        "params_active": cfg.param_count(active_only=True),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_per_device": report.peak_bytes_per_chip,
            "analytic_resident_bytes": analytic_resident,
            "fits_hbm": report.peak_bytes_per_chip <= roofline.HBM_PER_CHIP,
            "analytic_fits_hbm":
                analytic_resident <= 0.75 * roofline.HBM_PER_CHIP,
        },
        "roofline": report.to_dict(),
        "roofline_kernel": {
            "t_memory": t_memory_kernel,
            "t_collective": t_coll_kernel,
            "bytes_per_chip": bytes_kernel,
            "coll_bytes_per_chip": coll_kernel,
            "credited_tags": {t: tagged.get(t, 0.0) for t in credits},
            "credited_coll_tags": {t: tagged_coll.get(t, 0.0)
                                   for t in coll_credits},
            "analytic_kernel_bytes": credits,
            "step_time": max(report.t_compute, t_memory_kernel,
                             t_coll_kernel),
            "mfu": report.model_flops / (roofline.PEAK_FLOPS * max(
                report.t_compute, t_memory_kernel, t_coll_kernel))
            if max(report.t_compute, t_memory_kernel,
                   t_coll_kernel) > 0 else 0.0,
        },
        "rules": {k: str(v) for k, v in (rules or {}).items()},
        "remat": mesh_cfg.remat,
    }
    if verbose:
        gb = 1024 ** 3
        print(f"[{arch} | {shape_name} | {mesh_label(multi_pod)}] "
              f"compile={elapsed:.1f}s")
        print(f"  memory/device: args={mem.argument_size_in_bytes/gb:.2f}G "
              f"temp={mem.temp_size_in_bytes/gb:.2f}G "
              f"out={mem.output_size_in_bytes/gb:.2f}G  "
              f"peak={report.peak_bytes_per_chip/gb:.2f}G "
              f"fits_16G={result['memory']['fits_hbm']} "
              f"analytic={analytic_resident/gb:.2f}G")
        print(f"  roofline: compute={report.t_compute*1e3:.2f}ms "
              f"memory={report.t_memory*1e3:.2f}ms "
              f"collective={report.t_collective*1e3:.2f}ms "
              f"-> bottleneck={report.bottleneck} "
              f"(useful_flops={report.useful_flops_ratio:.2f}, "
              f"mfu@roofline={report.mfu:.2%})")
        coll = {k: f"{v/gb:.2f}G"
                for k, v in (report.coll_detail or {}).items()}
        print(f"  collectives: {coll}")
        if credits:
            print(f"  kernel-deployed: memory={t_memory_kernel*1e3:.2f}ms "
                  f"collective={t_coll_kernel*1e3:.2f}ms "
                  f"mfu={result['roofline_kernel']['mfu']:.2%} "
                  f"(credited { {k: f'{v/gb:.1f}G' for k, v in tagged.items() if v} })")
    return result


def run_ltfb_cell(scope: str = "generator", quantize: bool = False,
                  verbose: bool = True) -> Dict[str, Any]:
    """Dry-run the paper's technique itself: a 32-trainer LTFB tournament
    step (model exchange + local eval + winner select) on a
    ('trainer','model') mesh — collective-permute over trainers.

    Variants (EXPERIMENTS.md §Perf cell 3):
      scope='full'       — naive full-model exchange
      scope='generator'  — the paper's optimization (discriminators local)
      quantize=True      — beyond-paper int8 wire format
    """
    from repro.configs.icf_cyclegan import FULL as CCFG
    from repro.core import ltfb
    from repro.models import icf_cyclegan as cg

    K = 32
    mesh = make_ltfb_mesh(K, 16)
    t0 = time.perf_counter()

    def metric(params, batch):
        return cg.discriminator_metric(params, CCFG, batch)

    p_sh = jax.eval_shape(
        lambda: jax.tree.map(
            lambda x: jnp.broadcast_to(x, (K,) + x.shape),
            cg.init_cyclegan(CCFG, jax.random.PRNGKey(0))[0]))
    B = 128 * 4   # tournament_batches * paper mini-batch
    batch_sh = {"x": jax.ShapeDtypeStruct((K, B, CCFG.input_dim),
                                          jnp.float32),
                "y": jax.ShapeDtypeStruct((K, B, CCFG.output_dim),
                                          jnp.float32)}

    step = ltfb.make_ltfb_step(metric, K, mesh, axis="trainer",
                               scope=scope, quantize=quantize)
    lowered = step.lower(p_sh, batch_sh, jax.ShapeDtypeStruct((), jnp.int32))
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = roofline.parse_collectives(hlo)
    elapsed = time.perf_counter() - t0
    variant = f"{scope}{'_int8' if quantize else ''}"
    result = {
        "arch": "icf-cyclegan-ltfb", "shape": f"tournament_k32_{variant}",
        "mesh": "ltfb_32x16", "chips": mesh.devices.size, "ok": True,
        "compile_seconds": elapsed,
        "collective_bytes": coll.total_bytes,
        "collectives": coll.bytes_by_op,
        "counts": coll.count_by_op,
        "exchange_seconds": coll.total_bytes / roofline.ICI_BW,
        "flops": cost.get("flops", 0.0),
        "memory": {"temp_bytes": mem.temp_size_in_bytes},
    }
    if verbose:
        print(f"[LTFB tournament | K=32 | 512 chips | {variant}] "
              f"compile={elapsed:.1f}s")
        print(f"  exchange bytes/trainer: "
              f"{coll.total_bytes / 2**20:.1f} MiB "
              f"-> {result['exchange_seconds']*1e3:.2f} ms on ICI "
              f"({coll.bytes_by_op})")
    return result


def run_pp_cell(verbose: bool = True) -> Dict[str, Any]:
    """Pipeline-parallelism demo cell: a 4-stage x 8-DP x 8-TP (256-chip)
    circular pipeline over transformer-block stages; reports the
    collective-permute schedule and bubble fraction."""
    from jax.sharding import Mesh, PartitionSpec as P
    import numpy as np

    from repro.parallel.pipeline import (bubble_fraction,
                                         make_pipelined_forward)

    S, M, mb, d, dff = 4, 16, 8, 2048, 8192
    devices = np.asarray(jax.devices()[:256]).reshape(S, 8, 8)
    mesh = Mesh(devices, ("stage", "data", "model"))
    t0 = time.perf_counter()

    def stage_fn(params, h):
        w1, w2 = params
        return h + jax.nn.silu(h @ w1) @ w2

    p_sh = (jax.ShapeDtypeStruct((S, d, dff), jnp.bfloat16),
            jax.ShapeDtypeStruct((S, dff, d), jnp.bfloat16))
    x_sh = jax.ShapeDtypeStruct((M, mb, 1024, d), jnp.bfloat16)

    pipe = make_pipelined_forward(
        stage_fn, mesh, S, "stage",
        param_spec=(P("stage", None, "model"), P("stage", "model", None)),
        x_spec=P(None, "data"))

    def loss(params, x):
        return jnp.mean(jnp.square(pipe(params, x)))

    co = jax.jit(jax.grad(loss)).lower(p_sh, x_sh).compile()
    coll = roofline.parse_collectives(co.as_text())
    elapsed = time.perf_counter() - t0
    result = {
        "arch": "pp-demo-4stage", "shape": f"microbatches_{M}",
        "mesh": "pp_4x8x8", "chips": 256, "ok": True,
        "compile_seconds": elapsed,
        "bubble_fraction": bubble_fraction(S, M),
        "collectives": coll.bytes_by_op,
        "counts": coll.count_by_op,
    }
    if verbose:
        print(f"[PP demo | 4 stages x 8 DP x 8 TP | M={M}] "
              f"compile={elapsed:.1f}s bubble={bubble_fraction(S, M):.1%}")
        print(f"  collectives: {coll.bytes_by_op} ({coll.count_by_op})")
    return result


def build_parser():
    """The dryrun CLI's argument parser (separate from :func:`main` so
    ``docs/flags.md`` can be checked against it)."""
    ap = argparse.ArgumentParser(prog="repro.launch.dryrun")
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--ltfb", action="store_true")
    ap.add_argument("--pp", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--no-seq-parallel", action="store_true")
    ap.add_argument("--preset", default="base", choices=sorted(PRESETS))
    ap.add_argument("--suffix", default="",
                    help="filename suffix for perf-iteration variants")
    ap.add_argument("--moe-dispatch", default=None,
                    choices=["einsum", "scatter"])
    return ap


def main(argv=None):
    """CLI entry point: run the selected dry-run cells."""
    args = build_parser().parse_args(argv)
    cfg_overrides = {}
    if args.moe_dispatch:
        cfg_overrides["moe.dispatch"] = args.moe_dispatch

    cells = dryrun_cells()
    if args.list:
        for a, s in cells:
            print(f"{a} {s}")
        return 0

    if args.ltfb:
        for scope, quant in (("full", False), ("generator", False),
                             ("generator", True)):
            res = run_ltfb_cell(scope=scope, quantize=quant)
            _save(args.out,
                  f"ltfb_tournament_{scope}{'_int8' if quant else ''}", res)
        return 0

    if args.pp:
        res = run_pp_cell()
        _save(args.out, "pp_demo", res)
        return 0

    if args.arch:
        cells = [(a, s) for a, s in cells if a == args.arch]
    if args.shape:
        cells = [(a, s) for a, s in cells if s == args.shape]
    if not cells:
        print("no matching cells", file=sys.stderr)
        return 1

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    mesh_cfg = MeshConfig(remat=args.remat,
                          seq_parallel=not args.no_seq_parallel)
    failures = 0
    for arch, shape in cells:
        for multi in meshes:
            try:
                res = run_cell(arch, shape, multi, mesh_cfg=mesh_cfg,
                               preset=args.preset,
                               cfg_overrides=cfg_overrides or None)
            except Exception as e:
                failures += 1
                res = {"arch": arch, "shape": shape,
                       "mesh": mesh_label(multi), "ok": False,
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                print(f"[{arch} | {shape} | {mesh_label(multi)}] FAILED: "
                      f"{type(e).__name__}: {str(e)[:300]}")
            _save(args.out,
                  f"{arch}__{shape}__{mesh_label(multi)}{args.suffix}", res)
    print(f"dry-run complete: {len(cells) * len(meshes) - failures}"
          f"/{len(cells) * len(meshes)} cells OK")
    return 1 if failures else 0


def _save(out_dir: str, name: str, result: Dict[str, Any]):
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, name + ".json"), "w") as f:
        json.dump(result, f, indent=2, default=str)


if __name__ == "__main__":
    sys.exit(main())
