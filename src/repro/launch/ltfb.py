"""LTFB population-training launcher (paper §III: datastore + tournament).

Runs K trainers, each fed from its own distributed-datastore partition
of an on-disk bundle manifest (JAG ICF bundles for the CycleGAN, token
shards for the LM architectures), with tournaments between rounds and
checkpoint/restart of the full population.

  python -m repro.launch.ltfb --arch icf-cyclegan --trainers 4 \
      --steps-per-round 2 --rounds 2 --smoke
  python -m repro.launch.ltfb --arch qwen3-0.6b --smoke --trainers 2
  python -m repro.launch.ltfb --arch icf-cyclegan --trainers 4 \
      --rescale-to 6 --rounds 4        # elastic rescale mid-run

Resumes from --ckpt-dir automatically unless --no-resume.
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile

from repro.configs.base import OptimizerConfig
from repro.configs.registry import ARCHS, get_config
from repro.core.population import TrainerFns
from repro.core.tournament import (
    DataPlan,
    TournamentConfig,
    TournamentOrchestrator,
)
from repro.telemetry import (
    enable_json_logs,
    json_logs_enabled,
    log_event,
    write_trace,
)


def say(human: str, event: str, **fields):
    """Report line: one-line JSON under --log-json, human text otherwise
    (same dialect as the serve launcher's structured records)."""
    if json_logs_enabled():
        log_event(event, **fields)
    else:
        print(human)


def build_plan(args) -> DataPlan:
    """Materialize (or reuse) the on-disk bundle manifest."""
    root = args.data_dir or tempfile.mkdtemp(prefix="repro_ltfb_")
    if args.arch == "icf-cyclegan":
        from repro.data import jag
        image_size = 8 if args.smoke else 64
        files = jag.list_bundles(root)
        if files:
            got = jag.read_bundle(files[0])["images"].shape[-1]
            if got != image_size:
                raise SystemExit(
                    f"[ltfb] --data-dir {root} holds bundles at image size "
                    f"{got}, this run needs {image_size} — use a fresh "
                    "--data-dir")
        else:
            files = jag.write_bundles(root, args.samples,
                                      args.samples_per_file,
                                      image_size=image_size, seed=args.seed)
        say(f"[ltfb] manifest: {len(files)} JAG bundles in {root}",
            "ltfb_manifest", files=len(files), root=root, kind="jag")
        return DataPlan.jag_cyclegan(files)
    from repro.data import tokens
    cfg = get_config(args.arch, smoke=args.smoke)
    files = tokens.list_token_shards(root)
    if files:
        probe = tokens.read_token_shard(files[0])["tokens"]
        if probe.shape[1] != args.seq + 1 or probe.max() >= cfg.vocab_size:
            raise SystemExit(
                f"[ltfb] --data-dir {root} holds shards of seq "
                f"{probe.shape[1] - 1} / max token {probe.max()}, this run "
                f"needs seq {args.seq} / vocab {cfg.vocab_size} — use a "
                "fresh --data-dir")
    else:
        files = tokens.write_token_shards(
            root, args.samples, seq_len=args.seq, vocab=cfg.vocab_size,
            samples_per_file=args.samples_per_file, seed=args.seed)
    say(f"[ltfb] manifest: {len(files)} token shards in {root}",
        "ltfb_manifest", files=len(files), root=root, kind="tokens")
    return DataPlan.lm_tokens(files)


def build_fns(args) -> TrainerFns:
    opt = OptimizerConfig(name=args.optimizer, lr=args.lr, warmup_steps=1)
    if args.arch == "icf-cyclegan":
        from repro.configs.icf_cyclegan import FULL, SMOKE
        from repro.train.steps import make_gan_steps
        return TrainerFns(*make_gan_steps(SMOKE if args.smoke else FULL,
                                          opt))
    from repro.train.steps import make_lm_population_fns
    cfg = get_config(args.arch, smoke=args.smoke)
    return TrainerFns(*make_lm_population_fns(cfg, opt))


def report(orch: TournamentOrchestrator):
    st = orch.stats()
    for i, d in enumerate(st["per_trainer"]):
        say(f"[ltfb] trainer {i}: files={d['files']} "
            f"cache_hits={d['cache_hits']} "
            f"cache_misses={d['cache_misses']} "
            f"file_opens={d['file_opens']} "
            f"exchange_MB={d['exchange_bytes'] / 1e6:.2f} "
            f"wins={d['wins']} adoptions={d['adoptions']} "
            f"steps={d['steps']} "
            f"data_wait_s={d['data_wait_seconds']:.2f}",
            "ltfb_trainer_stats", trainer=i, **d)
    tot = st["total"]
    say(f"[ltfb] datastore total: read_MB={tot['bytes_read'] / 1e6:.2f} "
        f"exchange_MB={tot['exchange_bytes'] / 1e6:.2f} "
        f"cache_hits={int(tot['cache_hits'])} "
        f"cache_misses={int(tot['cache_misses'])} "
        f"samples={int(tot.get('samples_fetched', 0))} "
        f"prefetch_wait_s={st['prefetch_wait_seconds']:.2f}",
        "ltfb_datastore_stats",
        prefetch_wait_seconds=st["prefetch_wait_seconds"], **tot)
    wins = [d["wins"] for d in st["per_trainer"]]
    say(f"[ltfb] tournament: rounds={st['round']} win_counts={wins} "
        f"model_exchange_MB="
        f"{st['tournament_exchange_bytes'] / 1e6:.2f} "
        f"tournament_s={st['tournament_seconds']:.2f} "
        f"ckpt_s={st['checkpoint_seconds']:.2f}",
        "ltfb_tournament_stats", rounds=st["round"], win_counts=wins,
        tournament_exchange_bytes=st["tournament_exchange_bytes"],
        tournament_seconds=st["tournament_seconds"],
        checkpoint_seconds=st["checkpoint_seconds"],
        restore_seconds=st["restore_seconds"], events=st["events"])
    eff = st.get("efficiency") or {}
    if eff.get("speedup") is not None:
        say(f"[ltfb] efficiency: speedup={eff['speedup']:.2f}x "
            f"efficiency={eff['efficiency'] * 100:.0f}% "
            f"parallel_samples_per_s="
            f"{eff['parallel_samples_per_s']:.0f}",
            "ltfb_efficiency", **eff)


def build_parser() -> argparse.ArgumentParser:
    """The ltfb CLI's argument parser (separate from :func:`main` so
    ``docs/flags.md`` can be checked against it)."""
    ap = argparse.ArgumentParser(
        prog="repro.launch.ltfb",
        description="LTFB tournament training over the distributed "
                    "datastore")
    ap.add_argument("--arch", default="icf-cyclegan", choices=sorted(ARCHS))
    ap.add_argument("--trainers", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--steps-per-round", type=int, default=25)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--backend", default="host", choices=("host", "mesh"))
    ap.add_argument("--scope", default=None,
                    help="exchange scope (default: generator for GANs, "
                         "full otherwise)")
    ap.add_argument("--store-mode", default="preload",
                    choices=("preload", "dynamic", "none"))
    ap.add_argument("--num-ranks", type=int, default=2,
                    help="simulated datastore ranks per trainer")
    ap.add_argument("--partition", default="stride",
                    choices=("stride", "block"))
    ap.add_argument("--quantize-exchange", action="store_true",
                    help="int8 model exchange on the mesh backend")
    ap.add_argument("--no-async-eval", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + dataset (CPU-runnable)")
    ap.add_argument("--samples", type=int, default=None)
    ap.add_argument("--samples-per-file", type=int, default=None)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adam")
    ap.add_argument("--data-dir", default=None,
                    help="bundle manifest dir (default: fresh tempdir)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=1,
                    help="checkpoint every N rounds (0 = never)")
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--rescale-to", type=int, default=0,
                    help="elastically rescale to K' trainers mid-run")
    ap.add_argument("--seed", type=int, default=0)
    # observability (docs/observability.md "Training telemetry")
    ap.add_argument("--log-json", action="store_true",
                    help="one-line JSON log records instead of human text")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace JSON of per-trainer "
                         "step/exchange/eval spans here on exit")
    ap.add_argument("--prom-out", default=None,
                    help="write a Prometheus text snapshot here each round")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve the Prometheus snapshot on this HTTP "
                         "port (0 = ephemeral)")
    ap.add_argument("--genealogy", default=None,
                    help="tournament genealogy JSONL (default: "
                         "<ckpt-dir>/genealogy.jsonl when --ckpt-dir is "
                         "set; see repro.launch.lineage)")
    return ap


def main(argv=None) -> int:
    """CLI entry point: parse args, run the LTFB tournament."""
    args = build_parser().parse_args(argv)

    if args.log_json:
        enable_json_logs()
    if args.samples is None:
        args.samples = 1024 if args.smoke else 16_384
    if args.samples_per_file is None:
        args.samples_per_file = 64 if args.smoke else 512
    rounded = (args.samples // args.samples_per_file) * args.samples_per_file
    if rounded != args.samples:
        say(f"[ltfb] rounding --samples {args.samples} -> {rounded} "
            "(datastore bundles must be uniform)",
            "ltfb_samples_rounded", requested=args.samples, used=rounded)
        args.samples = max(rounded, args.samples_per_file)
    scope = args.scope or \
        ("generator" if args.arch == "icf-cyclegan" else "full")

    plan = build_plan(args)
    fns = build_fns(args)
    cfg = TournamentConfig(
        trainers=args.trainers, scope=scope, backend=args.backend,
        store_mode=args.store_mode, num_ranks=args.num_ranks,
        partition=args.partition, batch_size=args.batch,
        tournament_batch_size=min(args.batch * 2, args.samples_per_file),
        async_eval=not args.no_async_eval,
        quantize_exchange=args.quantize_exchange,
        ckpt_dir=args.ckpt_dir, seed=args.seed)

    from repro.train.telemetry import (GenealogyLog, MetricsServer,
                                       TrainTelemetry, train_prometheus,
                                       write_prom)
    tel = TrainTelemetry() \
        if (args.trace_out or args.prom_out
            or args.metrics_port is not None) else None
    gen_path = args.genealogy or (
        os.path.join(args.ckpt_dir, "genealogy.jsonl")
        if args.ckpt_dir else None)
    gen = GenealogyLog(gen_path) if gen_path else None
    server = MetricsServer(args.metrics_port) \
        if args.metrics_port is not None else None
    if server is not None:
        say(f"[ltfb] metrics endpoint: "
            f"http://127.0.0.1:{server.port}/metrics",
            "ltfb_metrics_endpoint", port=server.port)

    orch = TournamentOrchestrator(fns, plan, cfg, telemetry=tel,
                                  genealogy=gen)
    if tel is not None or server is not None or args.prom_out:
        def on_round(o: TournamentOrchestrator):
            text = train_prometheus(
                o.stats(), tel.phase_seconds if tel else None)
            if args.prom_out:
                write_prom(text, args.prom_out)
            if server is not None:
                server.update(text)
        orch.on_round = on_round
    log_line = None if args.log_json else print
    try:
        if not args.no_resume and orch.maybe_resume():
            say(f"[ltfb] resumed at round {orch.population.round}",
                "ltfb_resumed", round=orch.population.round)
        say(f"[ltfb] arch={args.arch} K={args.trainers} "
            f"backend={args.backend} scope={scope} "
            f"store={args.store_mode}/{args.partition} "
            f"ranks={args.num_ranks}",
            "ltfb_start", arch=args.arch, trainers=args.trainers,
            backend=args.backend, scope=scope,
            store_mode=args.store_mode, partition=args.partition,
            num_ranks=args.num_ranks)
        first = args.rounds // 2 if args.rescale_to else args.rounds
        orch.run(first, args.steps_per_round,
                 ckpt_every=args.ckpt_every, log=log_line)
        if args.rescale_to:
            if not args.log_json:
                print(f"[ltfb] elastic rescale {args.trainers} -> "
                      f"{args.rescale_to}")
            orch.rescale(args.rescale_to)
            orch.run(args.rounds - first, args.steps_per_round,
                     ckpt_every=args.ckpt_every, log=log_line)
        report(orch)
        if args.trace_out and tel is not None:
            write_trace(tel.tracer, args.trace_out)
            say(f"[ltfb] wrote {args.trace_out} "
                f"(Perfetto/chrome://tracing)",
                "ltfb_trace_written", path=args.trace_out,
                events=tel.tracer.emitted,
                dropped=tel.tracer.dropped)
    finally:
        orch.close()
        if gen is not None:
            gen.close()
        if server is not None:
            server.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
