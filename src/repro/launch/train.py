"""Production training launcher.

Single-controller JAX: builds the mesh from the runtime topology, the
datastore from the file manifest, shards the train state per the
logical rules, and runs the (optionally LTFB-wrapped) training loop with
checkpoint/restart.  On this CPU container it runs the reduced configs;
on a TPU pod slice the same script runs the full configs (the dry-run
proves every cell compiles on the production meshes).

  python -m repro.launch.train --arch qwen3-0.6b --smoke --steps 50
  python -m repro.launch.train --arch icf-cyclegan --steps 300
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs.base import MeshConfig, OptimizerConfig
from repro.configs.registry import ARCHS, get_config
from repro.data.tokens import train_batch
from repro.parallel.sharding import tree_shardings, use_sharding
from repro.train.steps import (init_lm_state, make_lm_eval_metric,
                               make_lm_train_step)


def build_mesh(args):
    n = len(jax.devices())
    if n == 1:
        return None
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    if n >= 512 and args.multi_pod:
        return make_production_mesh(multi_pod=True)
    if n >= 256:
        return make_production_mesh(multi_pod=False)
    return make_host_mesh(("data",))


def train_lm(args):
    cfg = get_config(args.arch, smoke=args.smoke)
    opt = OptimizerConfig(name=args.optimizer, lr=args.lr,
                          warmup_steps=min(100, args.steps // 10 + 1))
    mesh_cfg = MeshConfig(remat=args.remat)
    mesh = build_mesh(args)
    print(f"[train] arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"devices={len(jax.devices())} mesh={'none' if mesh is None else mesh.shape}")

    step_fn = make_lm_train_step(cfg, opt, mesh_cfg)
    metric = jax.jit(make_lm_eval_metric(cfg))

    with use_sharding(mesh):
        state, axes = init_lm_state(cfg, opt, jax.random.PRNGKey(args.seed))
        if mesh is not None:
            shardings = tree_shardings(mesh, axes, state)
            state = jax.device_put(state, shardings)
            step = jax.jit(step_fn, donate_argnums=(0,),
                           in_shardings=(shardings, None),
                           out_shardings=(shardings, None))
        else:
            step = jax.jit(step_fn, donate_argnums=(0,))

        # restart support
        start = 0
        latest = ckpt.latest_step_path(args.ckpt_dir)
        if latest and not args.no_resume:
            state, meta = ckpt.restore(latest, state)
            start = meta.get("step", 0)
            print(f"[train] resumed from {latest} at step {start}")

        saver = ckpt.AsyncCheckpointer()
        val = {k: jnp.asarray(v) for k, v in
               train_batch(cfg, args.batch, args.seq, seed=987654).items()}
        t0 = time.time()
        for i in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in
                     train_batch(cfg, args.batch, args.seq, seed=i).items()}
            state, m = step(state, batch)
            if i % args.log_every == 0:
                print(f"step {i:5d} loss={float(m['loss']):.4f} "
                      f"lr={float(m['lr']):.2e} "
                      f"({(time.time()-t0):.1f}s)")
            if args.ckpt_every and i and i % args.ckpt_every == 0:
                saver.save(os.path.join(args.ckpt_dir, f"step_{i}.ckpt"),
                           state, {"step": i})
        saver.wait()
        print(f"[train] done: val={float(metric(state['params'], val)):.4f}")


def train_cyclegan(args):
    """The paper's model: delegates to the quickstart pipeline."""
    from repro.configs.base import OptimizerConfig
    from repro.configs.icf_cyclegan import CycleGANConfig
    from repro.data import jag
    from repro.train.steps import make_gan_steps

    ccfg = CycleGANConfig(image_size=16 if args.smoke else 64,
                          enc_hidden=(256, 64), dec_hidden=(64, 256))
    init, train_step, metric = make_gan_steps(
        ccfg, OptimizerConfig(name="adam", lr=args.lr))
    params, opt_state, hparams = init(args.seed)
    xs = jag.sample_inputs(args.samples + 512, seed=0)
    sim = jag.jag_simulate(xs, ccfg.image_size)
    x, y = sim["x"], jag.flatten_outputs(sim)
    val = {"x": jnp.asarray(x[args.samples:]),
           "y": jnp.asarray(y[args.samples:])}
    import numpy as np
    rng = np.random.default_rng(args.seed)
    for i in range(args.steps):
        idx = rng.integers(0, args.samples, 128)
        batch = {"x": jnp.asarray(x[idx]), "y": jnp.asarray(y[idx])}
        params, opt_state, m = train_step(params, opt_state, batch, hparams)
        if i % args.log_every == 0:
            print(f"step {i:5d} g={float(m['g_loss']):.4f} "
                  f"d={float(m['d_loss']):.4f} "
                  f"val={float(metric(params, val)):.4f}")
    print(f"[train] done: val={float(metric(params, val)):.4f}")


def build_parser():
    """The train CLI's argument parser (separate from :func:`main` so
    ``docs/flags.md`` can be checked against it)."""
    ap = argparse.ArgumentParser(prog="repro.launch.train")
    ap.add_argument("--arch", default="icf-cyclegan",
                    choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--samples", type=int, default=8000)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adam")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    return ap


def main(argv=None):
    """CLI entry point: train the selected arch."""
    args = build_parser().parse_args(argv)

    if args.arch == "icf-cyclegan":
        train_cyclegan(args)
    else:
        train_lm(args)
    return 0


if __name__ == "__main__":
    sys.exit(main())
