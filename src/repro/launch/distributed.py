"""Multi-process mesh serving harness (``jax.distributed``).

Runs the :class:`repro.serve.mesh.MeshScheduler` across REAL OS
processes: host 0 decides (admission, hot swap, submits, cancels) and
broadcasts a :class:`repro.serve.mesh.StepPlan` every step; followers
replay it and land in an identical state.  On CPU the plan rides the
jax coordination service (XLA's CPU backend cannot run cross-process
computations), each process holds a full model replica on its private
local mesh, and a per-step barrier turns a dead peer into a clean
timeout error instead of a hang.  On TPU/GPU the same harness gets a
true global mesh and device-collective broadcasts.

Two modes in one CLI:

* **spawn** (``--procs N``): pick a free coordinator port, launch N
  worker copies of this module, supervise them (first failure kills
  the rest), exit with the workers' status;
* **worker** (``--process-id I --num-processes N --coordinator
  HOST:PORT``): ``jax.distributed.initialize``, build the scheduler,
  run the request trace (host 0) or the replay loop (followers), and
  write per-process results to ``--out-json`` (followers append
  ``.pI``) so token identity across processes is checkable from the
  outside.

  python -m repro.launch.distributed --procs 2 --smoke \
      --requests 4 --max-new 8 --out-json /tmp/dist.json
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import subprocess
import sys
import time
from typing import List, Optional

# NOTE: no top-level jax import — the spawner must be able to set
# XLA_FLAGS in the children's environment before THEIR jax import, and
# must not initialize a backend in the parent at all.


def find_free_port(host: str = "127.0.0.1") -> int:
    """Bind port 0, return the OS-assigned free port."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind((host, 0))
        return s.getsockname()[1]


def build_parser() -> argparse.ArgumentParser:
    """The distributed CLI's argument parser (separate from
    :func:`main` so ``docs/flags.md`` can be checked against it)."""
    ap = argparse.ArgumentParser(
        prog="repro.launch.distributed",
        description="Multi-process mesh serving over jax.distributed")
    # spawn mode
    ap.add_argument("--procs", type=int, default=0,
                    help="spawn N worker processes and supervise them "
                         "(0 = run as a worker in THIS process)")
    # worker topology
    ap.add_argument("--process-id", type=int, default=0,
                    help="this worker's rank (0 = host-0 scheduler)")
    ap.add_argument("--num-processes", type=int, default=1,
                    help="total worker count in the job")
    ap.add_argument("--coordinator", default="127.0.0.1:0",
                    help="jax.distributed coordinator HOST:PORT "
                         "(the spawner fills this in)")
    ap.add_argument("--step-timeout", type=float, default=60.0,
                    help="per-step plan-broadcast timeout in seconds; "
                         "a dead peer raises instead of hanging")
    ap.add_argument("--feed", default="host0",
                    choices=("host0", "replicated"),
                    help="host0: only host 0 sees the requests "
                         "(followers receive them in the plan, the "
                         "gateway path); replicated: every process "
                         "submits the trace locally")
    # model / trace (mirrors launch.serve)
    ap.add_argument("--arch", default="qwen3-0.6b",
                    help="registered LM arch to serve")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--mesh", default="1,1",
                    help="per-process mesh 'DATA,MODEL' (CPU: each "
                         "process holds a full replica on its local "
                         "devices)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=0,
                    help="per-request cap + pool sizing (0 = fit the "
                         "trace)")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prompt-lens", default="8,16",
                    help="comma list; requests cycle through these")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out-json", default=None,
                    help="write {rid: tokens} + stats here (followers "
                         "append .p<rank>)")
    # fault tolerance
    ap.add_argument("--journal", default=None,
                    help="host-0 write-ahead request journal (JSONL); "
                         "restarted generations resume unfinished "
                         "requests from it token-identically")
    ap.add_argument("--resume-journal", default=None,
                    help="replay a previous generation's --journal on "
                         "host 0 before serving the trace")
    ap.add_argument("--fault-spec", default=None,
                    help="deterministic fault injection, comma list of "
                         "kind@step[:key=val...] (kinds kill|crash|"
                         "stall|corrupt|oom|disconnect; rank= picks "
                         "the victim process, default 0) — stripped "
                         "automatically on supervised restarts")
    ap.add_argument("--restart-on-failure", type=int, default=0,
                    help="spawn mode: on a worker failure, restart the "
                         "whole job up to N times with a fresh "
                         "coordinator, resuming from the previous "
                         "generation's --journal")
    # online LTFB arena (mirrors launch.serve; every rank mirrors the
    # roster, rank 0 owns the registry archive + write-back)
    ap.add_argument("--arena", default=None,
                    help="serve an N-member population roster from this "
                         "LTFB checkpoint dir as an ONLINE tournament: "
                         "champion serves, challengers draft, accept "
                         "rate scores matches, winners hot-swap in — "
                         "host 0 decides, the promotion rides the step "
                         "plan")
    ap.add_argument("--arena-policy", default="champion",
                    choices=("champion", "epsilon", "shadow"),
                    help="challenger routing: champion = best "
                         "challenger drafts (exploit); epsilon = mostly "
                         "best, periodically round-robin; shadow = "
                         "round-robin every stint (even sampling)")
    ap.add_argument("--arena-window", type=int, default=128,
                    help="sliding accept-rate window per member, in "
                         "speculative row-rounds (the match metric)")
    ap.add_argument("--arena-margin", type=float, default=0.02,
                    help="a challenger must beat the champion's "
                         "promotion-time accept rate by this margin to "
                         "win a match")
    ap.add_argument("--arena-min-samples", type=int, default=32,
                    help="proposals a challenger's window must hold "
                         "before it can qualify for promotion")
    ap.add_argument("--arena-hysteresis", type=int, default=2,
                    help="consecutive winning match evaluations before "
                         "a promotion fires")
    ap.add_argument("--arena-check-every", type=int, default=8,
                    help="scheduler steps between match evaluations")
    ap.add_argument("--arena-writeback", default=None,
                    help="rank 0 writes finished request/response "
                         "streams back as datastore token shards in "
                         "this dir (train->serve->train)")
    ap.add_argument("--arena-seq", type=int, default=64,
                    help="write-back row width minus one: rows are "
                         "(seq+1) tokens, matching launch/ltfb.py "
                         "--seq so shards re-ingest directly")
    return ap


def _strip_flags(argv: List[str], names) -> List[str]:
    """Drop ``--flag value`` / ``--flag=value`` pairs from an argv."""
    out, skip = [], False
    for a in argv:
        if skip:
            skip = False
            continue
        if a.split("=", 1)[0] in names:
            skip = "=" not in a
            continue
        out.append(a)
    return out


def spawn(args, argv: List[str]) -> int:
    """Launch ``--procs`` worker copies of this module and supervise
    them: the first nonzero exit kills the remaining workers.

    With ``--restart-on-failure N`` a failed job is relaunched up to N
    times: fresh coordinator port, ``--fault-spec`` stripped (the
    drill already fired), and — when a ``--journal`` is attached —
    the new generation resumes from the previous generation's journal
    so the combined output is token-identical to an uninterrupted
    run."""
    from repro.launch.serve import parse_lens  # no jax at import time
    from repro.serve.mesh import parse_mesh

    data, model = parse_mesh(args.mesh)
    _ = parse_lens(args.prompt_lens)    # fail fast on a bad trace spec
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = env.get("JAX_PLATFORMS", "cpu")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count="
                        f"{data * model}").strip()
    # workers re-run this argv minus the spawn flag, plus topology
    passthrough = _strip_flags(argv, ("--procs",))
    restarts = max(0, int(getattr(args, "restart_on_failure", 0) or 0))
    journal = getattr(args, "journal", None)
    attempt = 0
    while True:
        extra: List[str] = []
        if attempt > 0:
            # the fault already fired; a restarted generation gets a
            # clean spec, a fresh journal file and the previous
            # generation's journal to resume from
            extra = _strip_flags(
                passthrough, ("--fault-spec", "--journal",
                              "--resume-journal"))
            if journal is not None:
                prev = journal if attempt == 1 \
                    else f"{journal}.r{attempt - 1}"
                extra += ["--journal", f"{journal}.r{attempt}",
                          "--resume-journal", prev]
            status = _spawn_once(args, extra, env)
        else:
            status = _spawn_once(args, passthrough, env)
        if status == 0 or attempt >= restarts:
            return status
        attempt += 1
        print(f"[dist] worker failure (rc={status}); restarting the "
              f"job (attempt {attempt}/{restarts})"
              + (f", resuming from the generation-{attempt - 1} "
                 f"journal" if journal else ""), flush=True)


def _spawn_once(args, passthrough: List[str], env: dict) -> int:
    """One supervised generation: launch the workers on a fresh
    coordinator port, return the job's exit status."""
    port = find_free_port()
    coordinator = f"127.0.0.1:{port}"
    procs = []
    for rank in range(args.procs):
        cmd = [sys.executable, "-m", "repro.launch.distributed",
               *passthrough, "--process-id", str(rank),
               "--num-processes", str(args.procs),
               "--coordinator", coordinator]
        procs.append(subprocess.Popen(cmd, env=env))
    status = 0
    try:
        live = list(procs)
        while live:
            for p in list(live):
                rc = p.poll()
                if rc is None:
                    continue
                live.remove(p)
                if rc != 0:
                    status = rc
                    # one dead worker stalls the others at the next
                    # barrier; don't wait for the timeout to prove it
                    for q in live:
                        q.terminate()
            time.sleep(0.05)
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
    return status


def run_worker(args) -> int:
    """One worker process: initialize the process group, serve the
    trace (host 0) or replay plans (followers), write results."""
    import jax

    if args.num_processes > 1:
        jax.distributed.initialize(
            coordinator_address=args.coordinator,
            num_processes=args.num_processes,
            process_id=args.process_id,
            initialization_timeout=max(int(args.step_timeout), 10))
    import numpy as np

    from repro.configs.registry import get_config
    from repro.launch.serve import build_requests, parse_lens
    from repro.models.lm import init_lm
    from repro.serve.mesh import MeshScheduler, parse_mesh

    cfg = get_config(args.arch, smoke=args.smoke)
    # identical seed -> identical replica weights in every process
    params, _ = init_lm(cfg, jax.random.PRNGKey(args.seed))
    lens = parse_lens(args.prompt_lens)
    max_len = args.max_len or max(lens) + args.max_new
    journal = None
    if args.journal and args.process_id == 0:
        from repro.serve.journal import RequestJournal
        journal = RequestJournal(args.journal)
    faults = None
    if args.fault_spec:
        from repro.serve.faults import FaultInjector
        faults = FaultInjector(args.fault_spec, rank=args.process_id)
    # online LTFB arena: EVERY rank mirrors the roster (promotions are
    # replayed from the plan); rank 0 alone archives + writes back.
    # All ranks replay the journaled arena state on resume (shared
    # filesystem) so the mesh starts aligned on the same champion.
    arena, spec_tokens, draft_params = None, 0, None
    if args.arena:
        from repro.launch.serve import make_arena
        arena = make_arena(args, cfg, params, rank=args.process_id)
        spec_tokens = 4
        draft_params = arena.drafter_params
    sched = MeshScheduler(
        cfg, arena.champion_params if arena is not None else params,
        mesh_shape=parse_mesh(args.mesh),
        local_mesh=args.num_processes > 1,
        step_timeout_s=args.step_timeout,
        num_slots=args.slots, max_len=max_len,
        journal=journal, faults=faults, arena=arena,
        draft_params=draft_params, spec_tokens=spec_tokens)
    rank = jax.process_index()
    print(f"[dist] rank={rank}/{args.num_processes} arch={cfg.name} "
          f"mesh={args.mesh} feed={args.feed} slots={sched.pool.num_slots} "
          f"channel={type(sched.channel).__name__}", flush=True)
    reqs = build_requests(cfg, args.requests, lens, args.max_new,
                          temperature=args.temperature, seed=args.seed)
    prefixes: dict = {}
    resumed: set = set()
    if rank == 0 and args.resume_journal:
        from repro.serve import journal as journal_mod
        entries = journal_mod.replay(args.resume_journal)
        prefixes = journal_mod.resume_scheduler(sched, entries)
        resumed = set(entries)
        print(f"[dist] rank=0 journal: replayed {len(entries)} "
              f"request(s) from {args.resume_journal} "
              f"(requeued {sched.stats.journal_replayed} unfinished)",
              flush=True)
    if rank == 0:
        for r in reqs:
            if r.rid in resumed:    # the journal already owns this rid
                continue
            sched.submit(r)
        try:
            while sched.queue or sched.active or sched.prefilling:
                sched.step()
        except RuntimeError as e:
            # confirmed peer death: make the in-flight state durable
            # before dying so the restarted generation can resume
            if journal is not None:
                journal.record_note("peer_death", error=str(e)[:200])
                journal.close()
            raise
        sched.shutdown()
        results = sched.results
        if prefixes:
            from repro.serve import journal as journal_mod
            results = journal_mod.stitched_results(results, prefixes)
    else:
        if args.feed == "replicated":
            # exercise the dedupe path: the plan's submits must be
            # recognized as already-local copies, not enqueued twice
            for r in reqs:
                sched.submit(r)
        results = sched.run_follower()
    if journal is not None:
        journal.close()
    sched.stats.stop()
    if rank == 0:
        sched.stats.report(prefix="[dist]")
        if arena is not None:
            arena.report(prefix="[dist][arena]")
    if arena is not None:
        arena.close()
    out = {"rank": rank,
           "results": {str(rid): [int(t) for t in toks]
                       for rid, toks in results.items()},
           "stats": sched.stats.as_dict()}
    if arena is not None:
        out["arena"] = arena.snapshot()
    if rank == 0:
        # the gathered per-rank snapshots — host-0's export covers the
        # whole mesh, so one scrape sees every process's counters
        out["mesh_stats"] = {str(r): s
                             for r, s in sched.remote_stats.items()}
    if args.out_json:
        path = args.out_json if rank == 0 \
            else f"{args.out_json}.p{rank}"
        with open(path, "w") as f:
            json.dump(out, f)
        print(f"[dist] rank={rank} wrote {path} "
              f"({len(results)} results)", flush=True)
        if rank == 0:
            from repro.serve import telemetry as telemetry_mod
            with open(path + ".prom", "w") as f:
                f.write(telemetry_mod.scheduler_prometheus(sched))
            print(f"[dist] rank=0 wrote {path}.prom "
                  f"(Prometheus exposition, all ranks)", flush=True)
    if args.num_processes > 1:
        jax.distributed.shutdown()
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    """CLI entry point: spawn mode with ``--procs``, else worker."""
    argv = list(sys.argv[1:] if argv is None else argv)
    args = build_parser().parse_args(argv)
    if args.procs > 0:
        return spawn(args, argv)
    try:
        return run_worker(args)
    except Exception:
        import traceback
        traceback.print_exc()
        # hard-exit: a failed worker must DIE, not hang in jax's
        # atexit distributed-shutdown handshake waiting for the very
        # peers it just lost — the supervisor kills the rest
        sys.stdout.flush()
        sys.stderr.flush()
        os._exit(1)


if __name__ == "__main__":
    sys.exit(main())
