"""Config dataclasses for the repro framework.

Everything in the framework is driven by these configs: model construction
(`repro.models`), sharding rules (`repro.parallel`), the launchers
(`repro.launch`) and the dry-run/roofline tooling.

Configs are plain frozen dataclasses (no external deps) so they can be
constructed in tests, serialized into checkpoints, and diffed in logs.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration."""

    num_experts: int = 8
    top_k: int = 2
    num_shared_experts: int = 0      # deepseek-style always-on shared experts
    d_expert: int = 0                # per-expert hidden dim (0 -> use d_ff)
    capacity_factor: float = 1.25    # tokens per expert = cf * tokens * k / E
    first_k_dense: int = 0           # deepseek: first k layers use dense FFN
    dense_d_ff: int = 0              # d_ff of those dense layers
    moe_period: int = 1              # MoE every `period` layers (jamba: 2)
    router_aux_weight: float = 0.01  # load-balancing aux loss weight
    router_z_weight: float = 1e-4    # router z-loss weight
    # dispatch algorithm: 'einsum' (GShard one-hot matmuls, baseline) or
    # 'scatter' (beyond-paper: indexed scatter/gather — no O(T*E*C)
    # dispatch tensors, no dispatch matmul flops)
    dispatch: str = "einsum"


@dataclass(frozen=True)
class MambaConfig:
    """Mamba-1 selective SSM block configuration."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block stack configuration (mLSTM/sLSTM interleave)."""

    # Pattern string over layers, cycled: 'm' = mLSTM, 's' = sLSTM.
    pattern: str = "msmmmms"
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 1.3333
    conv_kernel: int = 4
    chunk_size: int = 64             # chunkwise-parallel mLSTM chunk


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB config ([vlm]/[audio] archs).

    The backbone consumes precomputed patch/frame embeddings; `input_specs`
    produces ShapeDtypeStructs for them.  No frontend weights are built.
    """

    kind: str = "none"               # 'none' | 'vision' | 'audio'
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # qwen2-vl M-RoPE
    num_codebooks: int = 4           # musicgen EnCodec streams (stub: folded)


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"            # dense|moe|ssm|vlm|hybrid|audio|cyclegan

    num_layers: int = 4
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0                # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 32000

    # attention details
    qk_norm: bool = False            # qwen3
    qkv_bias: bool = False           # qwen1.5/2.5
    rope_theta: float = 10_000.0
    use_mrope: bool = False          # qwen2-vl
    # 'auto': flash-style chunked online-softmax attention for long seqs
    # (the pure-JAX twin of kernels/flash_attention.py), dense for short.
    attn_impl: str = "auto"          # auto | dense | chunked
    attn_chunk: int = 1024           # KV chunk for the chunked impl

    # block pattern for hybrid archs; cycled over layers.
    # 'a' = attention block, 'M' = mamba block. Dense/MoE archs use all-'a'.
    block_pattern: str = "a"

    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    frontend: FrontendConfig = field(default_factory=FrontendConfig)

    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # --- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind, cycling `block_pattern`."""
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        if i < self.moe.first_k_dense:
            return False
        return (i % self.moe.moe_period) == (self.moe.moe_period - 1) \
            if self.moe.moe_period > 1 else True

    # --- parameter accounting (used for MODEL_FLOPS = 6*N*D) ---------------
    def _attn_params(self) -> int:
        hd = self.resolved_head_dim
        p = self.d_model * (self.q_dim + 2 * self.kv_dim)      # wq wk wv
        p += self.q_dim * self.d_model                          # wo
        if self.qkv_bias:
            p += self.q_dim + 2 * self.kv_dim
        if self.qk_norm:
            p += 2 * hd
        return p

    def _dense_ffn_params(self, d_ff: int) -> int:
        # SwiGLU: wi, wg: d_model x d_ff ; wo: d_ff x d_model
        return 3 * self.d_model * d_ff

    def _moe_ffn_params(self, active_only: bool) -> int:
        m = self.moe
        d_e = m.d_expert or self.d_ff
        per_expert = 3 * self.d_model * d_e
        router = self.d_model * m.num_experts
        shared = m.num_shared_experts * per_expert
        routed = (m.top_k if active_only else m.num_experts) * per_expert
        return router + shared + routed

    def _mamba_params(self) -> int:
        mc = self.mamba or MambaConfig()
        d_in = mc.expand * self.d_model
        dt_rank = mc.dt_rank or math.ceil(self.d_model / 16)
        p = self.d_model * 2 * d_in                 # in_proj (x and z)
        p += d_in * mc.d_conv                       # depthwise conv
        p += d_in * (dt_rank + 2 * mc.d_state)      # x -> (dt, B, C)
        p += dt_rank * d_in + d_in                  # dt proj + bias
        p += d_in * mc.d_state + d_in               # A_log, D
        p += d_in * self.d_model                    # out_proj
        return p

    def _xlstm_params(self) -> int:
        xc = self.xlstm or XLSTMConfig()
        # mLSTM block: up-proj 2x (pf*d), qkv (pf*d)^2-ish, gates, down-proj
        d = self.d_model
        dm = int(xc.proj_factor_mlstm * d)
        m = 2 * d * dm + 3 * dm * dm // 4 + 3 * dm + dm * d
        ds = d
        s = 4 * (ds * ds + ds * ds // 4) + int(xc.proj_factor_slstm * d) * d * 2
        n_m = sum(1 for i in range(self.num_layers)
                  if xc.pattern[i % len(xc.pattern)] == "m")
        n_s = self.num_layers - n_m
        return n_m * m + n_s * s

    def param_count(self, active_only: bool = False) -> int:
        """Total (or active) parameter count, excluding frontend stubs."""
        n = self.vocab_size * self.d_model                      # embed
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model                 # lm head
        n += self.d_model                                       # final norm
        if self.family == "ssm" and self.xlstm is not None:
            return n + self._xlstm_params()
        for i, kind in enumerate(self.layer_kinds()):
            n += 2 * self.d_model                               # 2 norms
            if kind == "a":
                n += self._attn_params()
            elif kind == "M":
                n += self._mamba_params()
            if kind == "a" or self.family == "hybrid":
                if self.is_moe_layer(i):
                    n += self._moe_ffn_params(active_only)
                else:
                    d_ff = self.d_ff
                    if self.moe is not None and i < self.moe.first_k_dense:
                        d_ff = self.moe.dense_d_ff or self.d_ff
                    if d_ff:
                        n += self._dense_ffn_params(d_ff)
        return n


# ---------------------------------------------------------------------------
# Shapes (assigned input-shape set for the LM family)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # 'train' | 'prefill' | 'decode'


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


# ---------------------------------------------------------------------------
# Training / LTFB / mesh configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adam"               # adam | adamw | adafactor | sgd
    lr: float = 1e-3                 # paper: Adam, initial lr 0.001
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip_norm: float = 1.0
    warmup_steps: int = 100
    schedule: str = "constant"       # constant | cosine | linear
    total_steps: int = 10_000
    # moment dtype: 'float32' for fidelity, 'bfloat16' to halve optimizer HBM
    moment_dtype: str = "float32"


@dataclass(frozen=True)
class LTFBConfig:
    """Paper §III-C — Let a Thousand Flowers Bloom."""

    num_trainers: int = 4
    interval: int = 100              # mini-batch steps between tournaments
    metric: str = "val_loss"         # lower is better
    exchange: str = "full"           # 'full' | 'generator' (GANs)
    tournament_batches: int = 4      # batches of tournament data per eval
    # PBT-style hyperparameter exploration on tournament loss ties
    perturb_hparams: bool = True
    perturb_factor: float = 1.2
    # straggler mitigation: a trainer whose partner misses the deadline
    # self-pairs (trains through) instead of blocking the round.
    straggler_timeout_s: float = 30.0


@dataclass(frozen=True)
class MeshConfig:
    # axis sizes; trainer axis only used by LTFB meshes
    pod: int = 1
    data: int = 16
    model: int = 16
    # parallelism toggles
    fsdp: bool = True                # shard params/opt over data axis (ZeRO-3)
    seq_parallel: bool = True        # shard activations' seq dim on model ax.
    remat: str = "full"              # 'none' | 'full' | 'selective'
    # beyond-paper: int8 error-feedback compression on the pod (DCN) axis
    compress_pod_grads: bool = False


@dataclass(frozen=True)
class DataConfig:
    dataset: str = "synthetic_tokens"   # synthetic_tokens | jag
    samples_per_file: int = 1_000       # paper: 1000-sample HDF5 bundles
    num_files: int = 100
    store_mode: str = "preload"         # preload | dynamic | none
    prefetch_depth: int = 2
    seed: int = 0


@dataclass(frozen=True)
class RunConfig:
    """Top-level config: one of these per experiment / launch."""

    model: ModelConfig = field(default_factory=ModelConfig)
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    ltfb: Optional[LTFBConfig] = None
    mesh: MeshConfig = field(default_factory=MeshConfig)
    data: DataConfig = field(default_factory=DataConfig)
    batch_size: int = 128            # paper: mini-batch 128
    steps: int = 1_000
    eval_every: int = 100
    checkpoint_every: int = 500
    checkpoint_dir: str = "/tmp/repro_ckpt"
    seed: int = 0


def replace(cfg, **kw):
    """dataclasses.replace that tolerates nested dotted keys ('moe.top_k')."""
    direct = {k: v for k, v in kw.items() if "." not in k}
    nested = {k: v for k, v in kw.items() if "." in k}
    out = dataclasses.replace(cfg, **direct) if direct else cfg
    for k, v in nested.items():
        head, rest = k.split(".", 1)
        sub = getattr(out, head)
        out = dataclasses.replace(out, **{head: replace(sub, **{rest: v})})
    return out
