"""qwen2-vl-7b [vlm] — arXiv:2409.12191 (M-RoPE, dynamic resolution).

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064. BACKBONE ONLY:
the vision frontend is a stub; input_specs() provides precomputed patch
embeddings plus 3-component M-RoPE position ids.
"""
from repro.configs.base import FrontendConfig, ModelConfig, replace

ARCH_ID = "qwen2-vl-7b"

FULL = ModelConfig(
    name=ARCH_ID,
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    use_mrope=True,
    rope_theta=1_000_000.0,
    frontend=FrontendConfig(kind="vision", mrope_sections=(16, 24, 24)),
)

SMOKE = replace(
    FULL, name=ARCH_ID + "-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=256,
    frontend=FrontendConfig(kind="vision", mrope_sections=(4, 2, 2)),
)
