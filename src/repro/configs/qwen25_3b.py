"""qwen2.5-3b [dense] — hf:Qwen/Qwen2.5-3B family (GQA, QKV bias).

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936.
"""
from repro.configs.base import ModelConfig, replace

ARCH_ID = "qwen2.5-3b"

FULL = ModelConfig(
    name=ARCH_ID,
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    d_ff=11008,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)

SMOKE = replace(
    FULL, name=ARCH_ID + "-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=256,
)
