"""granite-8b [dense] — arXiv:2405.04324 (Granite Code, llama arch).

36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.
"""
from repro.configs.base import ModelConfig, replace

ARCH_ID = "granite-8b"

FULL = ModelConfig(
    name=ARCH_ID,
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    rope_theta=10_000_000.0,
)

SMOKE = replace(
    FULL, name=ARCH_ID + "-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=256,
)
