"""deepseek-moe-16b [moe] — arXiv:2401.06066.

28L d_model=2048 16H (GQA kv=16 = MHA) d_ff=1408 vocab=102400,
MoE: 2 shared + 64 routed experts, top-6, fine-grained; first layer dense.
"""
from repro.configs.base import ModelConfig, MoEConfig, replace

ARCH_ID = "deepseek-moe-16b"

FULL = ModelConfig(
    name=ARCH_ID,
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    moe=MoEConfig(
        num_experts=64, top_k=6, num_shared_experts=2, d_expert=1408,
        first_k_dense=1, dense_d_ff=10944,
    ),
)

SMOKE = replace(
    FULL, name=ARCH_ID + "-smoke",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, d_ff=48,
    vocab_size=256,
    moe=MoEConfig(num_experts=8, top_k=3, num_shared_experts=1, d_expert=48,
                  first_k_dense=1, dense_d_ff=128),
)
