"""qwen3-0.6b [dense] — hf:Qwen/Qwen3-0.6B family (qk_norm, GQA).

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936; head_dim=128
(qwen3 uses explicit head_dim larger than d_model/num_heads); tied embeds.
"""
from repro.configs.base import ModelConfig, replace

ARCH_ID = "qwen3-0.6b"

FULL = ModelConfig(
    name=ARCH_ID,
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)

SMOKE = replace(
    FULL, name=ARCH_ID + "-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
)
