"""phi3.5-moe-42b-a6.6b [moe] — hf:microsoft/Phi-3.5-MoE-instruct.

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16 experts top-2.
"""
from repro.configs.base import ModelConfig, MoEConfig, replace

ARCH_ID = "phi3.5-moe-42b-a6.6b"

FULL = ModelConfig(
    name=ARCH_ID,
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    moe=MoEConfig(num_experts=16, top_k=2),
    rope_theta=10_000.0,
)

# Reduced same-family config for CPU smoke tests: small width, few experts.
SMOKE = replace(
    FULL, name=ARCH_ID + "-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=96,
    vocab_size=256, moe=MoEConfig(num_experts=4, top_k=2),
)
