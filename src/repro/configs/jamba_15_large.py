"""jamba-1.5-large-398b [hybrid] — arXiv:2403.19887 (Mamba+attn 1:7, MoE).

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Block pattern: 1 attention : 7 mamba per 8-layer period; MoE every 2nd
layer (jamba convention). Hybrid -> runs long_500k (attention KV only on
every 8th layer; mamba state O(1)).
"""
from repro.configs.base import MambaConfig, ModelConfig, MoEConfig, replace

ARCH_ID = "jamba-1.5-large-398b"

FULL = ModelConfig(
    name=ARCH_ID,
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    block_pattern="MMMMaMMM",       # attn at position 4 of each 8-layer period
    moe=MoEConfig(num_experts=16, top_k=2, moe_period=2),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
)

SMOKE = replace(
    FULL, name=ARCH_ID + "-smoke",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, d_ff=96,
    vocab_size=256, block_pattern="MMaM",
    moe=MoEConfig(num_experts=4, top_k=2, moe_period=2),
    mamba=MambaConfig(d_state=8, d_conv=4, expand=2),
)
