"""musicgen-medium [audio] — arXiv:2306.05284 (decoder over EnCodec tokens).

48L d_model=1536 24H (kv=24 = MHA) d_ff=6144 vocab=2048. BACKBONE ONLY:
the EnCodec frontend is a stub; input_specs() provides token ids in the
(folded) codebook-interleaved stream plus precomputed conditioning frames.
"""
from repro.configs.base import FrontendConfig, ModelConfig, replace

ARCH_ID = "musicgen-medium"

FULL = ModelConfig(
    name=ARCH_ID,
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    frontend=FrontendConfig(kind="audio", num_codebooks=4),
)

SMOKE = replace(
    FULL, name=ARCH_ID + "-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=64,
    frontend=FrontendConfig(kind="audio", num_codebooks=2),
)
