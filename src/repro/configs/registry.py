"""Architecture registry: ``--arch <id>`` resolution.

Each assigned architecture lives in its own module with FULL (exact
literature config) and SMOKE (reduced same-family config for CPU tests).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.configs import (
    codeqwen15,
    deepseek_moe,
    granite_8b,
    icf_cyclegan,
    jamba_15_large,
    musicgen_medium,
    phi35_moe,
    qwen2_vl,
    qwen25_3b,
    qwen3_06b,
    xlstm_125m,
)
from repro.configs.base import SHAPE_BY_NAME, SHAPES, ModelConfig, ShapeConfig

_MODULES = (
    phi35_moe,
    deepseek_moe,
    codeqwen15,
    qwen3_06b,
    qwen25_3b,
    granite_8b,
    xlstm_125m,
    qwen2_vl,
    jamba_15_large,
    musicgen_medium,
)

ARCHS: Dict[str, object] = {m.ARCH_ID: m for m in _MODULES}
ARCHS[icf_cyclegan.ARCH_ID] = icf_cyclegan

# LM architectures participating in the arch x shape dry-run grid.
LM_ARCH_IDS: Tuple[str, ...] = tuple(m.ARCH_ID for m in _MODULES)

# Families that support the long_500k sub-quadratic decode shape.
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def get_config(arch_id: str, smoke: bool = False):
    if arch_id not in ARCHS:
        raise KeyError(
            f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    mod = ARCHS[arch_id]
    return mod.SMOKE if smoke else mod.FULL


def shapes_for(cfg: ModelConfig) -> List[ShapeConfig]:
    """Applicable input shapes for an architecture (skips documented in
    DESIGN.md section 4): long_500k only for sub-quadratic families."""
    out = []
    for s in SHAPES:
        if s.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
            continue
        out.append(s)
    return out


def dryrun_cells() -> List[Tuple[str, str]]:
    """All (arch_id, shape_name) dry-run cells."""
    cells = []
    for arch_id in LM_ARCH_IDS:
        cfg = get_config(arch_id)
        for s in shapes_for(cfg):
            cells.append((arch_id, s.name))
    return cells


def get_shape(name: str) -> ShapeConfig:
    return SHAPE_BY_NAME[name]
