"""xlstm-125m [ssm] — arXiv:2405.04517 (sLSTM + mLSTM blocks).

12L d_model=768 4H vocab=50304, d_ff=0 (xLSTM blocks carry their own
projections). Pattern: mostly mLSTM with interleaved sLSTM (xLSTM[3:1]).
Recurrent state is O(1) per token -> runs long_500k.
"""
from repro.configs.base import ModelConfig, XLSTMConfig, replace

ARCH_ID = "xlstm-125m"

FULL = ModelConfig(
    name=ARCH_ID,
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    # chunk_size=256 chosen by the §Perf hillclimb: the (B,H,DH,DH)
    # matrix-memory carry is read+written once per chunk, so larger
    # chunks divide that traffic (baseline 64 -> iteration 1: 256).
    xlstm=XLSTMConfig(pattern="mmms", chunk_size=256),
    tie_embeddings=True,
)

SMOKE = replace(
    FULL, name=ARCH_ID + "-smoke",
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, vocab_size=256,
    xlstm=XLSTMConfig(pattern="ms", chunk_size=16),
)
