"""codeqwen1.5-7b [dense] — hf:Qwen/CodeQwen1.5-7B (qwen1.5 arch).

32L d_model=4096 32H (GQA kv=32 = MHA) d_ff=13440 vocab=92416, QKV bias.
"""
from repro.configs.base import ModelConfig, replace

ARCH_ID = "codeqwen1.5-7b"

FULL = ModelConfig(
    name=ARCH_ID,
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=13440,
    vocab_size=92416,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

SMOKE = replace(
    FULL, name=ARCH_ID + "-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=256,
)
