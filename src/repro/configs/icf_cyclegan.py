"""The paper's own model: CycleGAN surrogate for ICF (Section II-D).

Forward model F: R^5 -> R^20 (latent of a multimodal autoencoder over
15 scalars + 12 x 64x64 X-ray images), adversarial latent discriminator
D: R^20 -> {0,1}, inverse model G: R^20 -> R^5 with G(F(x)) ~= x.
All components are fully-connected networks (paper: "standard
fully-connected neural network"); exact widths follow OSTI ref [14] in
spirit and are config-driven here.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

ARCH_ID = "icf-cyclegan"


@dataclass(frozen=True)
class CycleGANConfig:
    name: str = ARCH_ID
    family: str = "cyclegan"

    # JAG sample modality structure (paper Section II-B)
    input_dim: int = 5               # 5-D experiment parameter space
    num_scalars: int = 15            # 15 scalar observables
    num_images: int = 12             # 3 lines of sight x 4 channels
    image_size: int = 64             # 64 x 64 pixels
    latent_dim: int = 20             # 20-D latent space

    # network widths (fully connected)
    fwd_hidden: Tuple[int, ...] = (64, 128, 64)      # F: 5 -> 20
    inv_hidden: Tuple[int, ...] = (64, 128, 64)      # G: 20 -> 5
    disc_hidden: Tuple[int, ...] = (64, 64)          # D: 20 -> 1
    enc_hidden: Tuple[int, ...] = (1024, 256)        # AE encoder -> 20
    dec_hidden: Tuple[int, ...] = (256, 1024)        # AE decoder 20 -> out

    # loss weights (MAE everywhere per paper; adversarial on latent)
    w_forward: float = 1.0           # | F(x) - E(y) | internal consistency
    w_cycle: float = 1.0             # | G(F(x)) - x | self consistency
    w_adv: float = 0.1               # adversarial (physical consistency)
    w_recon: float = 1.0             # AE reconstruction

    dtype: str = "float32"           # paper: single precision

    @property
    def output_dim(self) -> int:
        return self.num_scalars + self.num_images * self.image_size ** 2

    def param_count(self) -> int:
        def mlp(dims):
            return sum(dims[i] * dims[i + 1] + dims[i + 1]
                       for i in range(len(dims) - 1))
        d_out = self.output_dim
        n = mlp((self.input_dim, *self.fwd_hidden, self.latent_dim))
        n += mlp((self.latent_dim, *self.inv_hidden, self.input_dim))
        n += mlp((self.latent_dim, *self.disc_hidden, 1))
        n += mlp((d_out, *self.enc_hidden, self.latent_dim))
        n += mlp((self.latent_dim, *self.dec_hidden, d_out))
        return n


FULL = CycleGANConfig()

# Reduced config for fast CPU tests: 8x8 images, narrow nets.
SMOKE = CycleGANConfig(
    name=ARCH_ID + "-smoke",
    image_size=8,
    fwd_hidden=(32, 32), inv_hidden=(32, 32), disc_hidden=(32,),
    enc_hidden=(64,), dec_hidden=(64,),
)
