"""Host-orchestrated LTFB population trainer (paper §III-C, Figs. 6/11-13).

Drives K trainers with their own data partitions, optimizer states and
hyperparameters; between tournaments trainers are fully independent (on
real hardware each runs on its own mesh slice — here they time-share the
host, and per-trainer step counts/wall-times are accounted separately).

Features beyond the basic loop (all paper-motivated):
  * generator-only exchange for GANs (``scope="generator"``)
  * PBT-style hyperparameter perturbation on model adoption [20]
  * straggler mitigation: late/dead trainers self-pair for the round
  * checkpoint/restart of the whole population (fault tolerance)
  * elastic rescale: grow/shrink K, re-partitioning data and cloning
    tournament winners into new slots
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core import ltfb

Params = Any


@dataclass
class TrainerFns:
    """Model-agnostic plumbing for one trainer.

    init(seed) -> (params, opt_state, hparams)
    train_step(params, opt_state, batch, hparams)
        -> (params, opt_state, metrics)   [jitted by caller]
    metric(params, batch) -> scalar       [tournament metric, lower=better]
    """

    init: Callable
    train_step: Callable
    metric: Callable


@dataclass
class TrainerState:
    params: Params
    opt_state: Any
    hparams: Dict[str, float]
    loader: Callable[[], Dict[str, np.ndarray]]
    tournament_batches: List[Dict[str, np.ndarray]]
    alive: bool = True
    steps: int = 0
    train_seconds: float = 0.0
    wins: int = 0           # pairwise comparisons this trainer's model won
    adoptions: int = 0      # times this trainer adopted a partner's model
    history: List[float] = field(default_factory=list)


class Population:
    def __init__(self, fns: TrainerFns, loaders: Sequence[Callable],
                 tournament_batches: Sequence[List[dict]],
                 scope: str = "full", seed: int = 0,
                 perturb_factor: float = 1.2,
                 perturb_hparams: bool = True):
        self.fns = fns
        self.scope = scope
        self.seed = seed
        self.perturb_factor = perturb_factor
        self.perturb_hparams = perturb_hparams
        self.round = 0
        self.rng = np.random.default_rng(seed)
        self.trainers: List[TrainerState] = []
        for i, (loader, tb) in enumerate(zip(loaders, tournament_batches)):
            params, opt_state, hparams = fns.init(seed + 1000 * i + 1)
            self.trainers.append(TrainerState(params, opt_state, hparams,
                                              loader, list(tb)))

    # -- independent training ------------------------------------------------
    def train_round(self, steps: int) -> Dict[str, Any]:
        """Each alive trainer runs `steps` mini-batch steps independently."""
        metrics = []
        for t in self.trainers:
            if not t.alive:
                continue
            t0 = time.perf_counter()
            m = None
            for _ in range(steps):
                batch = t.loader()
                t.params, t.opt_state, m = self.fns.train_step(
                    t.params, t.opt_state, batch, t.hparams)
                t.steps += 1
            t.train_seconds += time.perf_counter() - t0
            metrics.append(m)
        return {"last_metrics": metrics}

    # -- tournament ------------------------------------------------------------
    def _metric_on(self, idx: int, params: Params) -> float:
        vals = [float(self.fns.metric(params, b))
                for b in self.trainers[idx].tournament_batches]
        return float(np.mean(vals))

    def tournament(self, executor=None) -> Dict[str, Any]:
        """One tournament round.

        With ``executor`` (a ``concurrent.futures`` executor), metric
        evaluation is overlapped with the partner exchange
        (:func:`repro.core.ltfb.host_tournament_async`).
        """
        alive = [t.alive for t in self.trainers]
        partner = ltfb.random_pairing(len(self.trainers), self.round,
                                      self.seed, alive)
        pop = [t.params for t in self.trainers]
        winners, log = ltfb.host_tournament_async(
            pop, self._metric_on, partner, self.scope, executor)
        for i, j, m_local, m_other in log["metrics"]:
            winner_idx = j if m_other < m_local else i
            self.trainers[winner_idx].wins += 1
        for i, t in enumerate(self.trainers):
            adopted = winners[i] is not t.params
            t.params = winners[i]
            if adopted:
                t.adoptions += 1
                if self.perturb_hparams:
                    f = self.perturb_factor if self.rng.random() < 0.5 \
                        else 1.0 / self.perturb_factor
                    t.hparams = {k: v * f if k == "lr" else v
                                 for k, v in t.hparams.items()}
        self.round += 1
        log["partner"] = partner.tolist()
        return log

    def run(self, rounds: int, steps_per_round: int,
            eval_batch: Optional[dict] = None) -> List[float]:
        """Full LTFB loop; returns best-trainer validation trace."""
        trace = []
        for _ in range(rounds):
            self.train_round(steps_per_round)
            self.tournament()
            if eval_batch is not None:
                best = self.best_metric(eval_batch)
                trace.append(best)
                for t in self.trainers:
                    t.history.append(best)
        return trace

    def best_metric(self, batch: dict) -> float:
        return min(float(self.fns.metric(t.params, batch))
                   for t in self.trainers if t.alive)

    def best_params(self, batch: dict) -> Params:
        vals = [(float(self.fns.metric(t.params, batch)), i)
                for i, t in enumerate(self.trainers) if t.alive]
        return self.trainers[min(vals)[1]].params

    # -- fault tolerance / elasticity -----------------------------------------
    def fail(self, idx: int):
        """Simulate a node failure: trainer drops out of tournaments."""
        self.trainers[idx].alive = False

    def recover(self, idx: int, from_best_of: Optional[dict] = None):
        """Restart a failed trainer, optionally cloning the current best."""
        t = self.trainers[idx]
        t.alive = True
        if from_best_of is not None:
            t.params = self.best_params(from_best_of)

    def resize(self, new_k: int, loaders: Sequence[Callable],
               tournament_batches: Sequence[List[dict]],
               clone_batch: Optional[dict] = None):
        """Elastic rescale to `new_k` trainers."""
        if new_k < len(self.trainers):
            # keep the best new_k trainers
            if clone_batch is not None:
                scored = sorted(
                    (float(self.fns.metric(t.params, clone_batch)), i)
                    for i, t in enumerate(self.trainers))
                keep = sorted(i for _, i in scored[:new_k])
            else:
                keep = list(range(new_k))
            self.trainers = [self.trainers[i] for i in keep]
        else:
            src = self.best_params(clone_batch) if clone_batch is not None \
                else self.trainers[0].params
            for i in range(len(self.trainers), new_k):
                params, opt_state, hparams = self.fns.init(
                    self.seed + 7777 * i)
                st = TrainerState(params, opt_state, hparams,
                                  loaders[i], list(tournament_batches[i]))
                st.params = src          # warm-start from the current best
                self.trainers.append(st)
        for i, t in enumerate(self.trainers):
            t.loader = loaders[i]
            t.tournament_batches = list(tournament_batches[i])

    # -- checkpointing ----------------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {
            "round": self.round,
            "seed": self.seed,
            "scope": self.scope,
            "trainers": [
                {"params": t.params, "opt_state": t.opt_state,
                 "hparams": t.hparams, "steps": t.steps, "alive": t.alive,
                 "wins": t.wins, "adoptions": t.adoptions}
                for t in self.trainers],
        }

    def load_state_dict(self, state: Dict[str, Any]):
        self.round = state["round"]
        assert len(state["trainers"]) == len(self.trainers), \
            "use resize() for elastic restore"
        for t, s in zip(self.trainers, state["trainers"]):
            t.params = s["params"]
            t.opt_state = s["opt_state"]
            t.hparams = dict(s["hparams"])
            t.steps = int(s["steps"])
            t.alive = bool(s["alive"])
            t.wins = int(s.get("wins", 0))
            t.adoptions = int(s.get("adoptions", 0))
