"""Host-orchestrated LTFB population trainer (paper §III-C, Figs. 6/11-13).

Drives K trainers with their own data partitions, optimizer states and
hyperparameters; between tournaments trainers are fully independent (on
real hardware each runs on its own mesh slice — here they time-share the
host, and per-trainer step counts/wall-times are accounted separately).

Features beyond the basic loop (all paper-motivated):
  * generator-only exchange for GANs (``scope="generator"``)
  * PBT-style hyperparameter perturbation on model adoption [20]
  * straggler mitigation: late/dead trainers self-pair for the round
  * checkpoint/restart of the whole population (fault tolerance)
  * elastic rescale: grow/shrink K, re-partitioning data and cloning
    tournament winners into new slots
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core import ltfb

Params = Any


@dataclass
class TrainerFns:
    """Model-agnostic plumbing for one trainer.

    init(seed) -> (params, opt_state, hparams)
    train_step(params, opt_state, batch, hparams)
        -> (params, opt_state, metrics)   [jitted by caller]
    metric(params, batch) -> scalar       [tournament metric, lower=better]
    """

    init: Callable
    train_step: Callable
    metric: Callable


@dataclass
class TrainerState:
    params: Params
    opt_state: Any
    hparams: Dict[str, float]
    loader: Callable[[], Dict[str, np.ndarray]]
    tournament_batches: List[Dict[str, np.ndarray]]
    alive: bool = True
    steps: int = 0
    train_seconds: float = 0.0
    data_wait_seconds: float = 0.0   # slice of train_seconds spent in loader()
    wins: int = 0           # pairwise comparisons this trainer's model won
    adoptions: int = 0      # times this trainer adopted a partner's model
    history: List[float] = field(default_factory=list)
    # telemetry: last train-step metrics dict and last tournament metric
    last_metrics: Dict[str, float] = field(default_factory=dict)
    tournament_metric: Optional[float] = None


class Population:
    def __init__(self, fns: TrainerFns, loaders: Sequence[Callable],
                 tournament_batches: Sequence[List[dict]],
                 scope: str = "full", seed: int = 0,
                 perturb_factor: float = 1.2,
                 perturb_hparams: bool = True):
        self.fns = fns
        self.scope = scope
        self.seed = seed
        self.perturb_factor = perturb_factor
        self.perturb_hparams = perturb_hparams
        self.round = 0
        self.rng = np.random.default_rng(seed)
        # optional repro.train.telemetry.TrainTelemetry (set by the
        # orchestrator/launcher); None keeps the hot loop span-free
        self.telemetry = None
        self.trainers: List[TrainerState] = []
        for i, (loader, tb) in enumerate(zip(loaders, tournament_batches)):
            params, opt_state, hparams = fns.init(seed + 1000 * i + 1)
            self.trainers.append(TrainerState(params, opt_state, hparams,
                                              loader, list(tb)))

    # -- independent training ------------------------------------------------
    def train_round(self, steps: int) -> Dict[str, Any]:
        """Each alive trainer runs `steps` mini-batch steps independently.

        Wall time is attributed per trainer: ``data_wait_seconds`` is
        the slice of ``train_seconds`` spent blocked in ``loader()``
        (prefetch stall), the rest is compute.  With ``telemetry`` set,
        each step emits ``data_wait`` + ``step`` spans on the trainer's
        trace row.
        """
        metrics = []
        tel = self.telemetry
        for i, t in enumerate(self.trainers):
            if not t.alive:
                continue
            t0 = time.perf_counter()
            wait = 0.0
            m = None
            for _ in range(steps):
                w0 = time.perf_counter()
                batch = t.loader()
                w1 = time.perf_counter()
                wait += w1 - w0
                t.params, t.opt_state, m = self.fns.train_step(
                    t.params, t.opt_state, batch, t.hparams)
                t.steps += 1
                if tel is not None:
                    tel.trainer_span("data_wait", i, w0, w1)
                    tel.trainer_span("step", i, w1, time.perf_counter(),
                                     step=t.steps)
            t1 = time.perf_counter()
            t.train_seconds += t1 - t0
            t.data_wait_seconds += wait
            if m is not None:
                # forces the async dispatch, making the timing honest
                t.last_metrics = {k: float(v) for k, v in m.items()}
            if tel is not None:
                tel.trainer_span("train_round", i, t0, t1, phase=None,
                                 round=self.round, steps=steps)
                tel.add_phase("data_wait", wait)
                tel.add_phase("compute", (t1 - t0) - wait)
            metrics.append(m)
        return {"last_metrics": metrics}

    # -- tournament ------------------------------------------------------------
    def _metric_on(self, idx: int, params: Params) -> float:
        tel = self.telemetry
        t0 = time.perf_counter()
        vals = [float(self.fns.metric(params, b))
                for b in self.trainers[idx].tournament_batches]
        if tel is not None:
            tel.trainer_span("tournament_eval", idx, t0,
                             time.perf_counter(), phase="tournament_eval",
                             batches=len(vals))
        return float(np.mean(vals))

    def tournament(self, executor=None) -> Dict[str, Any]:
        """One tournament round.

        With ``executor`` (a ``concurrent.futures`` executor), metric
        evaluation is overlapped with the partner exchange
        (:func:`repro.core.ltfb.host_tournament_async`).
        """
        t0 = time.perf_counter()
        alive = [t.alive for t in self.trainers]
        partner = ltfb.random_pairing(len(self.trainers), self.round,
                                      self.seed, alive)
        pop = [t.params for t in self.trainers]
        winners, log = ltfb.host_tournament_async(
            pop, self._metric_on, partner, self.scope, executor,
            telemetry=self.telemetry)
        for i, j, m_local, m_other in log["metrics"]:
            winner_idx = j if m_other < m_local else i
            self.trainers[winner_idx].wins += 1
            self.trainers[i].tournament_metric = m_local
        for i, t in enumerate(self.trainers):
            adopted = winners[i] is not t.params
            t.params = winners[i]
            if adopted:
                t.adoptions += 1
                if self.perturb_hparams:
                    f = self.perturb_factor if self.rng.random() < 0.5 \
                        else 1.0 / self.perturb_factor
                    t.hparams = {k: v * f if k == "lr" else v
                                 for k, v in t.hparams.items()}
        self.round += 1
        log["partner"] = partner.tolist()
        log["seconds"] = time.perf_counter() - t0
        log["pairing_seed"] = self.seed
        if self.telemetry is not None:
            self.telemetry.span("tournament", t0, time.perf_counter(),
                                round=self.round - 1,
                                exchanged=log["exchanged"],
                                exchange_bytes=log["exchange_bytes"])
        return log

    def run(self, rounds: int, steps_per_round: int,
            eval_batch: Optional[dict] = None) -> List[float]:
        """Full LTFB loop; returns best-trainer validation trace."""
        trace = []
        for _ in range(rounds):
            self.train_round(steps_per_round)
            self.tournament()
            if eval_batch is not None:
                best = self.best_metric(eval_batch)
                trace.append(best)
                for t in self.trainers:
                    t.history.append(best)
        return trace

    def best_metric(self, batch: dict) -> float:
        return min(float(self.fns.metric(t.params, batch))
                   for t in self.trainers if t.alive)

    def best_index(self, batch: dict) -> int:
        vals = [(float(self.fns.metric(t.params, batch)), i)
                for i, t in enumerate(self.trainers) if t.alive]
        return min(vals)[1]

    def best_params(self, batch: dict) -> Params:
        return self.trainers[self.best_index(batch)].params

    # -- fault tolerance / elasticity -----------------------------------------
    def fail(self, idx: int):
        """Simulate a node failure: trainer drops out of tournaments."""
        self.trainers[idx].alive = False

    def recover(self, idx: int,
                from_best_of: Optional[dict] = None) -> Optional[int]:
        """Restart a failed trainer, optionally cloning the current best.

        Returns the trainer index the weights were cloned from (None
        when the trainer resumed with its own stale weights) — the
        genealogy needs the ancestry edge.
        """
        t = self.trainers[idx]
        t.alive = True
        if from_best_of is not None:
            src = self.best_index(from_best_of)
            t.params = self.trainers[src].params
            return src
        return None

    def resize(self, new_k: int, loaders: Sequence[Callable],
               tournament_batches: Sequence[List[dict]],
               clone_batch: Optional[dict] = None) -> Dict[str, Any]:
        """Elastic rescale to `new_k` trainers.

        Returns a provenance dict for the genealogy: ``kept`` maps each
        surviving slot to its pre-rescale trainer index, ``cloned``
        lists the new slots (grow), ``clone_src`` is the pre-rescale
        index the clones warm-started from.
        """
        old_k = len(self.trainers)
        info: Dict[str, Any] = {"from_k": old_k, "to_k": new_k,
                                "cloned": [], "clone_src": None}
        if new_k < old_k:
            # keep the best new_k trainers
            if clone_batch is not None:
                scored = sorted(
                    (float(self.fns.metric(t.params, clone_batch)), i)
                    for i, t in enumerate(self.trainers))
                keep = sorted(i for _, i in scored[:new_k])
            else:
                keep = list(range(new_k))
            self.trainers = [self.trainers[i] for i in keep]
            info["kept"] = keep
        else:
            if clone_batch is not None:
                scored = sorted(
                    (float(self.fns.metric(t.params, clone_batch)), i)
                    for i, t in enumerate(self.trainers) if t.alive)
                src_idx = scored[0][1]
            else:
                src_idx = 0
            src = self.trainers[src_idx].params
            for i in range(old_k, new_k):
                params, opt_state, hparams = self.fns.init(
                    self.seed + 7777 * i)
                st = TrainerState(params, opt_state, hparams,
                                  loaders[i], list(tournament_batches[i]))
                st.params = src          # warm-start from the current best
                self.trainers.append(st)
            info["kept"] = list(range(old_k))
            info["cloned"] = list(range(old_k, new_k))
            info["clone_src"] = src_idx
        for i, t in enumerate(self.trainers):
            t.loader = loaders[i]
            t.tournament_batches = list(tournament_batches[i])
        return info

    # -- checkpointing ----------------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {
            "round": self.round,
            "seed": self.seed,
            "scope": self.scope,
            "trainers": [
                {"params": t.params, "opt_state": t.opt_state,
                 "hparams": t.hparams, "steps": t.steps, "alive": t.alive,
                 "wins": t.wins, "adoptions": t.adoptions}
                for t in self.trainers],
        }

    def load_state_dict(self, state: Dict[str, Any]):
        self.round = state["round"]
        assert len(state["trainers"]) == len(self.trainers), \
            "use resize() for elastic restore"
        for t, s in zip(self.trainers, state["trainers"]):
            t.params = s["params"]
            t.opt_state = s["opt_state"]
            t.hparams = dict(s["hparams"])
            t.steps = int(s["steps"])
            t.alive = bool(s["alive"])
            t.wins = int(s.get("wins", 0))
            t.adoptions = int(s.get("adoptions", 0))
