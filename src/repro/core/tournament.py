"""End-to-end LTFB tournament orchestrator (paper §III-B + §III-C).

This is the integration point the paper's headline result depends on:
the LTFB tournament algorithm running *on top of* the distributed
in-memory data store.  Each of the K trainers owns a disjoint partition
of the bundle-file manifest, serves its mini-batches from its own
:class:`repro.datastore.store.DataStore` (preload / dynamic / none
population modes, owner->consumer exchange accounting) through a
background :class:`PrefetchLoader` overlapped with the train step, and
exchanges models through tournaments.

One API, two backends:

  * ``backend='host'`` — host-orchestrated random pairing
    (:mod:`repro.core.population`), with tournament metric evaluation
    overlapped with the partner exchange via a thread pool (the paper's
    non-blocking sendrecv).  Supports failure/recovery and elastic
    rescale.
  * ``backend='mesh'`` — the mesh-native butterfly tournament
    (:func:`repro.core.ltfb.make_ltfb_step`): the population lives on a
    ``trainer`` mesh axis and the exchange is a compiled
    collective-permute.  Requires >= K devices and power-of-two K.

Both feed from the same per-trainer datastores, checkpoint/restart the
full population through :mod:`repro.checkpoint.ckpt`, and report unified
data + tournament accounting via :meth:`TournamentOrchestrator.stats`.
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.checkpoint import ckpt
from repro.core import ltfb
from repro.core.population import Population, TrainerFns
from repro.datastore.store import (
    DataStore,
    PrefetchLoader,
    aggregate_stats,
    partition_files,
)
from repro.telemetry import log_event


@dataclass
class DataPlan:
    """File manifest + decode/adapt plumbing for one dataset.

    ``reader(path)`` -> dict of per-sample arrays (leading sample dim);
    ``adapt(store_batch)`` -> the batch dict the train step consumes.
    """

    files: List[str]
    reader: Callable[[str], Dict[str, np.ndarray]]
    adapt: Callable[[Dict[str, np.ndarray]], Dict[str, np.ndarray]] = \
        field(default=lambda b: b)

    @classmethod
    def jag_cyclegan(cls, files: List[str]) -> "DataPlan":
        """JAG ICF bundles -> CycleGAN (x, y) batches."""
        from repro.data import jag

        def adapt(b):
            return {"x": b["x"], "y": jag.flatten_outputs(b)}

        return cls(files=list(files), reader=jag.read_bundle, adapt=adapt)

    @classmethod
    def lm_tokens(cls, files: List[str]) -> "DataPlan":
        """Token shards -> (tokens, labels) LM batches."""
        from repro.data import tokens

        return cls(files=list(files), reader=tokens.read_token_shard,
                   adapt=tokens.lm_shard_batch)


@dataclass
class TournamentConfig:
    trainers: int = 4
    scope: str = "full"              # 'full' | 'generator' (GANs)
    backend: str = "host"            # 'host' | 'mesh'
    # datastore
    store_mode: str = "preload"      # 'preload' | 'dynamic' | 'none'
    num_ranks: int = 2               # simulated ranks per trainer
    partition: str = "stride"        # 'stride' | 'block' (data silos)
    batch_size: int = 128
    prefetch_depth: int = 2
    # tournament
    tournament_batches: int = 2      # held-out batches per metric eval
    tournament_batch_size: int = 64
    async_eval: bool = True          # overlap metric eval with exchange
    eval_workers: int = 4
    quantize_exchange: bool = False  # int8 mesh exchange (beyond-paper)
    # PBT
    perturb_hparams: bool = True
    perturb_factor: float = 1.2
    # reserve the manifest's last file as a shared held-out validation
    # set (never assigned to a trainer); falls back to file 0 (training
    # data — biased) when the manifest is too small to spare a file
    holdout: bool = True
    # persistence
    ckpt_dir: Optional[str] = None
    seed: int = 0


class TournamentOrchestrator:
    """Drives a K-trainer LTFB population fed from datastore partitions."""

    def __init__(self, fns: TrainerFns, plan: DataPlan,
                 cfg: TournamentConfig, mesh=None, telemetry=None,
                 genealogy=None):
        if cfg.backend not in ("host", "mesh"):
            raise ValueError(f"unknown backend {cfg.backend!r}")
        if cfg.backend == "mesh" and mesh is None:
            self._check_mesh_fits(cfg.trainers)
        self.fns = fns
        self.plan = plan
        self.cfg = cfg
        self._mesh = mesh
        self._user_mesh = mesh is not None
        self._mesh_step = None
        self._retired_stats: Dict[str, float] = {}
        self.tournament_exchange_bytes = 0
        # observability: tracing (repro.train.telemetry.TrainTelemetry),
        # the genealogy JSONL (GenealogyLog), per-round wall/tournament/
        # checkpoint timings, event counters and the live efficiency
        self.telemetry = telemetry
        self.genealogy = genealogy
        self.events = {"rescales": 0, "failures": 0, "recoveries": 0,
                       "checkpoints": 0, "restores": 0}
        self.tournament_seconds = 0.0
        self.round_wall_seconds = 0.0
        self.last_round_seconds = 0.0
        self.checkpoint_seconds = 0.0
        self.restore_seconds = 0.0
        self.last_efficiency: Optional[Dict[str, Any]] = None
        self._flops_per_step: Optional[float] = None
        self._flops_probed = False
        # per-round hook (called with the orchestrator after each
        # round's accounting) — the launcher writes the Prometheus
        # snapshot / pushes the metrics endpoint from here
        self.on_round: Optional[Callable[["TournamentOrchestrator"],
                                         None]] = None
        self._executor = ThreadPoolExecutor(max_workers=cfg.eval_workers) \
            if (cfg.async_eval and cfg.backend == "host") else None
        # global held-out batch for best-of reporting, warm-start cloning
        # on rescale, and failure recovery: the manifest's last file,
        # excluded from every trainer's partition
        if cfg.holdout and len(plan.files) > cfg.trainers + 1:
            self._train_files = list(plan.files[:-1])
            val_file = plan.files[-1]
        else:
            self._train_files = list(plan.files)
            val_file = plan.files[0]      # too few files: biased fallback
        probe = plan.adapt(plan.reader(val_file))
        n_val = min(cfg.tournament_batch_size,
                    len(next(iter(probe.values()))))
        self.val_batch = {k: v[:n_val] for k, v in probe.items()}
        self._build_data(cfg.trainers)
        self.population = Population(
            fns, self._loader_fns, self._tournament_batches,
            scope=cfg.scope, seed=cfg.seed,
            perturb_factor=cfg.perturb_factor,
            perturb_hparams=cfg.perturb_hparams)
        self.population.telemetry = telemetry
        if self.genealogy is not None:
            self.genealogy.append(
                "init", trainers=cfg.trainers, backend=cfg.backend,
                scope=cfg.scope, seed=cfg.seed,
                partition=cfg.partition, files=len(self._train_files))

    @staticmethod
    def _check_mesh_fits(k: int):
        import jax

        if k & (k - 1):
            raise ValueError(
                f"mesh backend needs power-of-two trainers, got {k}")
        if len(jax.devices()) < k:
            raise ValueError(
                f"mesh backend needs >= {k} devices (have "
                f"{len(jax.devices())}) — set XLA_FLAGS="
                "--xla_force_host_platform_device_count or use "
                "backend='host'")

    # -- data plumbing -----------------------------------------------------
    def _build_data(self, k: int):
        """Partition the manifest across k trainers; build stores,
        prefetchers and per-trainer held-out tournament batches."""
        if len(self._train_files) < k:
            raise ValueError(
                f"manifest has {len(self._train_files)} training files "
                f"(after the held-out reserve) < {k} trainers — write "
                "more bundles or lower --trainers")
        cfg = self.cfg
        parts = [partition_files(self._train_files, k, i, cfg.partition)
                 for i in range(k)]
        self.stores = [DataStore(p, self.plan.reader,
                                 num_ranks=cfg.num_ranks,
                                 mode=cfg.store_mode, seed=cfg.seed + i)
                       for i, p in enumerate(parts)]
        for s in self.stores:
            if cfg.store_mode == "preload":
                s.preload()
        self.loaders = [PrefetchLoader(s, cfg.batch_size,
                                       depth=cfg.prefetch_depth,
                                       consumer_rank=None)
                        for s in self.stores]
        self._loader_fns = [self._make_loader_fn(ld) for ld in self.loaders]
        self._tournament_batches = [self._held_out_batches(s, i)
                                    for i, s in enumerate(self.stores)]

    def _make_loader_fn(self, loader: PrefetchLoader):
        adapt = self.plan.adapt

        def next_batch():
            return adapt(loader.next())

        return next_batch

    def _held_out_batches(self, store: DataStore, idx: int) -> List[dict]:
        """Tournament set: a dedicated permutation of the trainer's own
        partition (the paper evaluates candidates on LOCAL held-out
        data — that is what makes winning models generalize across
        partitions)."""
        perm = store.epoch_permutation(999_983 + idx)
        return [self.plan.adapt(
                    store.get_batch(perm, s, self.cfg.tournament_batch_size))
                for s in range(self.cfg.tournament_batches)]

    def _teardown_data(self):
        for ld in self.loaders:
            ld.close()
        retired = aggregate_stats(self.stores)
        retired["prefetch_wait_seconds"] = sum(ld.wait_seconds
                                               for ld in self.loaders)
        for k, v in retired.items():
            self._retired_stats[k] = self._retired_stats.get(k, 0) + v

    # -- training + tournaments --------------------------------------------
    def train_round(self, steps: int) -> Dict[str, Any]:
        return self.population.train_round(steps)

    def tournament(self) -> Dict[str, Any]:
        t0 = time.perf_counter()
        if self.cfg.backend == "mesh":
            log = self._tournament_mesh()
            if self.telemetry is not None:
                self.telemetry.span("mesh_tournament", t0,
                                    time.perf_counter(),
                                    phase="tournament_eval",
                                    round=self.population.round - 1,
                                    exchange_bytes=log["exchange_bytes"])
        else:
            log = self.population.tournament(executor=self._executor)
        log.setdefault("seconds", time.perf_counter() - t0)
        self.tournament_seconds += float(log["seconds"])
        self.tournament_exchange_bytes += int(log.get("exchange_bytes", 0))
        return log

    def _maybe_probe_flops(self):
        """Per-compiled-step FLOPs (once, lazily, telemetry runs only):
        lower+compile the jitted train step on a probe batch and read
        the XLA cost analysis, so efficiency is also in model-FLOP/s."""
        if self._flops_probed or self.telemetry is None:
            return
        self._flops_probed = True
        try:
            from repro.train.telemetry import step_flops
            t0 = self.population.trainers[0]
            perm = self.stores[0].epoch_permutation(0)
            batch = self.plan.adapt(
                self.stores[0].get_batch(perm, 0, self.cfg.batch_size))
            self._flops_per_step = step_flops(
                self.fns.train_step, t0.params, t0.opt_state, batch,
                t0.hparams)
        except Exception:
            self._flops_per_step = None

    def run(self, rounds: int, steps_per_round: int, ckpt_every: int = 0,
            log: Optional[Callable[[str], None]] = None) -> List[float]:
        """rounds x (independent training, tournament[, checkpoint]).

        Returns the best-trainer validation trace (one entry/round).
        Each round also computes the live parallel-efficiency figures
        (:func:`repro.train.telemetry.efficiency_snapshot`), appends
        ``match`` + ``round`` genealogy records, and emits an
        ``ltfb_round`` structured log record (``--log-json``).
        """
        from repro.train.telemetry import efficiency_snapshot

        trace = []
        self._maybe_probe_flops()
        for _ in range(rounds):
            r0 = time.perf_counter()
            before = {id(t): (t.steps, t.train_seconds, t.data_wait_seconds)
                      for t in self.population.trainers}
            self.train_round(steps_per_round)
            tlog = self.tournament()
            round_idx = self.population.round - 1
            deltas = []
            for t in self.population.trainers:
                s0, tr0, dw0 = before.get(id(t), (t.steps, 0.0, 0.0))
                deltas.append({"steps": t.steps - s0,
                               "train_seconds": t.train_seconds - tr0,
                               "data_wait_seconds":
                                   t.data_wait_seconds - dw0})
            vals = [(float(self.fns.metric(t.params, self.val_batch)), i)
                    for i, t in enumerate(self.population.trainers)
                    if t.alive]
            best, best_idx = min(vals)
            trace.append(best)
            self.last_round_seconds = time.perf_counter() - r0
            self.round_wall_seconds += self.last_round_seconds
            eff = efficiency_snapshot(
                deltas, self.cfg.batch_size,
                float(tlog.get("seconds", 0.0)), self.last_round_seconds,
                flops_per_step=self._flops_per_step)
            self.last_efficiency = eff
            if self.genealogy is not None:
                seed = tlog.get("pairing_seed", self.cfg.seed)
                for i, j, m_local, m_other in tlog["metrics"]:
                    adopted = m_other < m_local
                    self.genealogy.append(
                        "match", round=round_idx, trainer=i, partner=j,
                        m_local=m_local, m_other=m_other,
                        winner=(j if adopted else i), adopted=adopted,
                        seed=seed)
                self.genealogy.append(
                    "round", round=round_idx, best_val=best,
                    best_trainer=best_idx,
                    exchanged=tlog["exchanged"],
                    exchange_bytes=int(tlog.get("exchange_bytes", 0)),
                    efficiency=eff)
            log_event("ltfb_round", round=round_idx, best_val=best,
                      best_trainer=best_idx, exchanged=tlog["exchanged"],
                      exchange_bytes=int(tlog.get("exchange_bytes", 0)),
                      tournament_seconds=float(tlog.get("seconds", 0.0)),
                      wall_seconds=self.last_round_seconds,
                      efficiency=eff)
            if log is not None:
                sp = eff.get("speedup")
                eff_txt = (f" speedup={sp:.2f}x "
                           f"eff={eff['efficiency'] * 100:.0f}%"
                           if sp is not None else "")
                log(f"[ltfb] round={self.population.round} "
                    f"best_val={best:.4f} exchanged={tlog['exchanged']} "
                    f"model_MB={tlog.get('exchange_bytes', 0) / 1e6:.2f}"
                    f"{eff_txt}")
            if self.on_round is not None:
                self.on_round(self)
            if (ckpt_every and self.cfg.ckpt_dir
                    and self.population.round % ckpt_every == 0):
                self.save_checkpoint()
        return trace

    # -- mesh-native backend -----------------------------------------------
    def _ensure_mesh_step(self):
        import jax

        k = len(self.population.trainers)
        if k & (k - 1) or len(jax.devices()) < k:
            raise ValueError(
                f"mesh tournament needs power-of-two trainers and >= K "
                f"devices (K={k}, devices={len(jax.devices())})")
        if self._mesh is None:
            from repro.launch.mesh import make_ltfb_mesh
            self._mesh = make_ltfb_mesh(k, per_trainer_model=1)

        def metric(params, batch):
            return self.fns.metric(params, batch)

        self._mesh_step = ltfb.make_ltfb_step(
            metric, k, self._mesh, axis="trainer", scope=self.cfg.scope,
            quantize=self.cfg.quantize_exchange)

    def _tournament_mesh(self) -> Dict[str, Any]:
        """Butterfly tournament compiled over the trainer mesh axis."""
        import jax
        import jax.numpy as jnp

        trainers = self.population.trainers
        if not all(t.alive for t in trainers):
            raise RuntimeError(
                "mesh tournament schedule is static and cannot self-pair "
                "dead trainers — recover() them first or use the host "
                "backend for failure handling")
        if self._mesh_step is None:
            self._ensure_mesh_step()
        k = len(trainers)
        stacked_p = jax.tree.map(lambda *xs: jnp.stack(xs),
                                 *[t.params for t in trainers])

        def cat(batches):     # full tournament set as one eval batch
            return {k: np.concatenate([np.asarray(b[k]) for b in batches])
                    for k in batches[0]}

        stacked_b = jax.tree.map(lambda *xs: jnp.stack(xs),
                                 *[cat(tb) for tb in
                                   self._tournament_batches])
        # commit to the current mesh — after an elastic rescale the
        # params may still live on the previous (smaller) trainer mesh
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P
        sharding = NamedSharding(self._mesh, P("trainer"))
        stacked_p = jax.tree.map(lambda x: jax.device_put(x, sharding),
                                 stacked_p)
        stacked_b = jax.tree.map(lambda x: jax.device_put(x, sharding),
                                 stacked_b)
        round_idx = self.population.round
        new_p, m_local, m_other = self._mesh_step(stacked_p, stacked_b,
                                                  jnp.int32(round_idx))
        m_local = np.asarray(m_local)
        m_other = np.asarray(m_other)
        partner = ltfb.butterfly_pairing(k, round_idx)
        exch, _ = ltfb.split_scope(trainers[0].params, self.cfg.scope)
        per_model = ltfb.tree_nbytes(exch)
        if self.cfg.quantize_exchange:
            per_model //= 4          # int8 payload vs f32 (+ small scales)
        log = {"exchanged": 0, "kept_local": 0, "metrics": [],
               "exchange_bytes": per_model * k,
               "partner": partner.tolist()}
        for i, t in enumerate(trainers):
            # pull the winner slice off the trainer mesh so per-trainer
            # training (uncommitted, default device) can proceed
            t.params = jax.tree.map(lambda x, i=i: np.asarray(x[i]), new_p)
            adopted = bool(m_other[i] < m_local[i])
            j = int(partner[i])
            log["metrics"].append((i, j, float(m_local[i]),
                                   float(m_other[i])))
            if adopted:
                t.adoptions += 1
                log["exchanged"] += 1
                trainers[j].wins += 1
            else:
                t.wins += 1
                log["kept_local"] += 1
        self.population.round += 1
        return log

    # -- fault tolerance / elasticity ---------------------------------------
    def fail(self, idx: int):
        self.population.fail(idx)
        self.events["failures"] += 1
        if self.genealogy is not None:
            self.genealogy.append("fail", trainer=idx,
                                  round=self.population.round)
        if self.telemetry is not None:
            self.telemetry.event("trainer_fail", trainer=idx)
        log_event("ltfb_trainer_fail", trainer=idx,
                  round=self.population.round)

    def recover(self, idx: int, from_best: bool = True):
        src = self.population.recover(
            idx, from_best_of=self.val_batch if from_best else None)
        self.events["recoveries"] += 1
        if self.genealogy is not None:
            self.genealogy.append("recover", trainer=idx, cloned_from=src,
                                  round=self.population.round)
        if self.telemetry is not None:
            self.telemetry.event("trainer_recover", trainer=idx,
                                 cloned_from=src)
        log_event("ltfb_trainer_recover", trainer=idx, cloned_from=src,
                  round=self.population.round)

    def rescale(self, new_k: int):
        """Elastic rescale: re-partition the datastore manifest across
        `new_k` trainers and grow (cloning tournament winners) or shrink
        (keeping the best) the population."""
        if self.cfg.backend == "mesh" and not self._user_mesh:
            self._check_mesh_fits(new_k)
        t0 = time.perf_counter()
        self._teardown_data()
        self._build_data(new_k)
        info = self.population.resize(new_k, self._loader_fns,
                                      self._tournament_batches,
                                      clone_batch=self.val_batch)
        # pairing schedule and trainer-axis size both depend on K
        self._mesh_step = None
        if not self._user_mesh:
            self._mesh = None
        self.events["rescales"] += 1
        if self.genealogy is not None:
            self.genealogy.append("rescale", round=self.population.round,
                                  **info)
        if self.telemetry is not None:
            self.telemetry.span("rescale", t0, time.perf_counter(),
                                **info)
        log_event("ltfb_rescale", round=self.population.round, **info)

    # -- checkpoint / restart -----------------------------------------------
    def save_checkpoint(self):
        assert self.cfg.ckpt_dir, "TournamentConfig.ckpt_dir not set"
        t0 = time.perf_counter()
        ckpt.save_population(self.cfg.ckpt_dir, self.population.round,
                             self.population.state_dict())
        dur = time.perf_counter() - t0
        self.checkpoint_seconds += dur
        self.events["checkpoints"] += 1
        if self.genealogy is not None:
            self.genealogy.append("checkpoint",
                                  round=self.population.round,
                                  seconds=dur)
            # a checkpoint is a durability point for the ancestry too
            self.genealogy.sync()
        if self.telemetry is not None:
            self.telemetry.span("checkpoint", t0, time.perf_counter(),
                                phase="checkpoint",
                                round=self.population.round)
        log_event("ltfb_checkpoint", round=self.population.round,
                  seconds=dur)

    def maybe_resume(self) -> bool:
        """Restore the newest population checkpoint, if any.  Elastic:
        a checkpoint with K' != K trainers restores into K slots."""
        if not self.cfg.ckpt_dir:
            return False
        step = ckpt.latest_population_step(self.cfg.ckpt_dir)
        if step is None:
            return False
        t0 = self.population.trainers[0]
        like = {"params": t0.params, "opt_state": t0.opt_state}
        w0 = time.perf_counter()
        state = ckpt.restore_population(
            self.cfg.ckpt_dir, step, like,
            num_trainers=len(self.population.trainers))
        self.population.load_state_dict(state)
        dur = time.perf_counter() - w0
        self.restore_seconds += dur
        self.events["restores"] += 1
        if self.genealogy is not None:
            self.genealogy.append("resume", round=self.population.round,
                                  step=step, seconds=dur)
        if self.telemetry is not None:
            self.telemetry.span("restore", w0, time.perf_counter(),
                                phase="restore", step=step)
        log_event("ltfb_resume", round=self.population.round, step=step,
                  seconds=dur)
        return True

    # -- accounting ----------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """Unified per-trainer + total data/tournament accounting.

        Per trainer: datastore counters plus partition sizes, step/wall
        attribution (``train_seconds`` / ``data_wait_seconds``), the
        last train-step metrics and tournament metric.  Totals include
        round wall time, tournament/checkpoint/restore durations,
        prefetch-stall time and rescale/fail/recover event counts, so
        consumers (fig11, the Prometheus export) never recompute
        timings out-of-band.
        """
        per = []
        for store, loader, t in zip(self.stores, self.loaders,
                                    self.population.trainers):
            d = store.stats.as_dict()
            d.update(files=len(store.files),
                     partition_samples=store.num_samples,
                     wins=t.wins, adoptions=t.adoptions, steps=t.steps,
                     alive=t.alive,
                     train_seconds=t.train_seconds,
                     data_wait_seconds=t.data_wait_seconds,
                     prefetch_wait_seconds=loader.wait_seconds,
                     train_metrics=dict(t.last_metrics),
                     tournament_metric=t.tournament_metric)
            per.append(d)
        total = aggregate_stats(self.stores)
        for k, v in self._retired_stats.items():
            total[k] = total.get(k, 0) + v
        return {"per_trainer": per, "total": total,
                "tournament_exchange_bytes": self.tournament_exchange_bytes,
                "round": self.population.round,
                "steps": sum(t.steps for t in self.population.trainers),
                "train_seconds": sum(t.train_seconds
                                     for t in self.population.trainers),
                "data_wait_seconds": sum(
                    t.data_wait_seconds
                    for t in self.population.trainers),
                "prefetch_wait_seconds": (
                    sum(ld.wait_seconds for ld in self.loaders)
                    + self._retired_stats.get("prefetch_wait_seconds", 0)),
                "tournament_seconds": self.tournament_seconds,
                "round_wall_seconds": self.round_wall_seconds,
                "last_round_seconds": self.last_round_seconds,
                "checkpoint_seconds": self.checkpoint_seconds,
                "restore_seconds": self.restore_seconds,
                "events": dict(self.events),
                "efficiency": self.last_efficiency,
                "flops_per_step": self._flops_per_step}

    # -- lifecycle -----------------------------------------------------------
    def close(self):
        for ld in self.loaders:
            ld.close()
        if self._executor is not None:
            self._executor.shutdown(wait=False)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
