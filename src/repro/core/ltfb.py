"""LTFB — "Let a Thousand Flowers Bloom" tournament training (paper §III-C).

Two faithful realizations of the paper's algorithm:

1. **Mesh-native** (:func:`tournament_step`, :func:`make_ltfb_step`) — the
   trainer population lives on a dedicated ``trainer`` mesh axis; model
   exchange is ``jax.lax.ppermute`` (HLO ``collective-permute``, the exact
   peer-to-peer pattern of the paper's MPI sendrecv), and tournament
   evaluation + winner selection compile into the same XLA program as
   training.  Pairings use a *butterfly (hypercube) schedule*: round r
   pairs trainer i with i XOR 2^(r mod log2 K).  This is the TPU-native
   adaptation of the paper's random pairing (DESIGN.md §2): every pairing
   is a static collective-permute (no retracing), and after log2 K rounds
   information has provably mixed across the whole population — the same
   "encoded propagation of data partitions" effect.

2. **Host-orchestrated** (:mod:`repro.core.population`) — the paper's
   random pairing with an explicit population, used by the benchmark
   experiments (Figs. 11–13) and for fault-tolerant/elastic deployments.

Both keep the discriminator local and exchange only the generator for
GANs (``exchange_scope``), per the paper's GAN extension.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Any
MetricFn = Callable[[Params, Dict[str, jax.Array]], jax.Array]


# ---------------------------------------------------------------------------
# Pairing schedules
# ---------------------------------------------------------------------------


def random_pairing(num_trainers: int, round_idx: int, seed: int = 0,
                   alive: Optional[Sequence[bool]] = None) -> np.ndarray:
    """Paper pairing: random disjoint pairs each round.

    Returns ``partner[i]`` (an involution).  Trainers that are down
    (``alive[i] == False``) or the odd one out self-pair — this is the
    straggler/failure mitigation: a missing partner never blocks a round.
    """
    rng = np.random.default_rng(hash((seed, round_idx)) % (2 ** 63))
    partner = np.arange(num_trainers)
    idx = [i for i in range(num_trainers)
           if alive is None or alive[i]]
    rng.shuffle(idx)
    for a, b in zip(idx[::2], idx[1::2]):
        partner[a], partner[b] = b, a
    return partner


def butterfly_pairing(num_trainers: int, round_idx: int) -> np.ndarray:
    """Hypercube schedule: i <-> i XOR 2^(r mod log2 K). Static involution."""
    assert num_trainers & (num_trainers - 1) == 0, "power-of-two trainers"
    bit = 1 << (round_idx % max(1, num_trainers.bit_length() - 1))
    return np.arange(num_trainers) ^ bit


def pairing_to_perm(partner: np.ndarray) -> List[Tuple[int, int]]:
    """ppermute (source, destination) pairs for a partner involution."""
    return [(int(i), int(partner[i])) for i in range(len(partner))]


# ---------------------------------------------------------------------------
# Exchange scope (GAN: generator only)
# ---------------------------------------------------------------------------


def split_scope(params: Params, scope: str) -> Tuple[Params, Params]:
    """Split params into (exchanged, local) per the exchange scope."""
    if scope == "full":
        return params, None
    if scope == "generator":
        local = {k: v for k, v in params.items() if k != "gen"}
        return params["gen"], local
    raise ValueError(scope)


def merge_scope(exchanged: Params, local: Params, scope: str) -> Params:
    if scope == "full":
        return exchanged
    return {**local, "gen": exchanged}


# ---------------------------------------------------------------------------
# Mesh-native tournament step
# ---------------------------------------------------------------------------


def tournament_shard(params: Params, batch: Dict[str, jax.Array],
                     metric_fn: MetricFn, perm: List[Tuple[int, int]],
                     axis: str = "trainer", scope: str = "full",
                     quantize: bool = False):
    """Body executed *inside* shard_map over the trainer axis.

    params/batch are the local (per-trainer) shard.  Returns the winner's
    params (and the local/received metrics for logging).

    ``quantize=True`` (beyond-paper): the exchanged model crosses the
    wire as int8 + per-tensor scales (4x less collective-permute volume
    than f32, 2x less than bf16).  The receiving trainer evaluates and —
    if adopted — continues training from the dequantized weights; GAN
    tournament selection is robust to the quantization (validated in
    tests/test_ltfb.py).
    """
    from repro.optim.compression import dequantize_int8, quantize_int8

    exch, local = split_scope(params, scope)
    if quantize:
        q_and_s = jax.tree.map(quantize_int8, exch)
        qs = jax.tree.map(lambda t: t[0], q_and_s,
                          is_leaf=lambda t: isinstance(t, tuple)
                          and len(t) == 2 and hasattr(t[0], "dtype"))
        ss = jax.tree.map(lambda t: t[1], q_and_s,
                          is_leaf=lambda t: isinstance(t, tuple)
                          and len(t) == 2 and hasattr(t[0], "dtype"))
        q_r = jax.lax.ppermute(qs, axis, perm)
        s_r = jax.lax.ppermute(ss, axis, perm)
        received = jax.tree.map(
            lambda q, s, like: dequantize_int8(q, s).astype(like.dtype),
            q_r, s_r, exch)
    else:
        received = jax.lax.ppermute(exch, axis, perm)
    cand_local = params
    cand_other = merge_scope(received, local, scope)
    m_local = metric_fn(cand_local, batch)
    m_other = metric_fn(cand_other, batch)
    take_other = m_other < m_local
    new_params = jax.tree.map(
        lambda a, b: jnp.where(take_other, b, a), cand_local, cand_other)
    return new_params, m_local, m_other


def _squeeze0(tree):
    return jax.tree.map(lambda x: x[0] if x.ndim else x, tree)


def _unsqueeze0(tree):
    return jax.tree.map(lambda x: x[None], tree)


def make_ltfb_step(metric_fn: MetricFn, num_trainers: int,
                   mesh, axis: str = "trainer", scope: str = "full",
                   param_specs=None, batch_specs=None,
                   quantize: bool = False):
    """Build a jitted LTFB tournament step over a trainer mesh axis.

    The returned ``step(params_stacked, batch_stacked, round_idx)`` uses a
    ``lax.switch`` over the log2(K) butterfly pairings, so every round is
    one compiled program with static collective-permutes.
    """
    from jax.sharding import PartitionSpec as P

    n_bits = max(1, num_trainers.bit_length() - 1)
    perms = [pairing_to_perm(butterfly_pairing(num_trainers, r))
             for r in range(n_bits)]

    in_spec = P(axis)

    def body(params, batch, round_idx):
        # shard_map delivers (1, ...)-shaped per-trainer blocks
        params = _squeeze0(params)
        batch = _squeeze0(batch)

        def mk_branch(perm):
            def branch(p, b):
                return tournament_shard(p, b, metric_fn, perm, axis, scope,
                                        quantize=quantize)
            return branch

        branches = [mk_branch(p) for p in perms]
        new_params, m_local, m_other = jax.lax.switch(
            round_idx % n_bits, branches, params, batch)
        return (_unsqueeze0(new_params), jnp.reshape(m_local, (1,)),
                jnp.reshape(m_other, (1,)))

    from repro.parallel.sharding import shard_map_compat

    shard_fn = shard_map_compat(
        body, mesh=mesh,
        in_specs=(param_specs if param_specs is not None else in_spec,
                  batch_specs if batch_specs is not None else in_spec,
                  P()),
        out_specs=(param_specs if param_specs is not None else in_spec,
                   in_spec, in_spec))
    return jax.jit(shard_fn)


# ---------------------------------------------------------------------------
# Host-side tournament (population trainer / benchmarks)
# ---------------------------------------------------------------------------


def tree_nbytes(tree) -> int:
    """Byte size of a pytree from array metadata (exchange-volume
    accounting) — never copies device buffers to host."""
    return int(sum(leaf.nbytes if hasattr(leaf, "nbytes")
                   else np.asarray(leaf).nbytes
                   for leaf in jax.tree.leaves(tree)))


def host_tournament(population: List[Params], metrics_eval: Callable,
                    partner: np.ndarray, scope: str = "full",
                    telemetry=None
                    ) -> Tuple[List[Params], Dict[str, Any]]:
    """One tournament round over an explicit population.

    metrics_eval(trainer_idx, candidate_params) -> float (lower better);
    candidate evaluation uses trainer_idx's LOCAL tournament data.
    ``telemetry`` (a :class:`repro.train.telemetry.TrainTelemetry`)
    gets one ``partner_exchange`` span per receiving trainer.
    """
    import time

    K = len(population)
    winners: List[Params] = [None] * K
    log = {"exchanged": 0, "kept_local": 0, "metrics": [],
           "exchange_bytes": 0}
    for i in range(K):
        j = int(partner[i])
        if j == i:
            winners[i] = population[i]
            log["kept_local"] += 1
            continue
        x0 = time.perf_counter()
        exch_j, _ = split_scope(population[j], scope)
        _, local_i = split_scope(population[i], scope)
        cand = merge_scope(exch_j, local_i, scope)
        nbytes = tree_nbytes(exch_j)
        log["exchange_bytes"] += nbytes
        if telemetry is not None:
            telemetry.trainer_span("partner_exchange", i, x0,
                                   time.perf_counter(),
                                   phase="partner_exchange",
                                   partner=j, bytes=nbytes)
        m_local = float(metrics_eval(i, population[i]))
        m_other = float(metrics_eval(i, cand))
        if m_other < m_local:
            winners[i] = cand
            log["exchanged"] += 1
        else:
            winners[i] = population[i]
            log["kept_local"] += 1
        log["metrics"].append((i, j, m_local, m_other))
    return winners, log


def host_tournament_async(population: List[Params], metrics_eval: Callable,
                          partner: np.ndarray, scope: str = "full",
                          executor=None, telemetry=None
                          ) -> Tuple[List[Params], Dict[str, Any]]:
    """Tournament round with evaluation overlapped with the exchange.

    The paper's non-blocking sendrecv: each trainer evaluates its OWN
    model on the held-out tournament set while the partner's model is in
    flight.  Here the local-metric evaluations are submitted to
    ``executor`` *before* the exchange (split/merge + byte accounting)
    runs, then the received-candidate evaluations are submitted, so the
    two phases overlap instead of strictly alternating per trainer.
    ``telemetry`` gets one ``partner_exchange`` span per receiving
    trainer (the eval spans come from ``metrics_eval`` itself).
    """
    import time

    if executor is None:
        return host_tournament(population, metrics_eval, partner, scope,
                               telemetry=telemetry)
    K = len(population)
    log = {"exchanged": 0, "kept_local": 0, "metrics": [],
           "exchange_bytes": 0}
    active = [i for i in range(K) if int(partner[i]) != i]
    # phase 1: local evals in flight while the exchange happens
    local_f = {i: executor.submit(metrics_eval, i, population[i])
               for i in active}
    cands: Dict[int, Params] = {}
    for i in active:
        j = int(partner[i])
        x0 = time.perf_counter()
        exch_j, _ = split_scope(population[j], scope)
        _, local_i = split_scope(population[i], scope)
        cands[i] = merge_scope(exch_j, local_i, scope)
        nbytes = tree_nbytes(exch_j)
        log["exchange_bytes"] += nbytes
        if telemetry is not None:
            telemetry.trainer_span("partner_exchange", i, x0,
                                   time.perf_counter(),
                                   phase="partner_exchange",
                                   partner=j, bytes=nbytes)
    # phase 2: received-candidate evals
    other_f = {i: executor.submit(metrics_eval, i, cands[i]) for i in active}
    winners = list(population)
    for i in range(K):
        j = int(partner[i])
        if j == i:
            log["kept_local"] += 1
            continue
        m_local = float(local_f[i].result())
        m_other = float(other_f[i].result())
        if m_other < m_local:
            winners[i] = cands[i]
            log["exchanged"] += 1
        else:
            log["kept_local"] += 1
        log["metrics"].append((i, j, m_local, m_other))
    return winners, log
