"""Shared telemetry core: tracing, JSON logs, Prometheus formatting.

The serving stack (PR 7) and the training/tournament stack both need
the same three primitives, and they must speak the *same dialect* so
one trace viewer, one log pipeline and one Prometheus scraper cover a
train→serve→train deployment end to end:

* :class:`Tracer` — a bounded ring buffer of Chrome-trace events
  (``ph: X`` complete spans / ``i`` instants / ``M`` metadata), with
  per-entity trace rows lazily assigned by key.  Serving keys rows by
  request id; training keys them by trainer index.
* :func:`enable_json_logs` / :func:`log_event` — one-line structured
  JSON records (``--log-json``) sharing ONE global switch, so a
  process that both trains and serves emits a single stream.
* :func:`prom_fmt` / :func:`prom_counter` / :func:`prom_gauge` /
  :func:`prom_labeled` — Prometheus text exposition (0.0.4)
  building blocks: every family gets ``# HELP`` + ``# TYPE`` headers,
  counters are suffixed ``_total`` by the caller, values are
  formatted per the text-format conventions (``NaN``/``+Inf``).

``repro.serve.telemetry`` re-exports the tracer/log surface for
backward compatibility and layers the serving-specific exposition on
top; ``repro.train.telemetry`` does the same for training.  Everything
here is stdlib-only.
"""

from __future__ import annotations

import json
import math
import sys
import time
from collections import deque
from typing import Any, Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Tracer",
    "write_trace",
    "enable_json_logs",
    "json_logs_enabled",
    "log_event",
    "prom_fmt",
    "prom_counter",
    "prom_gauge",
    "prom_labeled",
    "SCHED_TID",
]

# Chrome-trace identifiers: one fake process, tid 0 for scheduler/
# orchestrator-level events, tid 1.. assigned per entity (request id on
# the serve side, trainer index on the train side) in sighting order.
_TRACE_PID = 1
SCHED_TID = 0


class Tracer:
    """Bounded ring buffer of Chrome-trace events.

    Events follow the Chrome trace-event JSON schema (``ph`` = ``"X"``
    complete spans, ``"i"`` instant events, ``"M"`` metadata);
    timestamps are microseconds from a per-tracer ``perf_counter``
    epoch.  The buffer is a ``deque(maxlen=capacity)`` so a long-running
    process holds at most ``capacity`` events; ``dropped`` counts how
    many were evicted.

    ``row_prefix`` names the lazily-assigned per-entity rows (``"req"``
    for serving, ``"trainer"`` for training).
    """

    def __init__(self, capacity: int = 8192, row_name: str = "scheduler",
                 row_prefix: str = "req"):
        self.capacity = int(capacity)
        self.events: deque = deque(maxlen=self.capacity)
        self.epoch = time.perf_counter()
        self.emitted = 0  # total events ever emitted (dropped = emitted - len)
        self.row_prefix = row_prefix
        self._tids: Dict[str, int] = {}  # str(key) -> tid
        self._next_tid = SCHED_TID + 1
        self._meta: List[dict] = [
            {
                "name": "thread_name",
                "ph": "M",
                "pid": _TRACE_PID,
                "tid": SCHED_TID,
                "args": {"name": row_name},
            }
        ]

    @property
    def dropped(self) -> int:
        """Events evicted from the ring so far."""
        return self.emitted - len(self.events)

    def _ts(self, t: float) -> float:
        """Convert a ``perf_counter`` reading to trace microseconds."""
        return (t - self.epoch) * 1e6

    def _tid(self, key: Any) -> int:
        """Stable numeric thread id for an entity key (lazily assigned)."""
        skey = str(key)
        tid = self._tids.get(skey)
        if tid is None:
            # keep the key->tid map bounded alongside the ring
            if len(self._tids) >= 4 * self.capacity:
                self._tids.clear()
            tid = self._next_tid
            self._next_tid += 1
            self._tids[skey] = tid
            self._meta.append(
                {
                    "name": "thread_name",
                    "ph": "M",
                    "pid": _TRACE_PID,
                    "tid": tid,
                    "args": {"name": f"{self.row_prefix} {skey}"},
                }
            )
            if len(self._meta) > 4 * self.capacity:
                del self._meta[1 : len(self._meta) // 2]
        return tid

    def _push(self, ev: dict) -> None:
        self.events.append(ev)
        self.emitted += 1

    def complete(
        self, name: str, tid: int, t0: float, t1: float, **args: Any
    ) -> None:
        """Record a complete span (``ph: X``) on a numeric tid."""
        self._push(
            {
                "name": name,
                "ph": "X",
                "ts": self._ts(t0),
                "dur": max(0.0, (t1 - t0) * 1e6),
                "pid": _TRACE_PID,
                "tid": tid,
                "args": args,
            }
        )

    def instant(
        self, name: str, tid: int, t: Optional[float] = None, **args: Any
    ) -> None:
        """Record an instant event (``ph: i``) on a numeric tid."""
        self._push(
            {
                "name": name,
                "ph": "i",
                "s": "t",
                "ts": self._ts(time.perf_counter() if t is None else t),
                "pid": _TRACE_PID,
                "tid": tid,
                "args": args,
            }
        )

    def req_span(
        self, name: str, rid: Any, t0: float, t1: float, **args: Any
    ) -> None:
        """Record a complete span on the entity's own trace row."""
        self.complete(name, self._tid(rid), t0, t1, rid=str(rid), **args)

    def req_instant(
        self, name: str, rid: Any, t: Optional[float] = None, **args: Any
    ) -> None:
        """Record an instant event on the entity's own trace row."""
        self.instant(name, self._tid(rid), t, rid=str(rid), **args)

    def export(self) -> dict:
        """Export the buffer as a Chrome-trace JSON object."""
        return {
            "traceEvents": self._meta + list(self.events),
            "displayTimeUnit": "ms",
            "otherData": {
                "emitted": self.emitted,
                "dropped": self.dropped,
                "capacity": self.capacity,
            },
        }


def write_trace(tracer: Tracer, path: str) -> None:
    """Write a tracer's Chrome-trace JSON export to ``path``."""
    with open(path, "w") as f:
        json.dump(tracer.export(), f)


# ---- structured JSON logs -------------------------------------------------

_JSON_LOGS = {"enabled": False}


def enable_json_logs(enabled: bool = True) -> None:
    """Globally enable/disable one-line JSON log records (``--log-json``)."""
    _JSON_LOGS["enabled"] = bool(enabled)


def json_logs_enabled() -> bool:
    """Whether JSON log records are currently enabled."""
    return bool(_JSON_LOGS["enabled"])


def _json_safe(v: Any) -> Any:
    """Coerce a value to something ``json.dumps`` emits as valid JSON."""
    if isinstance(v, float) and not math.isfinite(v):
        return None
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, dict):
        return {str(k): _json_safe(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_json_safe(x) for x in v]
    return str(v)


def log_event(event: str, **fields: Any) -> None:
    """Emit one JSON log line (monotonic + unix timestamps) if enabled."""
    if not _JSON_LOGS["enabled"]:
        return
    rec = {"event": event, "ts_monotonic": time.monotonic(),
           "ts_unix": time.time()}
    rec.update({k: _json_safe(v) for k, v in fields.items()})
    sys.stdout.write(json.dumps(rec, allow_nan=False) + "\n")
    sys.stdout.flush()


# ---- prometheus text-format building blocks -------------------------------


def prom_fmt(v: Any) -> str:
    """Format a sample value per Prometheus text conventions."""
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    f = float(v)
    if math.isnan(f):
        return "NaN"
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    return repr(f)


def _labels(labels: Dict[str, Any]) -> str:
    """Render a label dict as ``{k="v",...}`` (empty string for none)."""
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels.items())
    return "{" + inner + "}"


def prom_counter(out: List[str], name: str, help_: str, value: Any) -> None:
    """Append one unlabelled counter family (caller includes ``_total``)."""
    out.append(f"# HELP {name} {help_}")
    out.append(f"# TYPE {name} counter")
    out.append(f"{name} {prom_fmt(value)}")


def prom_gauge(out: List[str], name: str, help_: str, value: Any) -> None:
    """Append one unlabelled gauge family."""
    out.append(f"# HELP {name} {help_}")
    out.append(f"# TYPE {name} gauge")
    out.append(f"{name} {prom_fmt(value)}")


def prom_labeled(out: List[str], name: str, typ: str, help_: str,
                 samples: Iterable[Tuple[Dict[str, Any], Any]]) -> None:
    """Append one labelled family: ONE HELP/TYPE header, then one
    sample line per ``(labels, value)`` pair."""
    out.append(f"# HELP {name} {help_}")
    out.append(f"# TYPE {name} {typ}")
    for labels, value in samples:
        out.append(f"{name}{_labels(labels)} {prom_fmt(value)}")
