"""Atomic, async-capable checkpointing of pytrees + population state.

Fault-tolerance substrate (DESIGN.md §5): checkpoints are written to a
temp directory and atomically renamed, so a node failure mid-write never
corrupts the restore point.  ``save_async`` overlaps serialization with
training (the paper's data-store philosophy applied to checkpoints).
Elastic restore: a population checkpoint with K trainers can be loaded
into K' != K trainers (best-ranked subset / cloned winners).
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

Params = Any

_SEP = "::"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return f"k{p.key}"
    if hasattr(p, "idx"):
        return f"i{p.idx}"
    return str(p)


def save(path: str, tree, metadata: Optional[dict] = None):
    """Atomic + durable checkpoint write: <path>.tmp, fsync, rename.

    The fsync-before-rename matters for the serving registry's
    transactional hot-swap: without it a machine crash can leave a
    fully-renamed file with torn contents, which the atomic rename
    alone does not protect against."""
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    # bf16 has no numpy dtype; view as uint16 with a marker
    store = {}
    dtypes = {}
    for k, v in flat.items():
        if v.dtype == jax.numpy.bfloat16:
            store[k] = v.view(np.uint16) if hasattr(v, "view") else \
                np.asarray(v, np.float32)
            dtypes[k] = "bfloat16"
        else:
            store[k] = v
            dtypes[k] = str(v.dtype)
    np.savez(tmp, __dtypes__=json.dumps(dtypes),
             __meta__=json.dumps(metadata or {}), **store)
    actual = tmp if os.path.exists(tmp) else tmp + ".npz"
    with open(actual, "rb+") as f:
        os.fsync(f.fileno())
    os.replace(actual, path)
    try:                  # best-effort: make the rename itself durable
        dfd = os.open(os.path.dirname(os.path.abspath(path)), os.O_RDONLY)
        os.fsync(dfd)
        os.close(dfd)
    except OSError:
        pass


def restore(path: str, like) -> Tuple[Any, dict]:
    """Restore into the structure of `like` (a pytree template)."""
    with np.load(path, allow_pickle=False) as z:
        dtypes = json.loads(str(z["__dtypes__"]))
        meta = json.loads(str(z["__meta__"]))
        flat = {k: z[k] for k in z.files
                if k not in ("__dtypes__", "__meta__")}
    leaves_paths = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    new_leaves = []
    for path_, leaf in leaves_paths:
        key = _SEP.join(_path_str(p) for p in path_)
        arr = flat[key]
        if dtypes.get(key) == "bfloat16":
            arr = arr.view(jax.numpy.bfloat16) if arr.dtype == np.uint16 \
                else arr
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype)
                          if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves), meta


class AsyncCheckpointer:
    """Overlap checkpoint writes with training."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None

    def save(self, path: str, tree, metadata: Optional[dict] = None):
        self.wait()
        # snapshot to host before backgrounding (device buffers may mutate)
        host_tree = jax.tree.map(np.asarray, tree)
        self._thread = threading.Thread(
            target=save, args=(path, host_tree, metadata), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step_path(ckpt_dir: str) -> Optional[str]:
    """Find the newest step checkpoint in a directory."""
    if not os.path.isdir(ckpt_dir):
        return None
    cands = [f for f in os.listdir(ckpt_dir)
             if f.startswith("step_") and f.endswith(".ckpt")]
    if not cands:
        return None
    best = max(cands, key=lambda f: int(f.split("_")[1].split(".")[0]))
    return os.path.join(ckpt_dir, best)


def save_population(ckpt_dir: str, step: int, pop_state: Dict[str, Any]):
    """Population checkpoint: one file per trainer + a manifest —
    trainers can checkpoint independently (no global barrier)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    manifest = {"step": step, "num_trainers": len(pop_state["trainers"]),
                "round": pop_state["round"], "time": time.time(),
                "seed": pop_state.get("seed", 0),
                "scope": pop_state.get("scope", "full")}
    for i, tr in enumerate(pop_state["trainers"]):
        save(os.path.join(ckpt_dir, f"step_{step}_trainer_{i}.ckpt"),
             {"params": tr["params"], "opt_state": tr["opt_state"]},
             {"hparams": tr["hparams"], "steps": tr["steps"],
              "alive": tr["alive"], "wins": tr.get("wins", 0),
              "adoptions": tr.get("adoptions", 0)})
    with open(os.path.join(ckpt_dir, f"step_{step}.manifest.tmp"), "w") as f:
        json.dump(manifest, f)
    os.replace(os.path.join(ckpt_dir, f"step_{step}.manifest.tmp"),
               os.path.join(ckpt_dir, f"step_{step}.manifest"))


def latest_population_step(ckpt_dir: str) -> Optional[int]:
    """Newest population-checkpoint step in a directory (None if empty)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(f[len("step_"):-len(".manifest")])
             for f in os.listdir(ckpt_dir)
             if f.startswith("step_") and f.endswith(".manifest")]
    return max(steps) if steps else None


def restore_population(ckpt_dir: str, step: int, like_trainer: dict,
                       num_trainers: Optional[int] = None
                       ) -> Dict[str, Any]:
    """Elastic restore: load <= stored trainers, cloning cyclically if
    the new population is larger."""
    with open(os.path.join(ckpt_dir, f"step_{step}.manifest")) as f:
        manifest = json.load(f)
    k_stored = manifest["num_trainers"]
    k = num_trainers or k_stored
    trainers = []
    for i in range(k):
        src = i % k_stored
        tree, meta = restore(
            os.path.join(ckpt_dir, f"step_{step}_trainer_{src}.ckpt"),
            like_trainer)
        trainers.append({"params": tree["params"],
                         "opt_state": tree["opt_state"],
                         "hparams": meta["hparams"],
                         "steps": meta["steps"], "alive": meta["alive"],
                         "wins": meta.get("wins", 0),
                         "adoptions": meta.get("adoptions", 0)})
    return {"round": manifest["round"],
            "seed": manifest.get("seed", 0),
            "scope": manifest.get("scope", "full"),
            "trainers": trainers}
