"""Train an assigned-architecture LM on the synthetic token pipeline.

Any of the 10 architectures is selectable; reduced (smoke) configs keep
this runnable on CPU, and the identical code path is what the dry-run
lowers at full scale on the production mesh.  Optional --ltfb K runs the
tournament algorithm over K trainers (full-model exchange).

  PYTHONPATH=src python examples/train_lm.py --arch xlstm-125m --steps 60
  PYTHONPATH=src python examples/train_lm.py --arch qwen2.5-3b --ltfb 2
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import MeshConfig, OptimizerConfig
from repro.configs.registry import LM_ARCH_IDS, get_config
from repro.core.population import Population, TrainerFns
from repro.data.tokens import train_batch
from repro.train.steps import (init_lm_state, make_lm_eval_metric,
                               make_lm_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=LM_ARCH_IDS)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ltfb", type=int, default=0,
                    help="number of LTFB trainers (0 = single trainer)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    opt = OptimizerConfig(lr=3e-3, warmup_steps=10)
    print(f"arch={cfg.name}: {cfg.param_count()/1e6:.1f}M params "
          f"({cfg.param_count(True)/1e6:.1f}M active), family={cfg.family}")

    raw_step = make_lm_train_step(cfg, opt, MeshConfig(remat="none"))
    step = jax.jit(raw_step)
    metric = jax.jit(make_lm_eval_metric(cfg))
    val = {k: jnp.asarray(v)
           for k, v in train_batch(cfg, args.batch, args.seq, 9999).items()}

    if args.ltfb:
        K = args.ltfb

        def init(seed):
            st, _ = init_lm_state(cfg, opt, jax.random.PRNGKey(seed))
            return st["params"], st["opt_state"], {"lr": opt.lr}

        def tstep(params, opt_state, batch, hparams):
            st, m = step({"params": params, "opt_state": opt_state}, batch)
            return st["params"], st["opt_state"], m

        def loader_for(k):
            c = [0]
            def loader():
                c[0] += 1
                b = train_batch(cfg, args.batch, args.seq,
                                seed=k * 100000 + c[0])
                return {kk: jnp.asarray(v) for kk, v in b.items()}
            return loader

        fns = TrainerFns(init, tstep, metric)
        tourn = [[{k: jnp.asarray(v) for k, v in
                   train_batch(cfg, args.batch, args.seq, 7_000 + k).items()}]
                 for k in range(K)]
        pop = Population(fns, [loader_for(k) for k in range(K)], tourn,
                         scope="full", seed=0)
        rounds = max(1, args.steps // 20)
        for r in range(rounds):
            pop.train_round(20)
            log = pop.tournament()
            print(f"round {r}: exchanged={log['exchanged']} "
                  f"best_val={pop.best_metric(val):.4f}")
        return

    state, _ = init_lm_state(cfg, opt, jax.random.PRNGKey(0))
    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in
                 train_batch(cfg, args.batch, args.seq, seed=i).items()}
        state, m = step(state, batch)
        if i % 10 == 0:
            print(f"step {i:4d} loss={float(m['loss']):.4f} "
                  f"val={float(metric(state['params'], val)):.4f}")
    print(f"{args.steps} steps in {time.time()-t0:.1f}s; "
          f"final val={float(metric(state['params'], val)):.4f}")


if __name__ == "__main__":
    main()
