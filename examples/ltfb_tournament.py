"""LTFB tournament training with fault tolerance + elastic rescale.

The full paper Section III lifecycle through the unified orchestrator:
4 LTFB trainers (generator-only exchange, local discriminators), each
fed from its own distributed-datastore partition of an on-disk JAG
bundle manifest, with background prefetch and tournament evaluation
overlapped with the model exchange.  One trainer is killed mid-run,
recovered from the population's best model, then the population is
elastically grown to 6 trainers (re-partitioning the datastore and
cloning tournament winners).

  PYTHONPATH=src python examples/ltfb_tournament.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import OptimizerConfig
from repro.configs.icf_cyclegan import CycleGANConfig
from repro.core.population import TrainerFns
from repro.core.tournament import (DataPlan, TournamentConfig,
                                   TournamentOrchestrator)
from repro.data import jag
from repro.train.steps import make_gan_steps

CCFG = CycleGANConfig(image_size=16, enc_hidden=(256, 64),
                      dec_hidden=(64, 256))
N, BATCH = 12_000, 128


def main():
    root = tempfile.mkdtemp(prefix="ltfb_example_")
    files = jag.write_bundles(root, N, samples_per_file=1000,
                              image_size=CCFG.image_size, seed=0)
    print(f"dataset: {len(files)} bundles in {root}")

    fns = TrainerFns(*make_gan_steps(
        CCFG, OptimizerConfig(name="adam", lr=1e-3)))
    cfg = TournamentConfig(trainers=4, scope="generator",
                           batch_size=BATCH, num_ranks=2,
                           tournament_batch_size=256, seed=0)
    orch = TournamentOrchestrator(fns, DataPlan.jag_cyclegan(files), cfg)
    pop = orch.population
    try:
        print("== 3 LTFB rounds, 4 trainers ==")
        for r in range(3):
            orch.train_round(40)
            log = orch.tournament()
            lrs = ["%.2e" % t.hparams["lr"] for t in pop.trainers]
            print(f"round {r}: exchanged={log['exchanged']} "
                  f"best_val={pop.best_metric(orch.val_batch):.4f} "
                  f"lrs={lrs}")

        print("== node failure: trainer 2 down ==")
        orch.fail(2)
        orch.train_round(40)
        log = orch.tournament()          # straggler-tolerant pairing
        print(f"with failure: exchanged={log['exchanged']} "
              f"best_val={pop.best_metric(orch.val_batch):.4f}")
        orch.recover(2)
        print("trainer 2 recovered from population best")

        print("== elastic rescale to 6 trainers ==")
        orch.rescale(6)                  # re-partitions the datastore
        orch.train_round(40)
        orch.tournament()
        print(f"after rescale: K={len(pop.trainers)} "
              f"best_val={pop.best_metric(orch.val_batch):.4f}")

        st = orch.stats()
        wins = [d["wins"] for d in st["per_trainer"]]
        print(f"datastore: cache_hits={int(st['total']['cache_hits'])} "
              f"exchange_MB={st['total']['exchange_bytes'] / 1e6:.2f}; "
              f"tournament win counts={wins}")
    finally:
        orch.close()


if __name__ == "__main__":
    main()
