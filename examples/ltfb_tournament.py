"""LTFB tournament training with fault tolerance + elastic rescale.

Runs 4 LTFB trainers (generator-only exchange, local discriminators) on
disjoint data partitions, kills one trainer mid-run, recovers it from
the population's best model, then elastically grows the population to 6
trainers — the full paper Section III-C lifecycle.

  PYTHONPATH=src python examples/ltfb_tournament.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.configs.base import OptimizerConfig
from repro.configs.icf_cyclegan import CycleGANConfig
from repro.core.population import Population, TrainerFns
from repro.data import jag
from repro.train.steps import make_gan_steps

CCFG = CycleGANConfig(image_size=16, enc_hidden=(256, 64),
                      dec_hidden=(64, 256))
N, BATCH = 12_000, 128


def make_parts(x, y, K):
    def loader_for(k):
        rng = np.random.default_rng(500 + k)
        pool = np.arange(k, N, K)
        def loader():
            idx = rng.choice(pool, BATCH)
            return {"x": jnp.asarray(x[idx]), "y": jnp.asarray(y[idx])}
        return loader
    loaders = [loader_for(k) for k in range(K)]
    tourn = [[{"x": jnp.asarray(x[np.arange(k, N, K)[:256]]),
               "y": jnp.asarray(y[np.arange(k, N, K)[:256]])}]
             for k in range(K)]
    return loaders, tourn


def main():
    xs = jag.sample_inputs(N + 1024, seed=0)
    sim = jag.jag_simulate(xs, CCFG.image_size)
    x, y = sim["x"], jag.flatten_outputs(sim)
    val = {"x": jnp.asarray(x[N:]), "y": jnp.asarray(y[N:])}

    init, train_step, metric = make_gan_steps(
        CCFG, OptimizerConfig(name="adam", lr=1e-3))
    fns = TrainerFns(init, train_step, metric)

    loaders, tourn = make_parts(x, y, 4)
    pop = Population(fns, loaders, tourn, scope="generator", seed=0)

    print("== 3 LTFB rounds, 4 trainers ==")
    for r in range(3):
        pop.train_round(40)
        log = pop.tournament()
        lrs = ["%.2e" % t.hparams["lr"] for t in pop.trainers]
        print(f"round {r}: exchanged={log['exchanged']} "
              f"best_val={pop.best_metric(val):.4f} lrs={lrs}")

    print("== node failure: trainer 2 down ==")
    pop.fail(2)
    pop.train_round(40)
    log = pop.tournament()          # straggler-tolerant pairing
    print(f"with failure: exchanged={log['exchanged']} "
          f"best_val={pop.best_metric(val):.4f}")
    pop.recover(2, from_best_of=val)
    print("trainer 2 recovered from population best")

    print("== elastic rescale to 6 trainers ==")
    loaders6, tourn6 = make_parts(x, y, 6)
    pop.resize(6, loaders6, tourn6, clone_batch=val)
    pop.train_round(40)
    pop.tournament()
    print(f"after rescale: K={len(pop.trainers)} "
          f"best_val={pop.best_metric(val):.4f}")


if __name__ == "__main__":
    main()
