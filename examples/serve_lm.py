"""Serve a small LM through the continuous-batching scheduler.

A mixed-length batch of prompts flows through the request queue: the
scheduler admits requests by token budget into a shared preallocated
KV-cache pool, interleaves prefill of new requests with batched decode
of in-flight ones, and frees slots per-request on completion — compare
with the static (pad-to-max) baseline by passing --policy static.

  PYTHONPATH=src python examples/serve_lm.py [--tokens 24]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.registry import get_config
from repro.launch.serve import build_requests, parse_lens
from repro.models.lm import init_lm
from repro.serve.scheduler import Scheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--prompt-lens", default="8,16,24")
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--policy", default="continuous",
                    choices=("continuous", "static"))
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    print(f"arch={cfg.name} (reduced config, {cfg.param_count()/1e6:.1f}M "
          f"params), slots={args.slots} policy={args.policy}")
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))

    lens = parse_lens(args.prompt_lens)
    max_len = max(lens) + args.tokens
    sched = Scheduler(cfg, params, num_slots=args.slots, max_len=max_len,
                      policy=args.policy)
    for r in build_requests(cfg, args.requests, lens, args.tokens, seed=1):
        sched.submit(r)
    results = sched.run()
    sched.stats.report()
    print("sample continuation (token ids):",
          list(map(int, results[0][:12])))


if __name__ == "__main__":
    main()
