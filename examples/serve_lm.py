"""Serve a small LM with batched requests through the KV-cache engine.

Uses the qwen3-family smoke config (the same code path the decode_32k /
long_500k dry-run cells lower at production scale): prefill a batch of
prompts, then greedy-decode continuations.

  PYTHONPATH=src python examples/serve_lm.py [--tokens 48]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.data.tokens import token_stream
from repro.models.lm import init_lm
from repro.serve.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=48)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    print(f"arch={cfg.name} (reduced config, {cfg.param_count()/1e6:.1f}M "
          f"params), batch={args.batch}")
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, max_len=args.prompt_len + args.tokens)

    prompts = jnp.asarray(
        token_stream(args.batch * args.prompt_len, cfg.vocab_size, seed=1)
        .reshape(args.batch, args.prompt_len))
    t0 = time.time()
    out = engine.generate(prompts, steps=args.tokens)
    dt = time.time() - t0
    total_new = args.batch * args.tokens
    print(f"generated {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s incl. compile)")
    # steady-state decode rate
    t0 = time.time()
    out = engine.generate(prompts, steps=args.tokens)
    dt = time.time() - t0
    print(f"steady state: {total_new/dt:.1f} tok/s")
    print("sample continuation (token ids):",
          list(map(int, out[0, args.prompt_len:args.prompt_len + 12])))


if __name__ == "__main__":
    main()
