"""Serve a small LM through the continuous-batching scheduler.

A mixed-length batch of prompts flows through the request queue: the
scheduler admits requests by token budget into a shared preallocated
KV-cache pool, interleaves prefill of new requests with batched decode
of in-flight ones, and frees slots per-request on completion — compare
with the static (pad-to-max) baseline by passing --policy static.

Every model call runs through ONE DecodeSession (the family-agnostic
decode API): pass --spec-tokens K to decode speculatively — a drafter
proposes K tokens per round and the target verifies them in a single
multi-token session.step, with token-identical output (here the
drafter is the model itself, the accept-rate upper bound; in
production it is an earlier LTFB population checkpoint, see
`python -m repro.launch.serve --draft-ckpt`).

  PYTHONPATH=src python examples/serve_lm.py [--tokens 24]
  PYTHONPATH=src python examples/serve_lm.py --spec-tokens 4
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.registry import get_config
from repro.launch.serve import build_requests, parse_lens
from repro.models.lm import init_lm
from repro.serve.scheduler import Scheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--prompt-lens", default="8,16,24")
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--policy", default="continuous",
                    choices=("continuous", "static"))
    ap.add_argument("--spec-tokens", type=int, default=0,
                    help="speculative decoding: draft tokens per round "
                         "(self-draft demo; 0 = off)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    print(f"arch={cfg.name} (reduced config, {cfg.param_count()/1e6:.1f}M "
          f"params), slots={args.slots} policy={args.policy}")
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))

    lens = parse_lens(args.prompt_lens)
    max_len = max(lens) + args.tokens
    sched = Scheduler(cfg, params, num_slots=args.slots, max_len=max_len,
                      policy=args.policy,
                      draft_params=params if args.spec_tokens > 0 else None,
                      spec_tokens=args.spec_tokens)
    for r in build_requests(cfg, args.requests, lens, args.tokens, seed=1):
        sched.submit(r)
    results = sched.run()
    sched.stats.report()
    print("sample continuation (token ids):",
          list(map(int, results[0][:12])))


if __name__ == "__main__":
    main()
