"""Quickstart: train the paper's ICF CycleGAN surrogate end-to-end.

Generates a synthetic JAG dataset (bundled files, paper layout), stands
up the distributed in-memory data store with background prefetch, and
trains the CycleGAN for a few hundred steps with checkpointing —
the full single-trainer pipeline of the paper in one script.

  PYTHONPATH=src python examples/quickstart.py [--steps 400]
"""
import argparse
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import OptimizerConfig
from repro.configs.icf_cyclegan import CycleGANConfig
from repro.data import jag
from repro.datastore.store import DataStore, PrefetchLoader
from repro.train.steps import make_gan_steps

CCFG = CycleGANConfig(image_size=16, enc_hidden=(256, 64),
                      dec_hidden=(64, 256))


def batch_from_store(raw):
    y = jag.flatten_outputs(raw)
    return {"x": jnp.asarray(raw["x"]), "y": jnp.asarray(y)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--samples", type=int, default=8000)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as root:
        print(f"writing {args.samples} JAG samples (bundles of 500)...")
        paths = jag.write_bundles(root, args.samples, 500,
                                  image_size=CCFG.image_size)
        store = DataStore(paths, jag.read_bundle, num_ranks=4,
                          mode="preload")
        store.preload(parallel=True)
        print(f"datastore: {store.num_samples} samples, "
              f"preload {store.stats.preload_seconds:.2f}s")
        loader = PrefetchLoader(store, batch_size=128, depth=2)

        init, train_step, metric = make_gan_steps(
            CCFG, OptimizerConfig(name="adam", lr=1e-3))  # paper settings
        params, opt_state, hparams = init(0)

        val_raw = jag.jag_simulate(jag.sample_inputs(512, seed=99),
                                   CCFG.image_size)
        val = batch_from_store(val_raw)
        ckpt_dir = args.ckpt_dir or os.path.join(root, "ckpt")

        t0 = time.time()
        try:
            for step in range(args.steps):
                batch = batch_from_store(loader.next())
                params, opt_state, m = train_step(params, opt_state,
                                                  batch, hparams)
                if step % 50 == 0:
                    v = float(metric(params, val))
                    print(f"step {step:4d}  g={float(m['g_loss']):.4f} "
                          f"d={float(m['d_loss']):.4f}  val={v:.4f}")
                if step and step % 200 == 0:
                    ckpt.save(os.path.join(ckpt_dir, f"step_{step}.ckpt"),
                              {"params": params, "opt_state": opt_state},
                              {"step": step})
        finally:
            loader.close()
        v = float(metric(params, val))
        print(f"final val={v:.4f} after {args.steps} steps "
              f"({time.time()-t0:.1f}s)")
        # show a couple of predicted vs ground-truth scalars (paper Fig. 7)
        from repro.models import icf_cyclegan as cg
        pred = cg.predict(params["gen"], val["x"][:4])
        print("scalars (pred vs truth), first 5 of 15:")
        for i in range(4):
            p = np.asarray(pred[i, :5]) * 10
            t = np.asarray(val["y"][i, :5]) * 10
            print("  ", np.round(p, 2), "|", np.round(t, 2))


if __name__ == "__main__":
    main()
