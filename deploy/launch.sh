#!/bin/sh
# Serving entrypoint: host-level tuning, then exec the launcher.
#
#   launch.sh [--entry MODULE] [--dry-run] <launcher args...>
#
# Defaults to `python -m repro.launch.serve`; pass
# `--entry repro.launch.distributed` for the multi-process harness.
# `--dry-run` prints the environment and command instead of running
# (used by CI on runners without docker).
#
# Tuning (same recipe the paper's training clusters used — see
# SNIPPETS.md and docs/deployment.md):
#   * tcmalloc via LD_PRELOAD when present — glibc malloc arena churn
#     slows XLA's large transient host allocations;
#   * TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD raised so numpy buffers
#     don't spam allocation warnings;
#   * TF_CPP_MIN_LOG_LEVEL=4 silences XLA's C++ chatter;
#   * REPRO_HOST_DEVICES=N emulates N devices on CPU
#     (--xla_force_host_platform_device_count) for mesh serving.
set -eu

ENTRY="repro.launch.serve"
DRY_RUN=0
while [ $# -gt 0 ]; do
    case "$1" in
        --entry) ENTRY="$2"; shift 2 ;;
        --dry-run) DRY_RUN=1; shift ;;
        *) break ;;
    esac
done

for lib in /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
           /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
           /usr/lib/libtcmalloc.so.4; do
    if [ -r "$lib" ]; then
        LD_PRELOAD="$lib${LD_PRELOAD:+:$LD_PRELOAD}"
        export LD_PRELOAD
        break
    fi
done
export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD="${TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD:-60000000000}"
export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

HOST_DEVICES="${REPRO_HOST_DEVICES:-1}"
case "${XLA_FLAGS:-}" in
    *xla_force_host_platform_device_count*) ;;
    *) export XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=$HOST_DEVICES" ;;
esac

if [ "$DRY_RUN" = 1 ]; then
    echo "launch.sh dry run:"
    echo "  LD_PRELOAD=${LD_PRELOAD:-<none>}"
    echo "  TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=$TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD"
    echo "  TF_CPP_MIN_LOG_LEVEL=$TF_CPP_MIN_LOG_LEVEL"
    echo "  JAX_PLATFORMS=$JAX_PLATFORMS"
    echo "  XLA_FLAGS=$XLA_FLAGS"
    echo "  exec: python -m $ENTRY $*"
    exit 0
fi

exec python -m "$ENTRY" "$@"
