"""Docs-suite checks: ``docs/flags.md`` must agree with the argparse
definitions (both directions, per CLI), the docs pages and README
landing page must exist and cross-link, and the public serving surface
must carry docstrings (the same D1 rules ``ruff.toml`` enforces,
re-checked here via ast so the suite doesn't depend on ruff being
installed)."""
import ast
import importlib
import os
import re

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = os.path.join(ROOT, "docs")

# every CLI module exposing build_parser() <-> its docs/flags.md section
CLIS = ["serve", "ltfb", "distributed", "train", "dryrun", "lineage"]


def _parser_flags(mod: str):
    m = importlib.import_module(f"repro.launch.{mod}")
    ap = m.build_parser()
    flags = set()
    for a in ap._actions:
        for opt in a.option_strings:
            if opt.startswith("--") and opt != "--help":
                flags.add(opt)
    return flags


def _doc_sections():
    """Split docs/flags.md into {module: section text}."""
    text = open(os.path.join(DOCS, "flags.md")).read()
    sections = {}
    current = None
    for line in text.splitlines():
        m = re.match(r"^## repro\.launch\.(\w+)\s*$", line)
        if m:
            current = m.group(1)
            sections[current] = []
        elif current is not None:
            sections[current].append(line)
    return {k: "\n".join(v) for k, v in sections.items()}


def test_flags_doc_has_a_section_per_cli():
    sections = _doc_sections()
    assert set(CLIS) == set(sections), (
        "docs/flags.md sections out of sync with the build_parser CLIs")


@pytest.mark.parametrize("mod", CLIS)
def test_flags_doc_matches_argparse(mod):
    """Both directions: documented ⊆ parser and parser ⊆ documented."""
    sections = _doc_sections()
    documented = set(re.findall(r"`(--[a-z][a-z0-9-]*)`", sections[mod]))
    actual = _parser_flags(mod)
    assert documented - actual == set(), (
        f"docs/flags.md documents flags {sorted(documented - actual)} "
        f"that repro.launch.{mod} does not define")
    assert actual - documented == set(), (
        f"repro.launch.{mod} defines flags {sorted(actual - documented)} "
        f"missing from docs/flags.md — document them")


def test_docs_suite_exists_and_crosslinks():
    pages = ["architecture.md", "serving.md", "deployment.md",
             "observability.md", "flags.md"]
    for p in pages:
        path = os.path.join(DOCS, p)
        assert os.path.exists(path), f"docs/{p} missing"
        assert len(open(path).read()) > 500, f"docs/{p} is a stub"
    readme = open(os.path.join(ROOT, "README.md")).read()
    for p in pages[:3]:
        assert f"docs/{p}" in readme, f"README does not link docs/{p}"
    # landing page, not a manual: the deep operational detail moved out
    assert len(readme.splitlines()) < 120, (
        "README grew past a landing page — move detail into docs/")


def test_deploy_artifacts_exist():
    assert os.path.exists(os.path.join(ROOT, "deploy", "Dockerfile"))
    launch = os.path.join(ROOT, "deploy", "launch.sh")
    assert os.path.exists(launch)
    assert os.access(launch, os.X_OK), "deploy/launch.sh not executable"
    text = open(launch).read()
    assert "tcmalloc" in text and "xla_force_host_platform_device_count" \
        in text


# -- docstring coverage (mirrors the ruff D1 scope) -------------------------

SERVE_DIR = os.path.join(ROOT, "src", "repro", "serve")


def _missing_docstrings(path: str):
    """Public defs/classes without docstrings, D1-style: underscore
    names are private; nested defs inside functions don't count;
    __init__/dunders are exempt (D105/D107 are ignored in ruff.toml)."""
    tree = ast.parse(open(path).read())
    missing = []

    def walk(node, prefix, in_class):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                name = child.name
                public = not name.startswith("_")
                if public and ast.get_docstring(child) is None:
                    missing.append(f"{prefix}{name}")
                if isinstance(child, ast.ClassDef) and public:
                    walk(child, f"{prefix}{name}.", True)
            elif not in_class and isinstance(child, ast.Module):
                walk(child, prefix, False)
    if ast.get_docstring(tree) is None:
        missing.append("<module>")
    walk(tree, "", False)
    return missing


def test_public_serve_surface_has_docstrings():
    problems = {}
    for fname in sorted(os.listdir(SERVE_DIR)):
        if not fname.endswith(".py"):
            continue
        missing = _missing_docstrings(os.path.join(SERVE_DIR, fname))
        if missing:
            problems[fname] = missing
    assert problems == {}, (
        f"public serve symbols missing docstrings: {problems}")


def test_ruff_selects_d1_for_serve():
    """The ruff config must keep pydocstyle D1 on for repro/serve —
    and the per-file-ignores must not carve serve back out."""
    text = open(os.path.join(ROOT, "ruff.toml")).read()
    assert re.search(r'select\s*=\s*\[[^]]*"D1', text), (
        "ruff.toml no longer selects D1xx (docstring presence)")
    for line in text.splitlines():
        if "serve" in line and "D1" in line and "ignore" in line:
            raise AssertionError(
                f"ruff.toml ignores D1 for serve: {line!r}")
