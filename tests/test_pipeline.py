"""Pipeline-parallelism tests: pipelined == sequential (fwd + grads)."""
import os
import subprocess
import sys

from repro.parallel.pipeline import bubble_fraction


def test_bubble_fraction():
    assert bubble_fraction(1, 8) == 0.0
    assert abs(bubble_fraction(4, 8) - 3 / 11) < 1e-9
    assert bubble_fraction(4, 28) < bubble_fraction(4, 8)


SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.parallel.pipeline import make_pipelined_forward

S, M, mb, d = 4, 8, 2, 16
mesh = Mesh(np.asarray(jax.devices()).reshape(S,), ("stage",))
key = jax.random.PRNGKey(0)
Ws = jax.random.normal(key, (S, d, d)) / np.sqrt(d)
bs = jax.random.normal(jax.random.fold_in(key, 1), (S, d)) * 0.1
x = jax.random.normal(jax.random.fold_in(key, 2), (M, mb, d))

def stage_fn(params, h):
    W, b = params
    return jnp.tanh(h @ W + b)

pipe = make_pipelined_forward(stage_fn, mesh, S, "stage")

def seq(params, xm):
    h = xm
    for s in range(S):
        h = stage_fn((params[0][s], params[1][s]), h)
    return h

out_pipe = pipe((Ws, bs), x)
out_ref = jax.vmap(lambda xm: seq((Ws, bs), xm))(x)
assert float(jnp.max(jnp.abs(out_pipe - out_ref))) < 1e-5

gp = jax.grad(lambda p: jnp.sum(jnp.sin(pipe(p, x))))((Ws, bs))
gr = jax.grad(lambda p: jnp.sum(jnp.sin(
    jax.vmap(lambda xm: seq(p, xm))(x))))((Ws, bs))
for a, b in zip(jax.tree.leaves(gp), jax.tree.leaves(gr)):
    assert float(jnp.max(jnp.abs(a - b))) < 1e-5
print("OK")
"""


def test_pipeline_matches_sequential_multidevice():
    env = dict(os.environ)
    env.update({"XLA_FLAGS": "--xla_force_host_platform_device_count=4",
                "PYTHONPATH": "src"})
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
