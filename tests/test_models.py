"""Per-architecture smoke tests (deliverable f) + cross-path parity.

Every assigned architecture instantiates its REDUCED config, runs one
forward/train step on CPU, and asserts output shapes + no NaNs.  The
parity tests prove prefill+decode == full forward for every family
(the strongest correctness property of the serving path).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MeshConfig, OptimizerConfig, replace
from repro.configs.registry import LM_ARCH_IDS, get_config
from repro.data.tokens import train_batch
from repro.models.lm import (init_cache, init_lm, lm_decode, lm_forward,
                             lm_prefill)
from repro.train.steps import init_lm_state, make_lm_train_step

KEY = jax.random.PRNGKey(0)


def _smoke_batch(cfg, B=2, S=16):
    b = train_batch(cfg, B, S, seed=0)
    return {k: jnp.asarray(v) for k, v in b.items()}


@pytest.mark.parametrize("arch", LM_ARCH_IDS)
def test_arch_smoke_forward_and_shapes(arch):
    cfg = get_config(arch, smoke=True)
    params, axes = init_lm(cfg, KEY)
    batch = _smoke_batch(cfg)
    logits, aux = lm_forward(params, cfg, batch)
    B = batch["labels"].shape[0]
    S = batch["labels"].shape[1]
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # axes tree mirrors params tree
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda t: isinstance(t, tuple)
        and all(x is None or isinstance(x, str) for x in t))


@pytest.mark.parametrize("arch", LM_ARCH_IDS)
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    opt_cfg = OptimizerConfig(lr=1e-3, warmup_steps=1)
    state, _ = init_lm_state(cfg, opt_cfg, KEY)
    step = jax.jit(make_lm_train_step(cfg, opt_cfg, MeshConfig(remat="full")))
    batch = _smoke_batch(cfg)
    l0 = None
    for i in range(3):
        state, metrics = step(state, batch)
        assert bool(jnp.isfinite(metrics["loss"])), (arch, i)
        l0 = l0 or float(metrics["loss"])
    assert float(metrics["loss"]) < l0 + 1.0  # no explosion


@pytest.mark.parametrize("arch", LM_ARCH_IDS)
def test_prefill_decode_parity(arch):
    """prefill(prompt) + decode steps == full forward, per family."""
    cfg = get_config(arch, smoke=True)
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe is not None:   # dropless so train-mode forward matches
        cfg = replace(cfg, **{
            "moe.capacity_factor": float(cfg.moe.num_experts)})
    params, _ = init_lm(cfg, KEY)
    B, S, Sp = 2, 12, 8
    if cfg.family == "vlm":
        batch = _smoke_batch(cfg, B, S)
        logits_full, _ = lm_forward(params, cfg, batch)
        pre = {k: (v[:, :, :Sp] if k == "positions" else v[:, :Sp])
               for k, v in batch.items() if k != "labels"}
        lg_pre, _ = lm_prefill(params, cfg, pre)
        np.testing.assert_allclose(
            np.asarray(lg_pre[:, -1]), np.asarray(logits_full[:, Sp - 1]),
            atol=1e-4)
        return
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    logits_full, _ = lm_forward(params, cfg, {"tokens": toks})
    lg_pre, cache0 = lm_prefill(params, cfg, {"tokens": toks[:, :Sp]})
    cache_full, _ = init_cache(cfg, B, S)

    def fit(dst, src):
        if dst.shape == src.shape:
            return src
        pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
        return jnp.pad(src, pad)

    cache = jax.tree.map(fit, cache_full, cache0)
    errs = [float(jnp.max(jnp.abs(lg_pre[:, -1] - logits_full[:, Sp - 1])))]
    for i in range(Sp, S):
        lg, cache = lm_decode(params, cfg, toks[:, i:i + 1], cache,
                              jnp.int32(i))
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - logits_full[:, i]))))
    assert max(errs) < 1e-3, (arch, errs)


def test_mamba_chunked_matches_sequential():
    from repro.models.layers import KeyGen
    from repro.models.ssm import (init_mamba, mamba_block,
                                  mamba_ref_sequential)
    cfg = dataclasses.replace(
        get_config("jamba-1.5-large-398b", smoke=True), dtype="float32")
    p, _ = init_mamba(KeyGen(KEY), cfg)
    x = jax.random.normal(KEY, (2, 37, cfg.d_model), jnp.float32) * 0.5
    np.testing.assert_allclose(
        np.asarray(mamba_block(p, cfg, x)),
        np.asarray(mamba_ref_sequential(p, cfg, x)), atol=1e-4)


def test_mlstm_chunkwise_matches_recurrence():
    from repro.models.layers import KeyGen
    from repro.models.xlstm import (init_mlstm, init_mlstm_state,
                                    mlstm_block, mlstm_decode)
    cfg = dataclasses.replace(
        get_config("xlstm-125m", smoke=True), dtype="float32")
    p, _ = init_mlstm(KeyGen(KEY), cfg)
    x = jax.random.normal(KEY, (2, 33, cfg.d_model), jnp.float32) * 0.5
    y_chunk = mlstm_block(p, cfg, x)
    state, _ = init_mlstm_state(cfg, 2)
    ys = []
    for t in range(x.shape[1]):
        y, state = mlstm_decode(p, cfg, x[:, t:t + 1], state)
        ys.append(y)
    y_rec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_rec),
                               atol=1e-4)


def test_param_count_matches_literature():
    """Total/active parameter counts are within 15% of the published
    sizes (validates the MODEL_FLOPS roofline inputs)."""
    expect = {
        "phi3.5-moe-42b-a6.6b": (42e9, 6.6e9),
        "deepseek-moe-16b": (16.4e9, 2.8e9),
        "codeqwen1.5-7b": (7.3e9, 7.3e9),
        "granite-8b": (8.1e9, 8.1e9),
        "jamba-1.5-large-398b": (398e9, 94e9),
        # musicgen uses a 2-matrix GELU FFN upstream; this framework's
        # uniform SwiGLU block adds one d_model x d_ff matrix per layer
        # (+0.3B) — documented adaptation, MODEL_FLOPS uses our count.
        "musicgen-medium": (1.82e9, 1.82e9),
        "qwen2-vl-7b": (7.6e9, 7.6e9),
        "qwen2.5-3b": (3.1e9, 3.1e9),
    }
    for arch, (total, active) in expect.items():
        cfg = get_config(arch)
        t = cfg.param_count()
        a = cfg.param_count(active_only=True)
        assert abs(t - total) / total < 0.18, (arch, t, total)
        assert abs(a - active) / active < 0.25, (arch, a, active)


def test_moe_capacity_drops_tokens_in_training_mode():
    from repro.models.layers import KeyGen, init_moe, moe_block
    cfg = get_config("phi3.5-moe-42b-a6.6b", smoke=True)
    p, _ = init_moe(KeyGen(KEY), cfg)
    x = jax.random.normal(KEY, (2, 64, cfg.d_model),
                          jnp.dtype(cfg.dtype))
    out_cap, aux = moe_block(p, cfg, x, dropless=False)
    out_free, _ = moe_block(p, cfg, x, dropless=True)
    assert out_cap.shape == out_free.shape == x.shape
    assert float(aux["moe_load_balance"]) > 0.0


def test_cyclegan_smoke():
    from repro.configs.icf_cyclegan import SMOKE as CCFG
    from repro.models import icf_cyclegan as cg
    params, axes = cg.init_cyclegan(CCFG, KEY)
    x = jax.random.uniform(KEY, (8, CCFG.input_dim))
    y = jax.random.uniform(KEY, (8, CCFG.output_dim))
    loss, metrics = cg.generator_loss(params["gen"], params["disc"],
                                      CCFG, {"x": x, "y": y})
    dloss, dm = cg.discriminator_loss(params["disc"], params["gen"],
                                      CCFG, {"x": x, "y": y})
    assert jnp.isfinite(loss) and jnp.isfinite(dloss)
    pred = cg.predict(params["gen"], x)
    assert pred.shape == (8, CCFG.output_dim)
