"""Checkpoint/restore + fault tolerance + elastic restore tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import OptimizerConfig
from repro.configs.icf_cyclegan import SMOKE as CCFG
from repro.train.steps import make_gan_steps

KEY = jax.random.PRNGKey(0)


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": (jnp.ones((4,), jnp.bfloat16),
                  {"c": jnp.array(3, jnp.int32)})}


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    path = str(tmp_path / "t.ckpt")
    ckpt.save(path, tree, {"step": 7})
    restored, meta = ckpt.restore(path, tree)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_atomic_write_never_leaves_partial(tmp_path):
    path = str(tmp_path / "t.ckpt")
    ckpt.save(path, _tree())
    assert os.path.exists(path)
    assert not os.path.exists(path + ".tmp")
    assert not os.path.exists(path + ".tmp.npz")


def test_async_checkpointer(tmp_path):
    path = str(tmp_path / "async.ckpt")
    ac = ckpt.AsyncCheckpointer()
    ac.save(path, _tree(), {"step": 1})
    ac.wait()
    restored, meta = ckpt.restore(path, _tree())
    assert meta["step"] == 1


def test_population_checkpoint_and_elastic_restore(tmp_path):
    init, train_step, metric = make_gan_steps(CCFG, OptimizerConfig())
    trainers = []
    for i in range(3):
        p, o, h = init(i)
        trainers.append({"params": p, "opt_state": o, "hparams": h,
                         "steps": 5 * i, "alive": True})
    state = {"round": 2, "seed": 0, "scope": "generator",
             "trainers": trainers}
    ckpt.save_population(str(tmp_path), 100, state)

    like = {"params": trainers[0]["params"],
            "opt_state": trainers[0]["opt_state"]}
    # same-size restore
    restored = ckpt.restore_population(str(tmp_path), 100, like)
    assert restored["round"] == 2
    assert len(restored["trainers"]) == 3
    # ELASTIC: restore into 5 trainers (cyclic cloning)
    bigger = ckpt.restore_population(str(tmp_path), 100, like,
                                     num_trainers=5)
    assert len(bigger["trainers"]) == 5
    a0 = jax.tree.leaves(bigger["trainers"][0]["params"])[0]
    a3 = jax.tree.leaves(bigger["trainers"][3]["params"])[0]
    np.testing.assert_array_equal(np.asarray(a0, np.float32),
                                  np.asarray(a3, np.float32))
    # ELASTIC: shrink to 2
    smaller = ckpt.restore_population(str(tmp_path), 100, like,
                                      num_trainers=2)
    assert len(smaller["trainers"]) == 2


def test_restart_continues_training_identically(tmp_path):
    """Fault-tolerance core property: save -> crash -> restore produces
    bit-identical continuation."""
    init, train_step, metric = make_gan_steps(CCFG, OptimizerConfig())
    params, opt_state, h = init(0)
    batch = {"x": jax.random.uniform(KEY, (16, CCFG.input_dim)),
             "y": jax.random.uniform(KEY, (16, CCFG.output_dim))}
    for _ in range(3):
        params, opt_state, _ = train_step(params, opt_state, batch, h)
    path = str(tmp_path / "mid.ckpt")
    ckpt.save(path, {"params": params, "opt_state": opt_state})
    # continue original
    p1, o1 = params, opt_state
    for _ in range(2):
        p1, o1, _ = train_step(p1, o1, batch, h)
    # "crash", restore, continue
    restored, _ = ckpt.restore(path, {"params": params,
                                      "opt_state": opt_state})
    p2, o2 = restored["params"], restored["opt_state"]
    for _ in range(2):
        p2, o2, _ = train_step(p2, o2, batch, h)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_step_path(tmp_path):
    assert ckpt.latest_step_path(str(tmp_path)) is None
    ckpt.save(str(tmp_path / "step_10.ckpt"), _tree())
    ckpt.save(str(tmp_path / "step_200.ckpt"), _tree())
    assert ckpt.latest_step_path(str(tmp_path)).endswith("step_200.ckpt")
