"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np

from hypothesis_compat import given, settings, st

from repro.models.layers import chunked_attention, dense_attention

KEY = jax.random.PRNGKey(11)


@given(
    B=st.integers(1, 3),
    S=st.integers(2, 96),
    Hkv=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2, 4]),
    D=st.sampled_from([8, 16, 32]),
    chunk=st.sampled_from([7, 16, 33, 64]),
    causal=st.booleans(),
)
@settings(max_examples=25, deadline=None)
def test_chunked_attention_equals_dense(B, S, Hkv, g, D, chunk, causal):
    H = Hkv * g
    ks = jax.random.split(jax.random.fold_in(KEY, S * H + D), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    a = dense_attention(q, k, v, causal)
    b = chunked_attention(q, k, v, causal, k_chunk=chunk)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


@given(n=st.integers(8, 512), K=st.integers(1, 8), dim=st.integers(0, 4))
@settings(max_examples=40, deadline=None)
def test_silo_partition_covers_disjointly(n, K, dim):
    from benchmarks.common import silo_partition
    rng = np.random.default_rng(0)
    x = rng.random((n, 5)).astype(np.float32)
    silos = silo_partition(x, K, key_dim=dim)
    flat = np.concatenate(silos)
    assert sorted(flat.tolist()) == list(range(n))
    # silos are ordered along the key dimension
    for a, b in zip(silos[:-1], silos[1:]):
        if len(a) and len(b):
            assert x[a, dim].max() <= x[b, dim].min() + 1e-6


@given(seed=st.integers(0, 100), n=st.integers(1, 2000))
@settings(max_examples=20, deadline=None)
def test_halton_inputs_in_unit_cube(seed, n):
    from repro.data.jag import sample_inputs
    x = sample_inputs(n, seed)
    assert x.shape == (n, 5)
    assert np.all(x >= 0.0) and np.all(x < 1.0)
    if n >= 500:
        # low-discrepancy: each octant of the first 3 dims is populated
        cells = (x[:, :3] > 0.5).astype(int)
        codes = cells @ np.array([4, 2, 1])
        assert len(np.unique(codes)) == 8


@given(st.integers(1, 6))
@settings(max_examples=6, deadline=None)
def test_moe_dropless_routes_all_tokens(k):
    """With dropless capacity, the MoE output must equal the gate-weighted
    sum of expert outputs for EVERY token (nothing dropped)."""
    import dataclasses
    from repro.configs.registry import get_config
    from repro.configs.base import replace
    from repro.models.layers import KeyGen, init_moe, moe_block

    cfg = dataclasses.replace(get_config("phi3.5-moe-42b-a6.6b", smoke=True),
                              dtype="float32")
    cfg = replace(cfg, **{"moe.top_k": min(k, cfg.moe.num_experts)})
    p, _ = init_moe(KeyGen(KEY), cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, k),
                          (1, 16, cfg.d_model), jnp.float32)
    out, _ = moe_block(p, cfg, x, dropless=True)
    # brute-force per-token reference over all experts
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ np.asarray(p["router"], np.float32)
    probs = jax.nn.softmax(jnp.asarray(logits), axis=-1)
    gv, gi = jax.lax.top_k(probs, cfg.moe.top_k)
    gv = gv / jnp.sum(gv, axis=-1, keepdims=True)

    def expert(e, t):
        h = jax.nn.silu(xt[t] @ p["wg"][e]) * (xt[t] @ p["wi"][e])
        return h @ p["wo"][e]

    ref = np.zeros_like(np.asarray(xt))
    for t in range(xt.shape[0]):
        for j in range(cfg.moe.top_k):
            ref[t] += float(gv[t, j]) * np.asarray(expert(int(gi[t, j]), t))
    np.testing.assert_allclose(np.asarray(out).reshape(ref.shape), ref,
                               atol=1e-4)
