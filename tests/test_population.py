"""Population (host-orchestrated LTFB) behaviour tests on a tiny convex
problem where tournament dynamics are analytically predictable."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.population import Population, TrainerFns

TARGET = 3.0


def _fns(lr=0.2):
    def init(seed):
        rng = np.random.default_rng(seed)
        params = {"w": jnp.asarray(rng.normal(0, 2, (1,)), jnp.float32)}
        return params, {"step": 0}, {"lr": lr}

    @jax.jit
    def train_step(params, opt_state, batch, hparams):
        g = jax.grad(lambda p: jnp.mean((p["w"] - batch["t"]) ** 2))(params)
        params = {"w": params["w"] - hparams["lr"] * g["w"]}
        return params, opt_state, {"loss": jnp.mean(
            (params["w"] - batch["t"]) ** 2)}

    @jax.jit
    def metric(params, batch):
        return jnp.mean(jnp.abs(params["w"] - batch["t"]))

    return TrainerFns(init, train_step, metric)


def _mk_pop(K=4, seed=0, **kw):
    batch = {"t": jnp.full((4,), TARGET)}
    loaders = [lambda b=batch: b for _ in range(K)]
    tb = [[batch] for _ in range(K)]
    return Population(_fns(), loaders, tb, seed=seed, **kw), batch


def test_population_improves_and_tournament_propagates():
    pop, batch = _mk_pop(4)
    m0 = pop.best_metric(batch)
    pop.run(rounds=3, steps_per_round=10)
    m1 = pop.best_metric(batch)
    assert m1 < m0
    # all trainers should be near the best after several tournaments
    vals = [float(pop.fns.metric(t.params, batch)) for t in pop.trainers]
    assert max(vals) < 0.5


def test_hparam_perturbation_on_adoption():
    pop, batch = _mk_pop(4, perturb_hparams=True)
    lrs0 = [t.hparams["lr"] for t in pop.trainers]
    for _ in range(4):
        pop.train_round(3)
        pop.tournament()
    lrs1 = [t.hparams["lr"] for t in pop.trainers]
    assert lrs0 != lrs1      # losers perturbed their lr


def test_failure_and_recovery():
    pop, batch = _mk_pop(4)
    pop.run(rounds=2, steps_per_round=5)
    pop.fail(1)
    log = pop.tournament()   # must not raise; dead trainer self-pairs
    assert 1 not in [p for i, p in enumerate(log["partner"]) if i != p
                     and i == 1]
    pop.recover(1, from_best_of=batch)
    assert pop.trainers[1].alive
    # recovered trainer adopted the best model
    m_rec = float(pop.fns.metric(pop.trainers[1].params, batch))
    assert m_rec <= pop.best_metric(batch) + 1e-6


def test_elastic_resize_grow_and_shrink():
    pop, batch = _mk_pop(2)
    pop.run(rounds=2, steps_per_round=10)
    best = pop.best_metric(batch)
    loaders = [lambda b=batch: b for _ in range(5)]
    tb = [[batch] for _ in range(5)]
    pop.resize(5, loaders, tb, clone_batch=batch)
    assert len(pop.trainers) == 5
    # new trainers warm-started from the best
    m_new = float(pop.fns.metric(pop.trainers[4].params, batch))
    assert m_new <= best + 1e-6
    pop.resize(3, loaders[:3], tb[:3], clone_batch=batch)
    assert len(pop.trainers) == 3


def test_state_dict_roundtrip():
    pop, batch = _mk_pop(3)
    pop.run(rounds=1, steps_per_round=5)
    state = pop.state_dict()
    pop2, _ = _mk_pop(3, seed=0)
    pop2.load_state_dict(state)
    for a, b in zip(pop.trainers, pop2.trainers):
        np.testing.assert_array_equal(np.asarray(a.params["w"]),
                                      np.asarray(b.params["w"]))
    assert pop2.round == pop.round
