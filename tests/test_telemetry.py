"""Telemetry tests: bounded latency series, per-request span lifecycle
completeness (every submitted rid ends in exactly one terminal event,
shed/cancelled included), Prometheus exposition wellformedness +
histogram/counter agreement, Chrome-trace export schema, loopback
mesh-stats aggregation, the gateway observability endpoints
(readyz gate, content negotiation, /debug/trace, /debug/profile), and
the --no-telemetry path."""
import asyncio
import dataclasses
import json
import re
import threading

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models.lm import init_lm
from repro.serve import telemetry as telemetry_mod
from repro.serve.gateway import Gateway
from repro.serve.metrics import (BoundedSeries, Histogram, LATENCY_BUCKETS,
                                 ServeStats, percentile)
from repro.serve.scheduler import Request, Scheduler

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def served():
    cfg = dataclasses.replace(get_config("qwen3-0.6b", smoke=True),
                              dtype="float32")
    params, _ = init_lm(cfg, KEY)
    return cfg, params


def _prompt(cfg, n=8, seed=3):
    return np.random.default_rng(seed).integers(
        0, cfg.vocab_size, n).astype(np.int32)


@pytest.fixture(scope="module")
def mixed_run(served):
    """One mixed-outcome trace: completions + a deadline shed + a
    cancel, served to completion; returns the scheduler."""
    cfg, params = served
    sched = Scheduler(cfg, params, num_slots=1, max_len=32)
    sched.submit(Request(rid="a", prompt=_prompt(cfg), max_new=4))
    sched.step()                       # occupy the only slot
    sched.submit(Request(rid="late", prompt=_prompt(cfg), max_new=4,
                         ttft_deadline_ms=1e-3))
    sched.submit(Request(rid="ok", prompt=_prompt(cfg), max_new=4))
    sched.submit(Request(rid="victim", prompt=_prompt(cfg), max_new=4))
    assert sched.shed_expired() == ["late"]
    assert sched.cancel("victim")
    results = sched.run()
    assert set(results) == {"a", "ok"}
    return sched


# -- bounded latency series -------------------------------------------------


def test_bounded_series_exact_then_reservoir():
    bs = BoundedSeries(exact_cap=16, reservoir=8)
    vals = [float(i) for i in range(10)]
    for v in vals:
        bs.append(v)
    # short runs answer from the exact list
    assert bs.exact and list(bs) == vals
    assert bs.count == 10 and len(bs) == 10
    assert bs.percentile(50) == percentile(vals, 50)
    assert bs.percentile(95) == percentile(vals, 95)
    # beyond the cap: bounded reservoir + histogram, totals stay exact
    for v in range(10, 200):
        bs.append(float(v))
    assert not bs.exact
    assert len(bs._sample) == 8        # bounded memory
    assert len(bs) == 200 and bs.count == 200
    assert bs.hist.total == 200
    assert bs.sum == pytest.approx(sum(range(200)))
    assert bs.mean == pytest.approx(sum(range(200)) / 200)
    p = bs.percentile(50)
    assert 0.0 <= p <= 199.0           # answered from the reservoir
    assert percentile(bs, 50) == p     # percentile() accepts the series


def test_histogram_bucket_counts():
    h = Histogram(LATENCY_BUCKETS)
    for v in (0.0005, 0.002, 0.002, 0.7, 1e9):
        h.observe(v)
    assert h.total == 5
    assert h.sum == pytest.approx(0.0005 + 0.002 + 0.002 + 0.7 + 1e9)
    by_le = dict(h.bucket_counts())
    assert by_le[0.001] == 1           # 0.0005
    assert by_le[0.0025] == 2          # the two 2ms observations
    assert by_le[1.0] == 1             # 0.7
    # the overflow observation lands only in +Inf (counts[-1])
    assert sum(n for _, n in h.bucket_counts()) == 4
    assert h.counts[-1] == 1


# -- span lifecycle ---------------------------------------------------------


def test_every_request_ends_in_exactly_one_terminal(mixed_run):
    sched = mixed_run
    evs = sched.telemetry.tracer.export()["traceEvents"]
    term = {}
    names = {}
    for ev in evs:
        rid = ev.get("args", {}).get("rid")
        if rid is None:
            continue
        names.setdefault(rid, set()).add(ev["name"])
        if ev.get("args", {}).get("terminal"):
            term.setdefault(rid, []).append(ev["name"])
    for rid in ("a", "late", "ok", "victim"):
        assert len(term.get(rid, [])) == 1, \
            f"{rid}: terminals {term.get(rid)}"
    assert term["a"] == ["finish"] and term["ok"] == ["finish"]
    assert term["late"] == ["shed"]
    assert term["victim"] == ["cancel"]
    # completed requests carry the full chain
    for rid in ("a", "ok"):
        assert {"enqueue", "admit", "first_token", "finish"} <= names[rid]


def test_chrome_trace_export_schema(mixed_run):
    out = mixed_run.telemetry.tracer.export()
    # loads/dumps round-trip: the gateway serves exactly this object
    out = json.loads(json.dumps(out))
    assert isinstance(out["traceEvents"], list) and out["traceEvents"]
    assert out["otherData"]["dropped"] == 0
    for ev in out["traceEvents"]:
        assert ev["ph"] in ("M", "X", "i")
        assert isinstance(ev["name"], str) and "pid" in ev and "tid" in ev
        if ev["ph"] == "X":
            assert ev["dur"] >= 0.0 and ev["ts"] >= 0.0
        if ev["ph"] == "i":
            assert ev["s"] == "t"
    # per-request rows are named via thread_name metadata
    meta = [e for e in out["traceEvents"] if e["ph"] == "M"]
    assert any(e["args"]["name"] == "scheduler" for e in meta)
    assert any(e["args"]["name"] == "req a" for e in meta)


def test_telemetry_disabled_emits_no_events(served):
    cfg, params = served
    sched = Scheduler(cfg, params, num_slots=2, max_len=32,
                      telemetry=False)
    for rid in ("x", "y"):
        sched.submit(Request(rid=rid, prompt=_prompt(cfg), max_new=4))
    results = sched.run()
    assert set(results) == {"x", "y"}
    assert len(sched.telemetry.tracer.events) == 0
    # phase wall-time attribution still accumulates (it feeds /metrics)
    assert sched.telemetry.phase_seconds.get("decode", 0.0) > 0.0


# -- prometheus exposition --------------------------------------------------

_SAMPLE = re.compile(r'^([a-zA-Z_:][a-zA-Z0-9_:]*)'
                     r'(\{[^}]*\})? (NaN|[+-]?[0-9eE.+-]+|[+-]Inf)$')


def _parse_prom(text):
    samples = {}
    for line in text.strip().splitlines():
        if line.startswith("#"):
            assert line.startswith(("# HELP ", "# TYPE "))
            continue
        m = _SAMPLE.match(line)
        assert m, f"malformed sample line: {line!r}"
        samples[m.group(1) + (m.group(2) or "")] = m.group(3)
    return samples


def test_prometheus_exposition(mixed_run):
    sched = mixed_run
    text = telemetry_mod.scheduler_prometheus(sched)
    samples = _parse_prom(text)
    s = sched.stats
    assert samples["repro_serve_completed_total"] == str(s.completed)
    assert samples["repro_serve_shed_deadline_total"] == "1"
    assert samples["repro_serve_cancelled_total"] == "1"
    assert samples["repro_serve_decode_tokens_total"] == \
        str(s.decode_tokens)
    # histogram: one ttft observation per completion, buckets cumulative
    assert samples["repro_serve_ttft_seconds_count"] == str(s.completed)
    infb = 'repro_serve_ttft_seconds_bucket{le="+Inf"}'
    assert samples[infb] == str(s.completed)
    cum = [int(v) for k, v in samples.items()
           if k.startswith("repro_serve_ttft_seconds_bucket")]
    assert cum == sorted(cum), "buckets must be cumulative"
    # per-shard pool occupancy + phase attribution ride along
    assert 'repro_serve_pool_high_water_blocks{shard="0"}' in samples
    assert 'repro_serve_phase_seconds_total{phase="decode"}' in samples


def test_prometheus_text_handles_empty_stats():
    text = telemetry_mod.prometheus_text(ServeStats(slots=2))
    samples = _parse_prom(text)
    assert samples["repro_serve_submitted_total"] == "0"
    assert samples["repro_serve_latency_seconds_count"] == "0"


# -- mesh aggregation (loopback channel, world size 1) ----------------------


def test_mesh_loopback_stats_aggregation(served):
    from repro.serve.mesh import MeshScheduler
    cfg, params = served
    sched = MeshScheduler(cfg, params, num_slots=2, max_len=32)
    for i in range(3):
        sched.submit(Request(rid=i, prompt=_prompt(cfg, seed=i),
                             max_new=4))
    results = sched.run(max_steps=200)
    assert len(results) == 3
    # the loopback gather ran every step: host-0's latest snapshot of
    # itself must equal its own live counters
    assert 0 in sched.remote_stats
    snap = sched.remote_stats[0]
    assert snap["completed"] == sched.stats.completed == 3
    assert snap["decode_steps"] == sched.stats.decode_steps
    assert snap["shards"], "per-data-shard pool snapshots must ride along"
    assert snap["shards"][0]["high_water_blocks"] > 0
    # and the exposition emits them as per-rank mesh series
    samples = _parse_prom(telemetry_mod.scheduler_prometheus(sched))
    assert samples['repro_serve_mesh_completed_total{rank="0"}'] == "3"
    assert 'repro_serve_mesh_pool_high_water_blocks' \
           '{rank="0",shard="0"}' in samples


# -- gateway observability endpoints ----------------------------------------


async def _http(port, method, path, body=None, headers=None):
    r, w = await asyncio.open_connection("127.0.0.1", port)
    payload = json.dumps(body).encode() if body is not None else b""
    extra = "".join(f"{k}: {v}\r\n" for k, v in (headers or {}).items())
    w.write((f"{method} {path} HTTP/1.1\r\nHost: t\r\n{extra}"
             f"Content-Length: {len(payload)}\r\n\r\n").encode() + payload)
    await w.drain()
    data = await r.read()
    w.close()
    return data.decode()


def _status(resp):
    return int(resp.split()[1])


def _body(resp):
    return resp.split("\r\n\r\n", 1)[1]


def _run(coro, timeout=300):
    return asyncio.new_event_loop().run_until_complete(
        asyncio.wait_for(coro, timeout))


def test_gateway_readyz_gates_on_warmup(served):
    cfg, params = served
    sched = Scheduler(cfg, params, num_slots=1, max_len=32)
    gate = threading.Event()
    gw = Gateway(sched, warmup=gate.wait)

    async def go():
        await gw.start()
        cold = await _http(gw.port, "GET", "/readyz")
        live = await _http(gw.port, "GET", "/healthz")
        gate.set()                     # weight load / compile finished
        while _status(await _http(gw.port, "GET", "/readyz")) != 200:
            await asyncio.sleep(0.01)
        warm = await _http(gw.port, "GET", "/readyz")
        await gw.stop()
        return cold, live, warm

    cold, live, warm = _run(go())
    assert _status(cold) == 503 and not json.loads(_body(cold))["ready"]
    # liveness stays 200 through cold start — only readiness gates
    assert _status(live) == 200 and not json.loads(_body(live))["ready"]
    wd = json.loads(_body(warm))
    assert wd["ready"] and "queued" in wd and "slots_busy" in wd


def test_gateway_metrics_trace_and_profile(served, tmp_path):
    cfg, params = served
    sched = Scheduler(cfg, params, num_slots=1, max_len=32)
    gw = Gateway(sched)
    prof_dir = str(tmp_path / "prof")

    async def go():
        await gw.start()
        armed = await _http(gw.port, "POST", "/debug/profile",
                            {"steps": 2, "dir": prof_dir})
        bad = await _http(gw.port, "POST", "/debug/profile",
                          {"steps": 0})
        gen = await _http(gw.port, "POST", "/v1/generate",
                          {"prompt": _prompt(cfg).tolist(), "max_new": 4,
                           "rid": "r", "stream": False})
        prom = await _http(gw.port, "GET", "/metrics")
        js = await _http(gw.port, "GET", "/metrics",
                         headers={"Accept": "application/json"})
        trace = await _http(gw.port, "GET", "/debug/trace")
        await gw.stop()
        return armed, bad, gen, prom, js, trace

    armed, bad, gen, prom, js, trace = _run(go())
    assert _status(armed) == 200 and json.loads(_body(armed))["armed"]
    assert _status(bad) == 400
    assert _status(gen) == 200
    # default scrape is Prometheus text with the versioned content type
    assert "text/plain; version=0.0.4" in prom
    samples = _parse_prom(_body(prom))
    assert samples["repro_serve_completed_total"] == "1"
    # JSON summary preserved behind content negotiation
    jd = json.loads(_body(js))
    assert jd["completed"] == 1 and "phase_seconds" in jd
    # trace export: full chain for the gateway-served request
    td = json.loads(_body(trace))
    names = {e["name"] for e in td["traceEvents"]
             if e.get("args", {}).get("rid") == "r"}
    assert {"enqueue", "first_token", "finish"} <= names
    # the armed window wrapped real steps and closed
    assert sched.telemetry.profiles_taken == 1
    assert (tmp_path / "prof").is_dir()


# -- structured JSON logs ---------------------------------------------------


def test_json_log_events(capsys):
    telemetry_mod.enable_json_logs()
    try:
        telemetry_mod.log_event("unit", n=1, bad=float("nan"),
                                nested={"t": (1, 2)})
        st = ServeStats(slots=1)
        st.start()
        st.stop()
        st.report()
    finally:
        telemetry_mod.enable_json_logs(False)
    lines = [ln for ln in capsys.readouterr().out.splitlines()
             if ln.startswith("{")]
    recs = [json.loads(ln) for ln in lines]   # every line is valid JSON
    unit = next(r for r in recs if r["event"] == "unit")
    assert unit["n"] == 1 and unit["bad"] is None
    assert unit["nested"] == {"t": [1, 2]}
    assert unit["ts_monotonic"] > 0
    report = next(r for r in recs if r["event"] == "serve_report")
    assert report["slots"] == 1 and "tokens_per_s" in report
    # disabled again: no further records
    telemetry_mod.log_event("after")
    assert "after" not in capsys.readouterr().out


# -- robustness counters ----------------------------------------------------


def test_prometheus_robustness_counters_present():
    """The fault-tolerance counters ride the standard exposition: typed,
    helped, zero-valued on an idle server (so dashboards can alert on
    any increase without first causing a fault)."""
    s = ServeStats(slots=2)
    text = telemetry_mod.prometheus_text(s)
    samples = _parse_prom(text)
    for name in ("fault_injected", "swap_rejected_corrupt",
                 "plan_retries", "journal_replayed"):
        key = f"repro_serve_{name}_total"
        assert samples[key] == "0", key
        assert f"# TYPE {key} counter" in text
    s.fault_injected = 3
    s.swap_rejected_corrupt = 1
    s.plan_retries = 2
    s.journal_replayed = 4
    samples = _parse_prom(telemetry_mod.prometheus_text(s))
    assert samples["repro_serve_fault_injected_total"] == "3"
    assert samples["repro_serve_swap_rejected_corrupt_total"] == "1"
    assert samples["repro_serve_plan_retries_total"] == "2"
    assert samples["repro_serve_journal_replayed_total"] == "4"
    d = s.as_dict()
    assert d["fault_injected"] == 3 and d["journal_replayed"] == 4
