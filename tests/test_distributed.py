"""Multi-process mesh serving tests (real OS processes over
``jax.distributed``): 2-process vs 1-process token identity through
the spawn CLI, follower-replica result identity, and the
coordination-service channel's dead-peer timeout (a clean error
instead of a hang)."""
import json
import os
import socket
import subprocess
import sys

import pytest

from repro.launch.distributed import build_parser, find_free_port

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _can_bind() -> bool:
    """The coordinator needs a bindable local TCP port."""
    try:
        with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
            s.bind(("127.0.0.1", 0))
        return True
    except OSError:
        return False


needs_loopback = pytest.mark.skipif(
    not _can_bind(), reason="cannot bind a local TCP port "
                            "(no loopback for the jax coordinator)")


def _run_cli(args, out_json, timeout=560):
    env = dict(os.environ)
    env.update({"JAX_PLATFORMS": "cpu",
                "PYTHONPATH": os.path.join(ROOT, "src"),
                "PYTHONUNBUFFERED": "1"})
    cmd = [sys.executable, "-m", "repro.launch.distributed",
           "--smoke", "--requests", "3", "--max-new", "6",
           "--prompt-lens", "8,12", "--out-json", out_json, *args]
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=timeout)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
    return r


def test_build_parser_smoke():
    args = build_parser().parse_args(
        ["--procs", "2", "--step-timeout", "5"])
    assert args.procs == 2 and args.step_timeout == 5.0
    assert find_free_port() > 0


@needs_loopback
def test_two_process_token_identity(tmp_path):
    """The tentpole acceptance: a 2-process run (host-0 scheduler +
    follower replica, plans over the coordination service) produces
    token-identical results to the single-process run, on BOTH
    processes."""
    one = str(tmp_path / "one.json")
    two = str(tmp_path / "two.json")
    _run_cli(["--procs", "1"], one)
    r = _run_cli(["--procs", "2", "--step-timeout", "120"], two)
    assert "CoordServiceChannel" in r.stdout
    a = json.load(open(one))
    b = json.load(open(two))
    follower = json.load(open(two + ".p1"))
    assert a["results"] == b["results"] == follower["results"]
    assert len(a["results"]) == 3
    assert all(len(t) == 6 for t in a["results"].values())
    # both processes saw the same scheduler trajectory
    assert b["stats"]["decode_steps"] == follower["stats"]["decode_steps"]
    assert b["stats"]["prefills"] == follower["stats"]["prefills"]
    # mesh-wide stats aggregation: host-0's export covers every rank,
    # and the gathered counters equal each process's own stats
    ms = b["mesh_stats"]
    assert sorted(ms) == ["0", "1"]
    for rank, own in (("0", b), ("1", follower)):
        for k in ("completed", "decode_steps", "prefills",
                  "decode_tokens"):
            assert ms[rank][k] == own["stats"][k], (rank, k)
        assert ms[rank]["shards"][0]["high_water_blocks"] > 0
    # the Prometheus sidecar host-0 writes covers both ranks
    prom = open(two + ".prom").read()
    assert prom.startswith("# HELP repro_serve_")
    assert f'repro_serve_mesh_completed_total{{rank="1"}} ' \
           f'{follower["stats"]["completed"]}' in prom


@needs_loopback
def test_replicated_feed_dedupes(tmp_path):
    """``--feed replicated``: followers also submit the trace locally;
    the plan's submits must be recognized as already-local copies (no
    duplicate enqueue), with identical results."""
    two = str(tmp_path / "rep.json")
    _run_cli(["--procs", "2", "--feed", "replicated",
              "--step-timeout", "120"], two)
    host0 = json.load(open(two))
    follower = json.load(open(two + ".p1"))
    assert host0["results"] == follower["results"]
    assert follower["stats"]["completed"] == 3


@needs_loopback
def test_dead_peer_times_out_not_hangs():
    """A follower that dies mid-serve must surface as a broadcast
    timeout error on the survivor, not an indefinite hang."""
    port = find_free_port()
    script = r"""
import os
import sys
import jax
pid = int(sys.argv[1])
jax.distributed.initialize(coordinator_address="127.0.0.1:%d",
                           num_processes=2, process_id=pid,
                           initialization_timeout=60)
from repro.serve.mesh import CoordServiceChannel, StepPlan
ch = CoordServiceChannel(timeout_s=3.0, namespace="t/dead")
if pid == 1:
    os._exit(0)          # hard death before joining the step barrier
try:
    ch.broadcast(StepPlan())
except RuntimeError as e:
    assert "timed out" in str(e), e
    print("TIMEOUT-OK", flush=True)
    os._exit(0)          # skip the atexit shutdown handshake: the
                         # peer it would wait for is already gone
print("UNEXPECTED: broadcast returned", flush=True)
os._exit(1)
""" % port
    env = dict(os.environ)
    env.update({"JAX_PLATFORMS": "cpu",
                "PYTHONPATH": os.path.join(ROOT, "src")})
    procs = [subprocess.Popen([sys.executable, "-c", script, str(i)],
                              env=env, stdout=subprocess.PIPE,
                              stderr=subprocess.PIPE, text=True)
             for i in range(2)]
    out0, err0 = procs[0].communicate(timeout=120)
    procs[1].communicate(timeout=120)
    assert procs[0].returncode == 0, f"{out0}\n{err0}"
    assert "TIMEOUT-OK" in out0
