"""Online LTFB arena tests (serve/arena.py): the promotion rule
(min-samples + margin + hysteresis), deterministic drafter routing,
journal match/promotion replay incl. torn-tail crash consistency, the
served-stream -> token-shard write-back round-trip with crash/resume
rid dedup, the gateway admin surface, and the end-to-end
train -> serve -> train acceptance loop."""
import dataclasses
import glob
import json
import os

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models.lm import init_lm
from repro.serve.arena import (Arena, ArenaConfig, MemberStats,
                               TokenWriteback, safe_rate)
from repro.serve.journal import RequestJournal, replay_arena
from repro.serve.scheduler import Request, Scheduler

KEY = jax.random.PRNGKey(0)


def _f32_cfg(arch="qwen3-0.6b"):
    return dataclasses.replace(get_config(arch, smoke=True),
                               dtype="float32")


def _dummy_arena(n=2, cfg=None, **kw):
    """An arena over trivially small 'weights' for rule-only tests."""
    members = {f"trainer_{i}": {"w": np.full((2,), float(i))}
               for i in range(n)}
    return Arena(members, "trainer_0", cfg or ArenaConfig(**kw))


def _write_population(pop_dir, params_list, wins):
    """A real launch/ltfb.py-shaped population checkpoint dir."""
    from repro.checkpoint import ckpt
    pop = {"round": 0, "trainers": [
        {"params": p, "opt_state": {"t": np.zeros((1,), np.float32)},
         "hparams": {"lr": 1e-3}, "steps": 1, "alive": True,
         "wins": w, "adoptions": 0}
        for p, w in zip(params_list, wins)]}
    ckpt.save_population(str(pop_dir), 0, pop)


# ---------------------------------------------------------------------------
# satellite: zero-guarded accept-rate accounting
# ---------------------------------------------------------------------------


def test_safe_rate_and_empty_window_stats():
    assert safe_rate(0, 0) == 0.0
    assert safe_rate(3, 4) == 0.75
    m = MemberStats(window=4)
    assert m.rate == 0.0 and m.win_offered == 0      # empty window: no NaN
    m.add(0, 0)                                      # zero-proposal round
    assert m.rate == 0.0
    for _ in range(6):
        m.add(4, 3)
    assert m.win_offered == 16                       # window slid to 4 rounds
    assert m.rate == pytest.approx(0.75)
    assert m.offered == 24 and m.accepted == 18      # lifetime keeps all
    d = m.as_dict()
    m2 = MemberStats(window=4)
    m2.load(d)
    assert m2.as_dict() == d


def test_arena_counters_never_nan_and_json_safe():
    a = _dummy_arena(3)
    snap, counters = a.snapshot(), a.counters()
    json.dumps(snap), json.dumps(counters)           # JSON-safe throughout
    for n, c in counters["members"].items():
        assert c["accept_rate"] == 0.0, n


# ---------------------------------------------------------------------------
# promotion rule: min-samples + margin + hysteresis
# ---------------------------------------------------------------------------


def test_promotion_rule_min_samples_margin_hysteresis():
    a = _dummy_arena(2, min_samples=8, margin=0.2, hysteresis=2,
                     window=64)
    ch = "trainer_1"
    assert a.active_drafter == ch
    a.record_spec(4, 4)
    assert a.decide(8) is None          # only 4 offered < min_samples
    a.record_spec(4, 0)                 # 8 offered, rate 0.5 >= 0 + 0.2
    assert a.decide(16) is None         # qualifies -> streak 1 < hysteresis
    assert a.streak == 1 and a.streak_member == ch
    assert a.decide(24) == ch           # second consecutive win -> promote
    params = a.promote(ch, 24)
    assert params is a.params[ch]
    assert a.champion == ch and a.generation == 1 and a.promotions == 1
    assert a.baseline == pytest.approx(0.5)   # winner's rate at promotion
    assert a.streak == 0 and a.streak_member is None
    assert all(not m.window for m in a.members.values())  # fresh measurement
    # the dethroned champion now drafts; beating baseline needs 0.5 + margin
    assert a.active_drafter == "trainer_0"
    a.record_spec(16, 10)               # rate 0.625 < 0.7
    assert a.decide(32) is None and a.streak == 0


def test_promotion_rule_margin_resets_streak_on_candidate_change():
    a = _dummy_arena(3, min_samples=4, margin=0.1, hysteresis=2,
                     policy="shadow")
    a.members["trainer_1"].add(8, 6)
    assert a.decide(8) is None and a.streak_member == "trainer_1"
    a.members["trainer_2"].add(8, 8)    # a better candidate appears
    assert a.decide(16) is None         # streak restarts on trainer_2
    assert a.streak == 1 and a.streak_member == "trainer_2"
    assert a.decide(24) == "trainer_2"


def test_forced_promotion_overrides_rule_and_validates():
    a = _dummy_arena(2, min_samples=10 ** 6)
    a.forced = "trainer_1"
    assert a.decide(1) == "trainer_1" and a.last_forced
    assert a.forced is None             # consumed
    a.forced = "trainer_0"              # already champion: ignored
    assert a.decide(2) is None and not a.last_forced


# ---------------------------------------------------------------------------
# routing: pure function of (step, arena state) on every host
# ---------------------------------------------------------------------------


def test_drafter_routing_policies_deterministic():
    shadow = _dummy_arena(3, policy="shadow", rotate_every=4)
    assert [shadow.drafter_for_step(s) for s in (0, 3, 4, 8, 12)] \
        == ["trainer_1", "trainer_1", "trainer_2", "trainer_1",
            "trainer_2"]
    champ = _dummy_arena(3, policy="champion", rotate_every=4)
    champ.members["trainer_2"].add(8, 8)
    assert champ.drafter_for_step(0) == "trainer_2"   # best by window rate
    eps = _dummy_arena(3, policy="epsilon", rotate_every=4, epsilon=0.5)
    eps.members["trainer_2"].add(8, 8)
    # period 2: even stints explore round-robin, odd stints exploit
    assert eps.drafter_for_step(0) == "trainer_1"
    assert eps.drafter_for_step(4) == "trainer_2"
    # two "hosts" with identical state agree at every step
    twin = _dummy_arena(3, policy="shadow", rotate_every=4)
    assert all(shadow.drafter_for_step(s) == twin.drafter_for_step(s)
               for s in range(40))


def test_arena_requires_two_members_and_known_champion():
    with pytest.raises(ValueError, match=">= 2 resident members"):
        Arena({"trainer_0": {}}, "trainer_0")
    with pytest.raises(ValueError, match="not in the roster"):
        Arena({"a": {}, "b": {}}, "c")
    with pytest.raises(ValueError, match="unknown arena policy"):
        ArenaConfig(policy="random")


# ---------------------------------------------------------------------------
# satellite: journal replay round-trip + torn-tail crash consistency
# ---------------------------------------------------------------------------


def test_journal_arena_replay_roundtrip_and_torn_promotion(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    j = RequestJournal(path)
    a = _dummy_arena(2, min_samples=4, hysteresis=1, margin=0.1)
    a.record_spec(8, 7)
    a.matches += 1
    j.record_match(8, a.snapshot())
    pre = a.snapshot()                   # durable pre-promotion state
    winner = "trainer_1"
    a.promote(winner, 16)
    j.record_promotion(16, winner, "trainer_0",
                       a.last_promotion["rate"], False, a.snapshot())
    post = a.snapshot()
    j.close()

    # clean replay: the post-promotion snapshot, restored token-identically
    state = replay_arena(path)
    b = _dummy_arena(2, min_samples=4, hysteresis=1, margin=0.1)
    b.restore(state)
    assert b.snapshot() == post
    assert b.champion == "trainer_1" and b.generation == 1
    assert b.baseline == pytest.approx(7 / 8)

    # torn tail: cut the promotion record mid-write -> it is NOT durable,
    # and because the journal sync is ordered BEFORE the weight swap the
    # crashed generation never served the winner: replay must land on the
    # pre-promotion match snapshot, exactly
    raw = open(path, "rb").read()
    lines = raw.rstrip(b"\n").split(b"\n")
    torn = b"\n".join(lines[:-1]) + b"\n" + lines[-1][:len(lines[-1]) // 2]
    open(path, "wb").write(torn)
    c = _dummy_arena(2, min_samples=4, hysteresis=1, margin=0.1)
    c.restore(replay_arena(path))
    assert c.snapshot() == pre
    assert c.champion == "trainer_0" and c.generation == 0
    # the windows survived byte-for-byte: the next decide() re-fires the
    # promotion the crash swallowed
    assert c.decide(16) == "trainer_1"


def test_journal_arena_records_do_not_disturb_request_replay(tmp_path):
    from repro.serve.journal import replay
    path = str(tmp_path / "journal.jsonl")
    j = RequestJournal(path)
    j.record_submit(Request(rid="r1", prompt=np.arange(4, dtype=np.int32),
                            max_new=8))
    a = _dummy_arena(2)
    j.record_match(1, a.snapshot())
    j.step_commit({"r1": [5, 6]}, [])
    j.record_promotion(2, "trainer_1", "trainer_0", 0.5, False,
                       a.snapshot())
    j.step_commit({"r1": [7]}, [])
    j.close()
    ent = replay(path)["r1"]
    assert ent.tokens == [5, 6, 7] and not ent.done
    assert replay_arena(path) is not None


def test_replay_arena_missing_or_empty_journal(tmp_path):
    assert replay_arena(str(tmp_path / "nope.jsonl")) is None
    path = str(tmp_path / "empty.jsonl")
    j = RequestJournal(path)
    j.record_submit(Request(rid="r", prompt=np.arange(3, dtype=np.int32),
                            max_new=2))
    j.close()
    assert replay_arena(path) is None    # no arena records -> None


# ---------------------------------------------------------------------------
# satellite: write-back round-trip + crash/resume rid dedup
# ---------------------------------------------------------------------------


def test_writeback_shards_reingest_into_datastore(tmp_path):
    from repro.data.tokens import list_token_shards, read_token_shard
    from repro.datastore.store import DataStore
    root = str(tmp_path / "wb")
    wb = TokenWriteback(root, seq_len=8, vocab=100, samples_per_file=4)
    streams = {f"r{i}": list(range(1, 4 + i)) for i in range(8)}
    for rid, s in streams.items():
        assert wb.add(rid, s)
    wb.close()
    shards = list_token_shards(root)
    assert len(shards) == 2              # 8 rows / 4 per file, all full
    rows = read_token_shard(shards[0])["tokens"]
    assert rows.shape == (4, 9) and rows.dtype == np.int32
    assert rows[0].tolist() == [1, 2, 3] + [0] * 6   # zero-padded
    # truncation: a stream longer than seq_len + 1 keeps the head
    assert read_token_shard(shards[1])["tokens"][3, :].tolist() \
        == list(range(1, 10))
    # the shard dir IS a datastore manifest: uniform bundles, right count
    store = DataStore(shards, read_token_shard, num_ranks=2,
                      mode="preload")
    store.preload()
    assert store.num_samples == 8 and store.samples_per_file == 4
    perm = store.epoch_permutation(0)
    batch = store.get_batch(perm, 0, 8, consumer_rank=0)
    assert batch["tokens"].shape == (8, 9)


def test_writeback_dedups_rids_across_crash_resume(tmp_path):
    root = str(tmp_path / "wb")
    wb = TokenWriteback(root, seq_len=4, vocab=50, samples_per_file=2)
    assert wb.add("a", [1, 2]) and wb.add("b", [3, 4])
    assert not wb.add("a", [1, 2])       # same-generation dedup
    assert wb.add("c", [5])              # buffered, shard not full
    # crash (no close) -> new generation over the same dir
    wb2 = TokenWriteback(root, seq_len=4, vocab=50, samples_per_file=2)
    assert not wb2.add("a", [1, 2])      # written rid survives the crash
    assert not wb2.add("c", [5])         # pending rid survives too
    assert wb2.add("d", [6, 7])          # completes the second shard
    from repro.data.tokens import list_token_shards, read_token_shard
    shards = list_token_shards(root)
    assert len(shards) == 2 and wb2._next_shard == 2
    all_rows = np.concatenate([read_token_shard(p)["tokens"]
                               for p in shards])
    assert all_rows.shape == (4, 5)      # a,b,c,d exactly once
    d = wb2.as_dict()
    assert d["rows_written"] == 4 and d["pending_rows"] == 0


def test_writeback_rejects_out_of_vocab_rows(tmp_path):
    wb = TokenWriteback(str(tmp_path / "wb"), seq_len=4, vocab=10)
    with pytest.raises(ValueError, match="token id 11 >= vocab 10"):
        wb.add("r", [1, 11])


def test_writeback_state_file_corruption_falls_back_to_shards(tmp_path):
    root = str(tmp_path / "wb")
    wb = TokenWriteback(root, seq_len=2, vocab=10, samples_per_file=1)
    wb.add("a", [1])
    open(os.path.join(root, TokenWriteback.STATE), "w").write("{torn")
    wb2 = TokenWriteback(root, seq_len=2, vocab=10, samples_per_file=1)
    assert wb2._next_shard == 1          # counts existing shards instead


# ---------------------------------------------------------------------------
# satellite: registry errors name the offending member
# ---------------------------------------------------------------------------


def test_check_draft_compat_error_names_member():
    from repro.serve.registry import check_draft_compat
    cfg = _f32_cfg()
    bad = dataclasses.replace(cfg, vocab_size=cfg.vocab_size + 1)
    with pytest.raises(ValueError) as e:
        check_draft_compat(cfg, bad, member="draft/step3")
    msg = str(e.value)
    assert "draft member 'draft/step3'" in msg
    assert str(cfg.vocab_size) in msg and str(bad.vocab_size) in msg


def test_load_population_error_names_member_path(tmp_path):
    from repro.serve.registry import load_population_params
    cfg = _f32_cfg()
    like, _ = init_lm(cfg, KEY)
    _write_population(tmp_path, [jax.tree.map(np.asarray, like)] * 2,
                      [1, 0])
    os.remove(str(tmp_path / "step_0_trainer_1.ckpt"))
    with pytest.raises(ValueError, match="trainer_1") as e:
        load_population_params(str(tmp_path), 0, like)
    assert "step_0_trainer_1.ckpt" in str(e.value)


# ---------------------------------------------------------------------------
# gateway admin surface
# ---------------------------------------------------------------------------


def test_gateway_population_and_promote_endpoints():
    import asyncio
    from repro.serve.gateway import Gateway
    cfg = _f32_cfg()
    params, _ = init_lm(cfg, KEY)
    host = jax.tree.map(np.asarray, params)
    members = {"trainer_0": host, "trainer_1": host}
    # min_samples is unreachable: only the forced override can promote
    arena = Arena(members, "trainer_0",
                  ArenaConfig(policy="shadow", min_samples=10 ** 6,
                              hysteresis=1, check_every=1))
    sched = Scheduler(cfg, params, num_slots=2, max_len=32,
                      block_size=4, draft_params=host, spec_tokens=2,
                      arena=arena)
    gw = Gateway(sched)

    async def _http(port, method, path, body=None):
        r, w = await asyncio.open_connection("127.0.0.1", port)
        payload = json.dumps(body).encode() if body is not None else b""
        w.write((f"{method} {path} HTTP/1.1\r\nHost: t\r\n"
                 f"Content-Length: {len(payload)}\r\n\r\n").encode()
                + payload)
        await w.drain()
        data = await r.read()
        w.close()
        return data.decode()

    async def go():
        await gw.start()
        pop = await _http(gw.port, "GET", "/population")
        bad = await _http(gw.port, "POST", "/arena/promote",
                          {"member": "nope"})
        self_p = await _http(gw.port, "POST", "/arena/promote",
                             {"member": "trainer_0"})
        ok = await _http(gw.port, "POST", "/arena/promote",
                         {"member": "trainer_1"})
        # one real request drives the scheduler loop -> the queued
        # control op applies and the forced promotion fires
        await _http(gw.port, "POST", "/v1/generate",
                    {"rid": "g", "prompt": [1, 2, 3], "max_new": 4,
                     "stream": False})
        pop2 = await _http(gw.port, "GET", "/population")
        await gw.stop()
        return pop, bad, self_p, ok, pop2

    pop, bad, self_p, ok, pop2 = asyncio.new_event_loop() \
        .run_until_complete(asyncio.wait_for(go(), 300))
    assert " 200 " in pop.splitlines()[0]
    snap = json.loads(pop.split("\r\n\r\n", 1)[1])
    assert snap["champion"] == "trainer_0" and "members" in snap
    assert " 400 " in bad.splitlines()[0]
    assert " 400 " in self_p.splitlines()[0]
    assert json.loads(ok.split("\r\n\r\n", 1)[1]) \
        == {"queued": True, "member": "trainer_1",
            "champion": "trainer_0"}
    snap2 = json.loads(pop2.split("\r\n\r\n", 1)[1])
    assert snap2["champion"] == "trainer_1"      # forced promotion applied
    assert snap2["promotions"] == 1
    assert sched.stats.arena_promotions == 1


def test_gateway_population_404_without_arena():
    import asyncio
    from repro.serve.gateway import Gateway
    cfg = _f32_cfg()
    params, _ = init_lm(cfg, KEY)
    gw = Gateway(Scheduler(cfg, params, num_slots=1, max_len=16))

    async def go():
        await gw.start()
        pop = await asyncio.open_connection("127.0.0.1", gw.port)
        r, w = pop
        w.write(b"GET /population HTTP/1.1\r\nHost: t\r\n"
                b"Content-Length: 0\r\n\r\n")
        await w.drain()
        data = await r.read()
        w.close()
        await gw.stop()
        return data.decode()

    resp = asyncio.new_event_loop().run_until_complete(
        asyncio.wait_for(go(), 120))
    assert " 404 " in resp.splitlines()[0]
    assert "--arena" in resp


# ---------------------------------------------------------------------------
# acceptance: the full train -> serve -> train loop
# ---------------------------------------------------------------------------


def test_arena_e2e_promotion_writeback_and_crash_resume(tmp_path):
    """2-member arena from a real population dir: the challenger's
    accept window crosses the margin, the transactional promotion fires
    through the drain-aware swap (streams stay token-identical to a
    plain no-arena run), finished streams land as datastore token
    shards, and a killed generation resumes token-identically from the
    journal."""
    from repro.data.tokens import list_token_shards, read_token_shard
    from repro.serve.registry import population_steps

    cfg = _f32_cfg()
    like, _ = init_lm(cfg, KEY)
    host = jax.tree.map(np.asarray, like)
    pop_dir = tmp_path / "pop"
    # identical twins: the challenger drafting for the champion accepts
    # at rate 1.0 (greedy), so the margin is crossed deterministically
    _write_population(pop_dir, [host, host], wins=[1, 0])
    assert population_steps(str(pop_dir)) == [0]

    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, cfg.vocab_size, 6 + i).astype(np.int32)
               for i in range(4)]
    jpath = str(tmp_path / "journal.jsonl")
    acfg = ArenaConfig(policy="shadow", window=64, min_samples=4,
                       margin=0.3, hysteresis=1, check_every=2,
                       seq_len=16, samples_per_file=4)
    arena = Arena.from_population(
        str(pop_dir), like, acfg, writeback_dir=str(tmp_path / "wb"),
        vocab=cfg.vocab_size)
    assert arena.champion == "trainer_0"         # most offline wins
    journal = RequestJournal(jpath)
    sched = Scheduler(cfg, arena.champion_params, num_slots=2,
                      max_len=48, block_size=4,
                      draft_params=arena.drafter_params, spec_tokens=3,
                      swap_mode="drain", journal=journal, arena=arena)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new=8))
    results = sched.run(max_steps=400)
    journal.close()
    arena.close()

    # the challenger crossed baseline + margin and was promoted through
    # the checksum-verified transactional swap
    assert arena.promotions == 1 and arena.champion == "trainer_1"
    assert sched.stats.arena_promotions == 1
    assert sched.stats.arena_matches == arena.matches > 0
    # twins accept (almost) everything — max_new / EOS truncation trims
    # a round's tail, so the rate sits just under 1.0
    assert 0.3 < arena.baseline <= 1.0
    archives = sorted(os.listdir(pop_dir / "arena"))
    assert any("retired_trainer_0" in f and f.endswith(".ckpt")
               for f in archives)
    assert any("champion_trainer_1" in f and f.endswith(".ckpt")
               for f in archives)
    assert any(f.endswith(".sha256") for f in archives)

    # drain-aware swap + twin weights: streams are token-identical to a
    # plain no-arena scheduler on the same prompts
    plain = Scheduler(cfg, like, num_slots=2, max_len=48, block_size=4)
    for i, p in enumerate(prompts):
        plain.submit(Request(rid=i, prompt=p, max_new=8))
    base = plain.run(max_steps=400)
    assert {i: results[i].tolist() for i in results} \
        == {i: base[i].tolist() for i in base}

    # write-back: 4 finished streams -> one full datastore token shard,
    # rows = prompt + generated, zero-padded to seq_len + 1
    shards = list_token_shards(str(tmp_path / "wb"))
    assert len(shards) == 1
    rows = read_token_shard(shards[0])["tokens"]
    assert rows.shape == (4, 17)
    full0 = list(prompts[0]) + list(results[0])
    assert rows[0, :len(full0)].tolist() == [int(t) for t in full0]

    # the journal holds durable match + promotion records
    recs = [json.loads(l) for l in open(jpath) if l.strip()]
    kinds = [r["t"] for r in recs]
    assert "match" in kinds and kinds.count("promotion") == 1
    promo = next(r for r in recs if r["t"] == "promotion")
    assert promo["winner"] == "trainer_1" and not promo["forced"]

    # kill/resume: a new generation over the same population dir + journal
    # reconstructs the last durable arena snapshot token-identically
    # (weights come from the roster, state from the journal)
    last = replay_arena(jpath)
    arena2 = Arena.from_population(str(pop_dir), like, acfg)
    arena2.restore(last)
    s2 = {k: v for k, v in arena2.snapshot().items() if k != "writeback"}
    assert s2 == {k: v for k, v in last.items() if k != "writeback"}
    assert arena2.champion == arena.champion == "trainer_1"
    assert arena2.generation == arena.generation == 1
    assert arena2.baseline == pytest.approx(arena.baseline)

    # resume refuses a roster that does not hold the journaled members
    tiny = Arena({"x": host, "y": host}, "x", acfg)
    with pytest.raises(ValueError, match="trainer_0"):
        tiny.restore(replay_arena(jpath))


def test_arena_prometheus_series(tmp_path):
    """arena_accept_rate / arena_served_tokens gauges carry a member
    label; promotions export as a counter — locally and aggregated
    mesh-wide with a rank label."""
    from repro.serve.metrics import ServeStats
    from repro.serve.telemetry import prometheus_text
    a = _dummy_arena(2)
    a.record_spec(8, 6)
    a.members["trainer_0"].served_tokens = 42
    a.promotions = 1
    stats = ServeStats()
    stats.arena_matches, stats.arena_promotions = 3, 1
    text = prometheus_text(stats, arena=a.counters())
    assert ('repro_serve_arena_accept_rate{member="trainer_1"} 0.75'
            in text)
    assert ('repro_serve_arena_served_tokens{member="trainer_0"} 42'
            in text)
    assert "repro_serve_arena_promotions_total 1" in text
    assert "repro_serve_arena_matches_total 3" in text
    # mesh aggregation: per-rank series, ONE header per family
    remote = {1: {"completed": 0, "arena": a.counters()}}
    text = prometheus_text(stats, remote_stats=remote, arena=a.counters())
    assert ('repro_serve_mesh_arena_accept_rate'
            '{rank="1",member="trainer_1"} 0.75') in text
    assert text.count("# TYPE repro_serve_mesh_arena_accept_rate") == 1


def test_mesh_arena_follower_replays_host0_promotion():
    """On a 4x2 emulated mesh, host 0's match evaluation promotes the
    challenger and the promotion name rides the StepPlan wire encoding:
    a follower replica replays it to an identical end state without
    ever running a match itself."""
    import subprocess
    import sys
    env = dict(os.environ)
    env.update({"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
                "JAX_PLATFORMS": "cpu", "PYTHONPATH": "src"})
    script = r"""
import dataclasses, jax, numpy as np
from repro.configs.registry import get_config
from repro.models.lm import init_lm
from repro.serve.scheduler import Request
from repro.serve.mesh import MeshScheduler, StepPlan
from repro.serve.arena import Arena, ArenaConfig

cfg = dataclasses.replace(get_config("qwen3-0.6b", smoke=True),
                          dtype="float32")
params, _ = init_lm(cfg, jax.random.PRNGKey(0))
host = jax.tree.map(np.asarray, params)
rng = np.random.default_rng(9)
prompts = [rng.integers(1, cfg.vocab_size, 6 + i).astype(np.int32)
           for i in range(4)]
acfg = ArenaConfig(policy="shadow", min_samples=4, margin=0.3,
                   hysteresis=1, check_every=2, window=64)

def mk(rank):
    arena = Arena({"trainer_0": host, "trainer_1": host}, "trainer_0",
                  acfg, rank=rank)
    s = MeshScheduler(cfg, arena.champion_params, num_slots=4,
                      max_len=48, block_size=4, mesh_shape=(4, 2),
                      swap_mode="drain",
                      draft_params=arena.drafter_params, spec_tokens=3,
                      arena=arena)
    for i, p in enumerate(prompts):
        s.submit(Request(rid=i, prompt=p, max_new=6))
    return s

host0, fol = mk(0), mk(1)
steps = 0
while (host0.queue or host0.active or host0.prefilling) and steps < 200:
    plan = host0.step()
    fol.step(plan=StepPlan.decode(plan.encode()))    # the wire
    steps += 1
assert host0.arena.promotions == 1, host0.arena.promotions
assert fol.arena.promotions == 1
assert fol.arena.champion == host0.arena.champion == "trainer_1"
assert fol.arena.matches == 0            # followers never decide
assert host0.arena.matches > 0
assert host0.results.keys() == fol.results.keys()
for k in host0.results:
    assert host0.results[k].tolist() == fol.results[k].tolist()
p = StepPlan.decode(StepPlan(promote="trainer_1").encode())
assert p.promote == "trainer_1"
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]


def test_serve_cli_rejects_arena_with_registry_flags(tmp_path):
    from repro.launch import serve
    with pytest.raises(SystemExit):
        serve.main(["--arch", "qwen3-0.6b", "--smoke",
                    "--arena", str(tmp_path), "--ckpt-dir",
                    str(tmp_path), "--requests", "1"])
