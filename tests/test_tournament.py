"""Integration tests for the unified LTFB tournament orchestrator:
K=4 tournament rounds through a real on-disk DataStore (tmp_path
bundles), exchange-byte accounting, winner propagation, checkpoint/
restart round-trip, elastic rescale, token-shard manifests, and the
``repro.launch.ltfb`` CLI."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs.base import OptimizerConfig
from repro.configs.icf_cyclegan import CycleGANConfig
from repro.core.population import TrainerFns
from repro.core.tournament import (DataPlan, TournamentConfig,
                                   TournamentOrchestrator)
from repro.data import jag, tokens
from repro.train.steps import make_gan_steps

CCFG = CycleGANConfig(
    name="icf-cyclegan-test", image_size=8,
    fwd_hidden=(16, 16), inv_hidden=(16, 16), disc_hidden=(16,),
    enc_hidden=(32,), dec_hidden=(32,))


@pytest.fixture(scope="module")
def bundle_files(tmp_path_factory):
    # 9 bundles: the orchestrator reserves the last as the shared
    # held-out validation file, leaving 8 to partition across trainers
    root = tmp_path_factory.mktemp("tourn_jag")
    return jag.write_bundles(str(root), num_samples=288,
                             samples_per_file=32, image_size=8, seed=0)


def _orch(files, k=4, **cfg_kw):
    fns = TrainerFns(*make_gan_steps(
        CCFG, OptimizerConfig(name="adam", lr=1e-3)))
    cfg = TournamentConfig(trainers=k, scope="generator", batch_size=16,
                           num_ranks=2, tournament_batches=1,
                           tournament_batch_size=32, seed=0, **cfg_kw)
    return TournamentOrchestrator(fns, DataPlan.jag_cyclegan(files), cfg)


def test_k4_rounds_exchange_accounting_and_winner_propagation(bundle_files):
    orch = _orch(bundle_files)
    try:
        trace = orch.run(rounds=4, steps_per_round=2)
        assert len(trace) == 4 and all(np.isfinite(trace))
        assert orch.population.round == 4
        st = orch.stats()
        # datastore owner->consumer exchange is accounted and nonzero
        assert st["total"]["exchange_bytes"] > 0
        assert st["total"]["cache_hits"] > 0
        # model exchange volume is accounted and nonzero
        assert st["tournament_exchange_bytes"] > 0
        # winners propagate: every round decides K pairwise comparisons,
        # and at least one trainer adopted a partner's model
        wins = [d["wins"] for d in st["per_trainer"]]
        assert sum(wins) == 4 * len(wins)
        assert sum(d["adoptions"] for d in st["per_trainer"]) >= 1
        # all trainers trained from their own partitions
        assert all(d["steps"] == 8 for d in st["per_trainer"])
        assert all(d["files"] == 2 for d in st["per_trainer"])
    finally:
        orch.close()


def test_checkpoint_restart_resumes_at_same_round(bundle_files, tmp_path):
    ck = str(tmp_path / "ck")
    orch = _orch(bundle_files, ckpt_dir=ck)
    try:
        orch.run(rounds=2, steps_per_round=2, ckpt_every=1)
        params0 = [jax.tree.leaves(t.params) for t in
                   orch.population.trainers]
        wins0 = [t.wins for t in orch.population.trainers]
    finally:
        orch.close()

    orch2 = _orch(bundle_files, ckpt_dir=ck)
    try:
        assert orch2.maybe_resume()
        assert orch2.population.round == 2          # same round
        for before, t in zip(params0, orch2.population.trainers):
            for a, b in zip(before, jax.tree.leaves(t.params)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b))
        assert [t.wins for t in orch2.population.trainers] == wins0
        orch2.run(rounds=1, steps_per_round=1)      # training continues
        assert orch2.population.round == 3
    finally:
        orch2.close()


def test_elastic_rescale_repartitions_and_clones_winners(bundle_files):
    orch = _orch(bundle_files, k=2)
    try:
        orch.run(rounds=1, steps_per_round=2)
        best = orch.population.best_metric(orch.val_batch)
        orch.rescale(4)
        assert len(orch.population.trainers) == 4
        assert len(orch.stores) == 4
        assert all(len(s.files) == 2 for s in orch.stores)
        # grown slots warm-start from the population best
        m_new = float(orch.fns.metric(orch.population.trainers[3].params,
                                      orch.val_batch))
        assert m_new <= best + 1e-6
        orch.run(rounds=1, steps_per_round=1)
        assert orch.population.round == 2
        # retired pre-rescale store stats survive in the totals
        assert orch.stats()["total"]["file_opens"] >= 8
        orch.rescale(2)
        assert len(orch.population.trainers) == 2
    finally:
        orch.close()


def test_failure_recovery_through_orchestrator(bundle_files):
    orch = _orch(bundle_files)
    try:
        orch.run(rounds=1, steps_per_round=2)
        orch.fail(1)
        log = orch.tournament()         # dead trainer self-pairs
        assert log["partner"][1] == 1
        orch.recover(1)
        assert orch.population.trainers[1].alive
        m = float(orch.fns.metric(orch.population.trainers[1].params,
                                  orch.val_batch))
        assert m <= orch.population.best_metric(orch.val_batch) + 1e-6
    finally:
        orch.close()


def test_token_shard_manifest_roundtrip(tmp_path):
    files = tokens.write_token_shards(str(tmp_path), num_samples=64,
                                      seq_len=16, vocab=97,
                                      samples_per_file=16, seed=1)
    assert len(files) == 4
    assert tokens.list_token_shards(str(tmp_path)) == files
    shard = tokens.read_token_shard(files[0])
    assert shard["tokens"].shape == (16, 17)
    plan = DataPlan.lm_tokens(files)
    batch = plan.adapt(plan.reader(files[0]))
    assert batch["tokens"].shape == (16, 16)
    np.testing.assert_array_equal(batch["labels"][:, :-1],
                                  batch["tokens"][:, 1:])


def test_cli_smoke_host_backend(tmp_path):
    from repro.launch import ltfb as cli
    rc = cli.main(["--arch", "icf-cyclegan", "--trainers", "2",
                   "--rounds", "1", "--steps-per-round", "1", "--smoke",
                   "--batch", "8", "--samples", "128",
                   "--samples-per-file", "32",
                   "--data-dir", str(tmp_path / "data"),
                   "--ckpt-dir", str(tmp_path / "ck")])
    assert rc == 0
    # a population checkpoint landed on disk
    assert any(f.endswith(".manifest")
               for f in os.listdir(tmp_path / "ck"))


MESH_SCRIPT = r"""
import numpy as np
from repro.configs.base import OptimizerConfig
from repro.configs.icf_cyclegan import CycleGANConfig
from repro.core.population import TrainerFns
from repro.core.tournament import (DataPlan, TournamentConfig,
                                   TournamentOrchestrator)
from repro.data import jag
from repro.train.steps import make_gan_steps

root = "{root}"
files = jag.write_bundles(root, 128, samples_per_file=32, image_size=8)
ccfg = CycleGANConfig(name="t", image_size=8, fwd_hidden=(16,),
                      inv_hidden=(16,), disc_hidden=(16,),
                      enc_hidden=(32,), dec_hidden=(32,))
fns = TrainerFns(*make_gan_steps(ccfg, OptimizerConfig(name="adam",
                                                       lr=1e-3)))
cfg = TournamentConfig(trainers=4, scope="generator", backend="mesh",
                       batch_size=8, num_ranks=2, tournament_batches=1,
                       tournament_batch_size=16, seed=0)
orch = TournamentOrchestrator(fns, DataPlan.jag_cyclegan(files), cfg)
try:
    orch.run(rounds=2, steps_per_round=1)
    st = orch.stats()
    assert st["round"] == 2
    assert st["tournament_exchange_bytes"] > 0
    assert sum(d["wins"] for d in st["per_trainer"]) == 8
finally:
    orch.close()
print("OK")
"""


def test_mesh_backend_tournament_on_8_devices(tmp_path):
    env = dict(os.environ)
    env.update({"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
                "PYTHONPATH": "src"})
    r = subprocess.run(
        [sys.executable, "-c", MESH_SCRIPT.format(root=str(tmp_path))],
        capture_output=True, text=True, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
