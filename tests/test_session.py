"""Decode-API tests: the family-agnostic DecodeSession / CacheLayout
protocol, K-token write/verify parity, recurrent snapshot/restore
round-trips, population speculative decoding (token-identity vs
target-only decode), prefix pinning, and the ragged gather-width
split."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import replace
from repro.configs.registry import get_config
from repro.models.lm import init_lm, lm_decode, lm_prefill
from repro.serve.kv_cache import PagedLayout, SlotLayout
from repro.serve.scheduler import Request, Scheduler
from repro.serve.session import DecodeSession

KEY = jax.random.PRNGKey(0)


def _f32_cfg(arch: str):
    cfg = dataclasses.replace(get_config(arch, smoke=True), dtype="float32")
    if cfg.moe is not None:   # dropless so train-mode forward matches
        cfg = replace(cfg, **{
            "moe.capacity_factor": float(cfg.moe.num_experts)})
    return cfg


def _prompts(cfg, n, max_len, seed=1):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n, max_len), 0, cfg.vocab_size), np.int32)


# ---------------------------------------------------------------------------
# DecodeSession parity vs the direct model entry points
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "jamba-1.5-large-398b"])
def test_session_step_matches_direct_lm_decode(arch):
    """session.step on a SlotLayout is the old dense decode loop: same
    tokens as calling lm_prefill + lm_decode by hand."""
    cfg = _f32_cfg(arch)
    params, _ = init_lm(cfg, KEY)
    toks = _prompts(cfg, 2, 6)

    # by hand: the pre-DecodeSession flow
    logits, cache = lm_prefill(params, cfg, {"tokens": jnp.asarray(toks)})
    hand = [np.asarray(jnp.argmax(logits[:, -1], axis=-1))]
    from repro.models.lm import init_cache
    full, _ = init_cache(cfg, 2, 16)
    cache = jax.tree.map(
        lambda d, s: jax.lax.dynamic_update_slice(
            d, s.astype(d.dtype), (0,) * d.ndim), full, cache)
    for i in range(3):
        logits, cache = lm_decode(params, cfg, hand[-1][:, None], cache,
                                  jnp.int32(6 + i))
        hand.append(np.asarray(jnp.argmax(logits[:, -1], axis=-1)))

    sess = DecodeSession(cfg, params, SlotLayout(cfg, 2, 16))
    logits = sess.prefill_batch(jnp.asarray(toks))
    got = [np.asarray(jnp.argmax(logits[:, -1], axis=-1))]
    index = np.full((2,), 6, np.int32)
    for i in range(3):
        logits = sess.step(got[-1][:, None], index + i)
        got.append(np.asarray(jnp.argmax(logits[:, -1], axis=-1)))
    for h, g in zip(hand, got):
        assert h.tolist() == g.tolist()


# ---------------------------------------------------------------------------
# K-token write/verify: multi-token step == K sequential single steps
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch,layout", [
    ("qwen3-0.6b", "paged"),
    ("qwen3-0.6b", "dense"),
    ("jamba-1.5-large-398b", "paged"),
    ("xlstm-125m", "dense"),
])
def test_k_token_step_matches_sequential(arch, layout):
    """One step(tokens, k=K) writes the same cache state and returns
    the same per-position logits as K single-token steps — the verify
    primitive speculative decoding relies on."""
    cfg = _f32_cfg(arch)
    params, _ = init_lm(cfg, KEY)
    prompt = _prompts(cfg, 1, 6)[0]
    K = 3
    feed = _prompts(cfg, 1, K, seed=9)[0]       # arbitrary verify block

    def make_sess():
        lay = PagedLayout(cfg, 1, 12, block_size=4) if layout == "paged" \
            else SlotLayout(cfg, 1, 24, block_size=4)
        sess = DecodeSession(cfg, params, lay)
        if layout == "paged":
            lay.admit("r", 6 + K + 1)
        else:
            lay.admit("r", 6 + K + 1)
        sess.prefill("r", prompt)
        if layout == "paged":
            lay.ensure("r", 6 + K)
        return sess

    # K sequential single-token steps
    seq = make_sess()
    seq_logits = []
    for t in range(K):
        lg = seq.step(feed[t].reshape(1, 1), np.asarray([6 + t], np.int32),
                      width=4 if layout == "paged" else None)
        seq_logits.append(np.asarray(lg[0, 0].astype(jnp.float32)))
    # one K-token verify step
    multi = make_sess()
    lg = multi.step(feed.reshape(1, K), np.asarray([6], np.int32),
                    width=4 if layout == "paged" else None)
    lg = np.asarray(lg[0].astype(jnp.float32))
    for t in range(K):
        np.testing.assert_allclose(lg[t], seq_logits[t],
                                   atol=1e-4, rtol=1e-4)
    # the cache states agree too: one more step from each must match
    nxt = np.asarray([[int(np.argmax(seq_logits[-1]))]], np.int32)
    a = seq.step(nxt, np.asarray([6 + K], np.int32),
                 width=4 if layout == "paged" else None)
    b = multi.step(nxt, np.asarray([6 + K], np.int32),
                   width=4 if layout == "paged" else None)
    np.testing.assert_allclose(np.asarray(a.astype(jnp.float32)),
                               np.asarray(b.astype(jnp.float32)),
                               atol=1e-4, rtol=1e-4)


def test_k_token_valid_mask_freezes_tail():
    """Tokens past ``valid`` must not change the cache: a masked
    K-token step equals feeding only the valid prefix (recurrent state
    frozen, paged writes null-routed)."""
    cfg = _f32_cfg("jamba-1.5-large-398b")
    params, _ = init_lm(cfg, KEY)
    prompt = _prompts(cfg, 1, 5)[0]
    feed = _prompts(cfg, 1, 4, seed=3)[0]

    def run(tokens, valid):
        lay = PagedLayout(cfg, 1, 12, block_size=4)
        sess = DecodeSession(cfg, params, lay)
        lay.admit("r", 16)
        sess.prefill("r", prompt)
        lay.ensure("r", 5 + len(tokens))
        sess.step(tokens.reshape(1, -1), np.asarray([5], np.int32),
                  valid=None if valid is None
                  else np.asarray([valid], np.int32), width=4)
        probe = np.asarray([[7]], np.int32)
        lg = sess.step(probe, np.asarray([5 + 2], np.int32), width=4)
        return np.asarray(lg.astype(jnp.float32))

    masked = run(feed, valid=2)          # 4 fed, 2 real
    exact = run(feed[:2], valid=None)    # the 2 real ones only
    np.testing.assert_allclose(masked, exact, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# snapshot / restore round-trip (recurrent families)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["jamba-1.5-large-398b", "xlstm-125m"])
def test_snapshot_restore_roundtrip(arch):
    """snapshot -> K steps -> restore replays to IDENTICAL logits, and
    restore is per-row: an untouched row keeps its advanced state."""
    cfg = _f32_cfg(arch)
    params, _ = init_lm(cfg, KEY)
    toks = _prompts(cfg, 2, 6)
    lay = SlotLayout(cfg, 2, 24, block_size=4)
    sess = DecodeSession(cfg, params, lay)
    assert lay.has_recurrent
    sess.prefill_batch(jnp.asarray(toks))
    index = np.full((2,), 6, np.int32)

    snap = sess.snapshot()
    assert len(snap) > 0
    feed = _prompts(cfg, 2, 1, seed=4)
    first = np.asarray(sess.step(feed, index).astype(jnp.float32))
    # advance further, then roll row 0 back and replay: identical
    sess.step(feed + 1, index + 1)
    sess.restore(snap, np.asarray([True, False]))
    again = np.asarray(sess.step(feed, index,
                                 valid=np.asarray([1, 0], np.int32))
                       .astype(jnp.float32))
    np.testing.assert_allclose(again[0], first[0], atol=1e-5, rtol=1e-5)
    # row 1 was NOT restored: its recurrent state kept moving, so the
    # same probe must now answer differently
    assert not np.allclose(again[1], first[1], atol=1e-5)


def test_snapshot_is_a_copy_not_a_view():
    """Donated step buffers must never alias a snapshot: mutate the
    cache after snapshotting, the snapshot stays intact."""
    cfg = _f32_cfg("xlstm-125m")
    params, _ = init_lm(cfg, KEY)
    toks = _prompts(cfg, 1, 6)
    lay = SlotLayout(cfg, 1, 16)
    sess = DecodeSession(cfg, params, lay)
    sess.prefill_batch(jnp.asarray(toks))
    snap = sess.snapshot()
    before = [np.asarray(s) for s in snap]
    for _ in range(3):                   # donating steps mutate the pool
        sess.step(np.asarray([[5]], np.int32), np.asarray([6], np.int32))
    for b, s in zip(before, snap):
        np.testing.assert_array_equal(b, np.asarray(s))


# ---------------------------------------------------------------------------
# speculative decoding: token-identity with target-only decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", [
    "qwen3-0.6b",              # dense attention
    "deepseek-moe-16b",        # attention + MoE
    "jamba-1.5-large-398b",    # hybrid mamba/attention/moe (rollback!)
])
@pytest.mark.parametrize("draft_kind", ["self", "other"])
def test_spec_decode_greedy_token_identity(arch, draft_kind):
    """Acceptance: speculative decoding at temperature 0 emits exactly
    the target-only greedy tokens — with a perfect drafter (self) and
    a disagreeing one (fresh init, near-zero accept rate)."""
    cfg = _f32_cfg(arch)
    params, _ = init_lm(cfg, KEY)
    draft = params if draft_kind == "self" \
        else init_lm(cfg, jax.random.PRNGKey(11))[0]
    toks = _prompts(cfg, 3, 12)

    def serve(dp, k):
        s = Scheduler(cfg, params, num_slots=2, max_len=28, block_size=4,
                      draft_params=dp, spec_tokens=k)
        for i in range(3):
            s.submit(Request(rid=i, prompt=toks[i, :5 + 3 * i], max_new=6))
        r = s.run(max_steps=300)
        assert len(r) == 3
        return r, s

    base, _ = serve(None, 0)
    spec, ss = serve(draft, 3)
    for i in range(3):
        assert base[i].tolist() == spec[i].tolist(), (arch, draft_kind, i)
    d = ss.stats.as_dict()
    assert d["spec_rounds"] > 0
    if draft_kind == "self":
        assert d["spec_accept_rate"] > 0.5    # only budget-tail losses
        assert d["spec_rounds"] < ss.stats.decode_tokens


def test_spec_decode_temperature_identity():
    """Sampling is deterministic in (seed, ntok), so spec decode is
    token-identical at temperature > 0 as well."""
    cfg = _f32_cfg("qwen3-0.6b")
    params, _ = init_lm(cfg, KEY)
    draft, _ = init_lm(cfg, jax.random.PRNGKey(11))
    toks = _prompts(cfg, 2, 8)

    def serve(dp, k):
        s = Scheduler(cfg, params, num_slots=2, max_len=24, block_size=4,
                      draft_params=dp, spec_tokens=k)
        for i in range(2):
            s.submit(Request(rid=i, prompt=toks[i], max_new=6,
                             temperature=0.8, seed=42 + i))
        return s.run(max_steps=300)

    assert {k: v.tolist() for k, v in serve(None, 0).items()} \
        == {k: v.tolist() for k, v in serve(draft, 2).items()}


def test_spec_decode_dense_layout_and_eos():
    """Spec rounds on the dense layout, with an EOS that lands inside
    an accepted block: generation stops AT the eos token."""
    cfg = _f32_cfg("qwen3-0.6b")
    params, _ = init_lm(cfg, KEY)
    toks = _prompts(cfg, 1, 8)

    def serve(dp, k, eos=None):
        s = Scheduler(cfg, params, num_slots=1, max_len=32, block_size=4,
                      layout="dense", draft_params=dp, spec_tokens=k)
        s.submit(Request(rid=0, prompt=toks[0], max_new=8, eos_id=eos))
        return s.run(max_steps=200)[0]

    gen = serve(None, 0)
    assert serve(params, 3).tolist() == gen.tolist()
    eos = int(gen[2])
    want = gen[:3].tolist()              # stops AT the eos token
    assert serve(None, 0, eos=eos).tolist() == want
    assert serve(params, 3, eos=eos).tolist() == want


# ---------------------------------------------------------------------------
# prefix pinning
# ---------------------------------------------------------------------------


def test_pin_prefix_survives_idle_and_reclaims_under_pressure():
    cfg = _f32_cfg("qwen3-0.6b")
    pool = PagedLayout(cfg, num_slots=2, num_pages=8, block_size=4,
                       pin_prefix=True)
    prompt = np.arange(11, dtype=np.int32)         # 2 full pages + tail
    pool.admit("a", 12, prompt)
    pool.ensure("a", 11)
    pool.register_prefix("a", prompt)
    pinned = pool.blocks.table("a")[:2]
    pool.release("a")                               # pool goes IDLE
    # the registered prefix pages survive: still resident + shareable
    assert all(pool.blocks.refcount(p) == 1 for p in pinned)
    assert pool.find_shared_prefix(prompt)[1] == 8
    assert pool.blocks.as_dict()["pinned_blocks"] == 2
    # a new request maps them without prefilling
    _, shared = pool.admit("b", 12, prompt)
    assert shared == 8 and pool.blocks.table("b")[:2] == pinned
    pool.release("b")
    # allocation pressure reclaims the pinned tier (oldest first) and
    # the prefix cache forgets the stolen pages
    pool.admit("big", 32)                           # all 8 pages
    pool.ensure("big", 32)
    assert pool.find_shared_prefix(prompt)[1] == 0
    assert pool.blocks.as_dict()["block_reclaims"] == 2
    pool.release("big")


def test_pin_shared_pages_not_double_counted_at_admission():
    """Mapping idle pinned pages as a shared prefix removes them from
    the reclaim tier: admission must not count them BOTH as free
    prefix pages and as reclaimable capacity (that over-promise used
    to surface as an uncaught RuntimeError from ensure() mid-serve)."""
    cfg = _f32_cfg("qwen3-0.6b")
    pool = PagedLayout(cfg, num_slots=3, num_pages=8, block_size=4,
                       pin_prefix=True)
    prompt = np.arange(16, dtype=np.int32)          # 4 full pages
    pool.admit("a", 16, prompt)
    pool.ensure("a", 16)
    pool.register_prefix("a", prompt)
    pool.release("a")                                # 4 pinned-idle pages
    pool.admit("c", 8)
    pool.ensure("c", 8)                              # 2 pages held live
    shared = pool.find_shared_prefix(prompt)
    assert shared[1] == 12                           # capped at len-1
    # 28 tokens = 7 blocks, 3 of them shared+pinned: only 2 free pages
    # remain once the shared ones stop being reclaimable -> reject
    assert not pool.can_admit(28, shared_pages=shared[0])
    with pytest.raises(RuntimeError, match="out of cache blocks"):
        pool.admit("b", 28, shared=shared)
    # a fit that honors the corrected budget still works end to end
    ok = pool.find_shared_prefix(prompt)
    slot, shared_len = pool.admit("b", 20, shared=ok)
    pool.ensure("b", 20)
    pool.release("b")
    pool.release("c")


def test_reclaim_insufficiency_leaves_pins_intact():
    """A reclaim that cannot cover the demand must raise BEFORE
    mutating: the pinned tier and the prefix cache stay consistent."""
    cfg = _f32_cfg("qwen3-0.6b")
    pool = PagedLayout(cfg, num_slots=3, num_pages=6, block_size=4,
                       pin_prefix=True)
    prompt = np.arange(9, dtype=np.int32)            # 2 full pages
    pool.admit("a", 9, prompt)
    pool.ensure("a", 9)
    pool.register_prefix("a", prompt)
    pool.release("a")                                # 2 pinned-idle, tail freed
    pinned = sorted(pool.blocks._pinned)
    # demand more than the reclaim tier holds (2 idle pinned pages)
    with pytest.raises(RuntimeError, match="out of cache blocks"):
        pool.blocks._reclaim(3)
    assert sorted(pool.blocks._pinned) == pinned     # nothing stolen
    assert pool.find_shared_prefix(prompt)[1] == 8   # prefix intact
    assert all(pool.blocks.refcount(p) == 1 for p in pinned)
    # and a coverable demand still reclaims cleanly
    pool.blocks._reclaim(2)
    assert pool.blocks.free_blocks == 6
    assert pool.find_shared_prefix(prompt)[1] == 0   # owner was told


def test_pin_prefix_unpinned_baseline_evicts():
    """Without the flag the PR-3 behavior is unchanged: last release
    evicts the prefix."""
    cfg = _f32_cfg("qwen3-0.6b")
    pool = PagedLayout(cfg, num_slots=2, num_pages=8, block_size=4)
    prompt = np.arange(11, dtype=np.int32)
    pool.admit("a", 12, prompt)
    pool.ensure("a", 11)
    pool.register_prefix("a", prompt)
    pool.release("a")
    assert pool.find_shared_prefix(prompt)[1] == 0


def test_pin_prefix_end_to_end_idle_gap():
    """Scheduler flag: a request stream with an idle gap re-serves the
    shared system prompt from pinned pages (prefix hit after the pool
    drained) and the generated tokens are unchanged."""
    cfg = _f32_cfg("qwen3-0.6b")
    params, _ = init_lm(cfg, KEY)
    rng = np.random.default_rng(5)
    sys_prefix = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    mk = lambda i: np.concatenate(
        [sys_prefix, rng.integers(0, cfg.vocab_size, 3).astype(np.int32)])
    p0, p1 = mk(0), mk(1)

    def serve(pin):
        s = Scheduler(cfg, params, num_slots=2, max_len=32, block_size=4,
                      pin_prefix=pin)
        s.submit(Request(rid=0, prompt=p0, max_new=4))
        s.run(max_steps=100)             # drains: pool idle
        assert not s.active and not s.prefilling
        s.submit(Request(rid=1, prompt=p1, max_new=4))
        s.run(max_steps=100)
        return s

    cold = serve(False)
    hot = serve(True)
    assert cold.pool.prefix_hits == 0     # evicted across the gap
    assert hot.pool.prefix_hits == 1      # pinned pages survived it
    assert hot.results[1].tolist() == cold.results[1].tolist()
    assert hot.stats.prefill_tokens < cold.stats.prefill_tokens
    # hot swap drops the pins with the prefix cache
    hot.set_params(init_lm(cfg, jax.random.PRNGKey(2))[0])
    assert hot.pool.blocks.as_dict()["pinned_blocks"] == 0


# ---------------------------------------------------------------------------
# ragged gather-width split
# ---------------------------------------------------------------------------


def test_ragged_width_split_triggers_and_preserves_tokens():
    """One long request among short chats: the decode round splits into
    (narrow, wide) groups on the CPU oracle, tokens unchanged."""
    cfg = _f32_cfg("qwen3-0.6b")
    params, _ = init_lm(cfg, KEY)
    toks = _prompts(cfg, 3, 80)

    def serve(split):
        s = Scheduler(cfg, params, num_slots=3, max_len=96, block_size=4,
                      prefix_sharing=False)
        assert s._group_decode            # paged + attention-only + CPU
        s._group_decode = split
        s.submit(Request(rid="long", prompt=toks[0], max_new=8))
        for i in range(2):
            s.submit(Request(rid=i, prompt=toks[1 + i, :6], max_new=8))
        r = s.run(max_steps=300)
        assert len(r) == 3
        return r, s

    plain, s0 = serve(False)
    split, s1 = serve(True)
    assert s0.stats.ragged_splits == 0
    # long request: 80 tokens -> 32-wide pow2 bucket; chats sit at 4
    assert s1.stats.ragged_splits > 0
    for rid in plain:
        assert plain[rid].tolist() == split[rid].tolist(), rid
