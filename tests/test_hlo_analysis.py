"""HLO analyzer validation: trip-count-corrected costs must match XLA's
cost_analysis on unrolled programs, and scans must scale with length."""
import jax
import jax.numpy as jnp
import pytest

from repro.parallel.hlo_analysis import analyze_hlo, xla_cost_analysis


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_unrolled_matches_xla_cost_analysis():
    def f(xs, w):
        c = w
        for i in range(5):
            c = c @ xs[i]
        return c

    co = _compile(f, jax.ShapeDtypeStruct((5, 64, 64), jnp.float32),
                  jax.ShapeDtypeStruct((64, 64), jnp.float32))
    mine = analyze_hlo(co.as_text()).flops
    xla = xla_cost_analysis(co).get("flops", 0.0)
    assert abs(mine - xla) / xla < 0.05


def test_scan_flops_scale_with_trip_count():
    def mk(n):
        def f(xs, w):
            def body(c, x):
                return c @ x, ()
            out, _ = jax.lax.scan(body, w, xs)
            return out
        return _compile(f, jax.ShapeDtypeStruct((n, 64, 64), jnp.float32),
                        jax.ShapeDtypeStruct((64, 64), jnp.float32))

    f3 = analyze_hlo(mk(3).as_text()).flops
    f12 = analyze_hlo(mk(12).as_text()).flops
    assert f12 == pytest.approx(4 * f3, rel=0.05)


def test_trip_count_detected():
    def f(xs, w):
        def body(c, x):
            return c @ x, ()
        out, _ = jax.lax.scan(body, w, xs)
        return out

    co = _compile(f, jax.ShapeDtypeStruct((7, 32, 32), jnp.float32),
                  jax.ShapeDtypeStruct((32, 32), jnp.float32))
    cost = analyze_hlo(co.as_text())
    assert 7 in cost.trip_counts.values()


def test_scan_matches_unrolled_flops():
    def f_scan(xs, w):
        def body(c, x):
            return c @ x, ()
        return jax.lax.scan(body, w, xs)[0]

    def f_unroll(xs, w):
        c = w
        for i in range(6):
            c = c @ xs[i]
        return c

    s1 = jax.ShapeDtypeStruct((6, 48, 48), jnp.float32)
    s2 = jax.ShapeDtypeStruct((48, 48), jnp.float32)
    a = analyze_hlo(_compile(f_scan, s1, s2).as_text()).flops
    b = analyze_hlo(_compile(f_unroll, s1, s2).as_text()).flops
    assert a == pytest.approx(b, rel=0.05)


def test_dynamic_update_slice_counted_as_slice_traffic():
    def f(buf, upd):
        return jax.lax.dynamic_update_slice(buf, upd, (0, 0))

    big = jax.ShapeDtypeStruct((4096, 256), jnp.float32)
    small = jax.ShapeDtypeStruct((1, 256), jnp.float32)
    # donated buffer -> in-place DUS (the KV-cache decode pattern)
    co = jax.jit(f, donate_argnums=(0,)).lower(big, small).compile()
    cost = analyze_hlo(co.as_text())
    # traffic ~ 2x the update slice (1KB), NOT the 4MB buffer
    assert cost.bytes < 64 * 1024, cost.bytes


def test_collectives_counted_with_multiplier():
    import os, subprocess, sys
    script = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P, NamedSharding
from repro.parallel.hlo_analysis import analyze_hlo
mesh = Mesh(np.asarray(jax.devices()).reshape(4,), ("d",))
sh = NamedSharding(mesh, P(None, "d"))
def f(xs, w):
    def body(c, x):
        h = c @ x
        return jax.lax.with_sharding_constraint(h, sh), jnp.sum(h)
    return jax.lax.scan(body, w, xs)
co = jax.jit(f, in_shardings=(None, sh)).lower(
    jax.ShapeDtypeStruct((5,64,64), jnp.float32),
    jax.ShapeDtypeStruct((64,64), jnp.float32)).compile()
c = analyze_hlo(co.as_text())
assert c.coll_bytes > 0, c
assert any(v >= 5 for v in c.coll_count.values()), c.coll_count
print("OK")
"""
    env = dict(os.environ)
    env.update({"XLA_FLAGS": "--xla_force_host_platform_device_count=4",
                "PYTHONPATH": "src"})
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0, r.stderr[-2000:]
