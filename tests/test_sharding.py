"""Sharding-rule resolution tests + multi-device constraint checks."""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import constrain


def test_constrain_is_noop_without_context():
    x = jnp.ones((4, 4))
    y = constrain(x, "batch", "seq")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_rules_divisibility_fallback():
    """Non-divisible dims fall back to replication instead of erroring."""
    script = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.parallel.sharding import ShardingRules
mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
rules = ShardingRules(mesh, {})
# heads=3 not divisible by model=4 -> dropped
spec = rules.resolve(("batch", "seq", "heads", "head_dim"), (8, 16, 3, 64))
assert spec[2] is None, spec
# mlp=8 divisible by model=4 -> kept
spec2 = rules.resolve(("batch", "seq", "mlp_act"), (8, 16, 8))
assert spec2[2] == "model", spec2
# batch rule ("pod","data"): pod absent from mesh -> only data
assert spec2[0] == "data", spec2
print("OK")
"""
    env = dict(os.environ)
    env.update({"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
                "PYTHONPATH": "src"})
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0, r.stderr[-2000:]


def test_tree_shardings_on_param_axes():
    script = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.parallel.sharding import tree_shardings
from repro.launch.specs import param_specs
from repro.configs.registry import get_config
mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
cfg = get_config("qwen3-0.6b", smoke=True)
shapes, axes = param_specs(cfg)
sh = tree_shardings(mesh, axes, shapes)
# every leaf got a NamedSharding and shard shapes divide evenly
for s, spec in zip(jax.tree.leaves(shapes), jax.tree.leaves(sh)):
    ss = spec.shard_shape(s.shape)
    assert all(a % b == 0 for a, b in zip(s.shape, ss))
print("OK")
"""
    env = dict(os.environ)
    env.update({"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
                "PYTHONPATH": "src"})
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0, r.stderr[-2000:]


def test_sharded_train_step_matches_single_device():
    """DP/TP-sharded smoke train step == single-device train step."""
    script = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.parallel.sharding import tree_shardings, use_sharding
from repro.configs.registry import get_config
from repro.configs.base import OptimizerConfig, MeshConfig
from repro.train.steps import init_lm_state, make_lm_train_step
from repro.launch.specs import state_specs
import dataclasses

cfg = dataclasses.replace(get_config("qwen3-0.6b", smoke=True),
                          dtype="float32")
opt = OptimizerConfig(lr=1e-3, warmup_steps=1)
batch = {"tokens": jnp.arange(2*16, dtype=jnp.int32).reshape(2,16) % cfg.vocab_size,
         "labels": jnp.arange(2*16, dtype=jnp.int32).reshape(2,16) % cfg.vocab_size}
step_fn = make_lm_train_step(cfg, opt, MeshConfig(remat="none"))

# single device
state0, _ = init_lm_state(cfg, opt, jax.random.PRNGKey(0))
s1, m1 = jax.jit(step_fn)(state0, batch)

# sharded over (2 data, 2 model)
mesh = Mesh(np.asarray(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
with use_sharding(mesh):
    state0b, axes = init_lm_state(cfg, opt, jax.random.PRNGKey(0))
    sh = tree_shardings(mesh, axes, state0b)
    state0b = jax.device_put(state0b, sh)
    s2, m2 = jax.jit(step_fn, in_shardings=(sh, None),
                     out_shardings=(sh, None))(state0b, batch)
d = abs(float(m1["loss"]) - float(m2["loss"]))
assert d < 1e-4, d
for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
    err = float(jnp.max(jnp.abs(a - b)))
    assert err < 1e-4, err
print("OK")
"""
    env = dict(os.environ)
    env.update({"XLA_FLAGS": "--xla_force_host_platform_device_count=4",
                "PYTHONPATH": "src"})
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0, (r.stderr[-3000:], r.stdout[-500:])
