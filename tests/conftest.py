import os
import sys

# smoke tests and benches must see 1 CPU device (the dry-run entrypoint
# sets its own XLA_FLAGS before importing jax) — ensure src is importable
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
