"""Distributed data store tests (paper §III-B): population modes, epoch
shuffling, exchange accounting, prefetch overlap, partitioning."""
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.data import jag
from repro.datastore.store import DataStore, PrefetchLoader, partition_files


@pytest.fixture(scope="module")
def bundles(tmp_path_factory):
    root = tmp_path_factory.mktemp("jag")
    paths = jag.write_bundles(str(root), num_samples=400,
                              samples_per_file=50, image_size=8, seed=0)
    return paths


def test_bundle_roundtrip(bundles):
    b = jag.read_bundle(bundles[0])
    assert b["x"].shape == (50, 5)
    assert b["scalars"].shape == (50, 15)
    assert b["images"].shape == (50, 12, 8, 8)
    assert np.all(np.isfinite(b["images"]))


def test_preload_opens_each_file_once(bundles):
    store = DataStore(bundles, jag.read_bundle, num_ranks=4, mode="preload")
    store.preload(parallel=True)
    # probe opened file 0 once; preload opens the remaining 7
    assert store.stats.file_opens == len(bundles)
    perm = store.epoch_permutation(0)
    batch = store.get_batch(perm, 0, 32)
    assert batch["x"].shape == (32, 5)
    assert store.stats.file_opens == len(bundles)   # no new opens


def test_dynamic_mode_caches_after_first_epoch(bundles):
    store = DataStore(bundles, jag.read_bundle, num_ranks=2, mode="dynamic")
    perm = store.epoch_permutation(0)
    spe = store.steps_per_epoch(32)
    for s in range(spe):
        store.get_batch(perm, s, 32)
    opens_after_first = store.stats.file_opens
    perm2 = store.epoch_permutation(1)
    for s in range(spe):
        store.get_batch(perm2, s, 32)
    assert store.stats.file_opens == opens_after_first  # epoch 2+: cached


def test_naive_mode_reopens_files(bundles):
    store = DataStore(bundles, jag.read_bundle, num_ranks=2, mode="none")
    perm = store.epoch_permutation(0)
    store.get_batch(perm, 0, 64)
    # naive reader: ~one open per sample (vs 8 files total)
    assert store.stats.file_opens > len(bundles)


def test_epoch_permutations_differ_and_cover(bundles):
    store = DataStore(bundles, jag.read_bundle, mode="preload")
    p0 = store.epoch_permutation(0)
    p1 = store.epoch_permutation(1)
    assert not np.array_equal(p0, p1)
    assert np.array_equal(np.sort(p0), np.arange(store.num_samples))
    assert np.array_equal(np.sort(p1), np.arange(store.num_samples))


def test_exchange_bytes_counted(bundles):
    store = DataStore(bundles, jag.read_bundle, num_ranks=4, mode="preload")
    store.preload()
    perm = store.epoch_permutation(0)
    store.get_batch(perm, 0, 64, consumer_rank=0)
    # ~3/4 of samples owned by other ranks -> exchanged
    assert store.stats.exchange_bytes > 0


def test_prefetch_loader_overlaps(bundles):
    store = DataStore(bundles, jag.read_bundle, mode="preload")
    store.preload()
    loader = PrefetchLoader(store, batch_size=16, depth=2)
    try:
        batches = [loader.next() for _ in range(5)]
        assert all(b["x"].shape == (16, 5) for b in batches)
    finally:
        loader.close()


@given(st.integers(1, 16), st.integers(1, 64))
@settings(max_examples=30, deadline=None)
def test_partition_files_disjoint_and_covering(k, n):
    files = [f"f{i}" for i in range(n)]
    parts = [partition_files(files, k, i) for i in range(k)]
    flat = [f for p in parts for f in p]
    assert sorted(flat) == sorted(files)          # covering, no dupes
    assert max(len(p) for p in parts) - min(len(p) for p in parts) <= 1


def test_jag_simulator_is_deterministic_and_nonlinear():
    x = jag.sample_inputs(64, seed=3)
    a = jag.jag_simulate(x, 8)
    b = jag.jag_simulate(x, 8)
    np.testing.assert_array_equal(a["scalars"], b["scalars"])
    # strong non-linearity in drive: doubling drive >> doubles yield
    lo = jag.jag_simulate(np.full((1, 5), 0.25, np.float32), 8)
    hi = jag.jag_simulate(np.full((1, 5), 0.50, np.float32), 8)
    assert hi["scalars"][0, 0] > 1.5 * lo["scalars"][0, 0]
